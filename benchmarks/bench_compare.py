"""Bench regression gate: fresh runs vs the committed ``BENCH_*.json``.

Every benchmark now emits the standardized ``dflow-bench/v1`` document
(schema tag + a flat ``metrics`` list of ``{system, metric, value,
direction, tolerance}`` rows).  This driver re-runs each benchmark with
the *committed document's own config* (so the comparison is
apples-to-apples even after a config change lands with new numbers),
diffs fresh against committed via
:func:`repro.core.obs.compare_docs`, and **exits 1 when any gated
metric regresses beyond its tolerance** (default 10% — the ">10% p99"
CI gate) or a committed metric vanishes from the fresh run.

Committed baselines are never overwritten — refresh them by running the
individual benchmark modules.

Run:  PYTHONPATH=src python -m benchmarks.bench_compare \
          [--only dcheck,obs] [--fast]
"""

import argparse
import json
import sys

from repro.core.obs import compare_docs

from . import (dcheck_overhead, dplan_overhead, dshard_routing,
               obs_overhead, serve_autoscale)


def _regen_dcheck(config, repeats):
    return dcheck_overhead.measure(config, repeats=repeats)


def _regen_dplan(config, repeats):
    return dplan_overhead.measure(config, repeats=repeats)


def _regen_obs(config, repeats):
    doc, _spans = obs_overhead.measure(config, repeats=repeats)
    return doc


def _regen_dshard(config, repeats):
    cfg = {k: v for k, v in config.items() if k != "nodes"}
    cfg["repeats"] = repeats
    return dshard_routing.measure(n_nodes=config["nodes"], cfg=cfg)


def _regen_scale(config, repeats):
    # The committed doc carries the rising-RPS sweep; regenerating it per
    # gate check would triple the runtime for report-only rows, so the
    # re-run gates on the comparison arms alone.
    cfg = {k: v for k, v in config.items() if k != "burst_rates"}
    return serve_autoscale.measure(cfg, repeats=repeats)


# name -> (committed baseline path, regenerator)
BENCHES = {
    "dcheck": ("BENCH_dcheck.json", _regen_dcheck),
    "dplan": ("BENCH_dplan.json", _regen_dplan),
    "dshard": ("BENCH_dshard.json", _regen_dshard),
    "obs": ("BENCH_obs.json", _regen_obs),
    "scale": ("BENCH_scale.json", _regen_scale),
}


def compare_one(name, *, fast=False, tolerance=0.10):
    """Returns (rows, failures) for one bench; failures non-empty on
    regression, schema mismatch, or unreadable baseline."""
    path, regen = BENCHES[name]
    try:
        with open(path, "r", encoding="utf-8") as fh:
            old = json.load(fh)
    except (OSError, ValueError) as exc:
        return [], [f"{name}: cannot read baseline {path!r}: {exc}"]
    if old.get("schema") != "dflow-bench/v1":
        return [], [f"{name}: baseline {path!r} lacks the dflow-bench/v1 "
                    "schema tag — regenerate it"]
    config = dict(old.get("config", {}))
    repeats = int(old.get("repeats", config.get("repeats", 3)))
    if fast:
        repeats = min(repeats, 2)
    new = regen(config, repeats)
    return compare_docs(old, new, default_tolerance=tolerance)


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", metavar="NAMES",
                    help="comma-separated subset of "
                    + ",".join(BENCHES))
    ap.add_argument("--fast", action="store_true",
                    help="cap repeats at 2 (CI quick tier)")
    ap.add_argument("--tolerance", type=float, default=0.10,
                    help="default relative tolerance for gated metrics "
                    "without an explicit one (default 0.10)")
    args = ap.parse_args(argv)
    names = (args.only.split(",") if args.only else list(BENCHES))
    for n in names:
        if n not in BENCHES:
            ap.error(f"unknown bench {n!r}; choose from {list(BENCHES)}")

    all_failures = []
    for name in names:
        rows, failures = compare_one(name, fast=args.fast,
                                     tolerance=args.tolerance)
        gated = sum(r["gated"] for r in rows)
        print(f"== {name}: {len(rows)} metric(s), {gated} gated, "
              f"{len(failures)} failure(s)")
        for r in rows:
            flag = ("REGRESSED" if r["regressed"]
                    else (r["direction"] or "report"))
            print(f"   {r['system']:10s} {r['metric']:26s} "
                  f"{r['old']:10.4g} -> {r['new']:10.4g} "
                  f"{r['rel']:+8.1%}  {flag}")
        all_failures += [f"{name}: {f}" for f in failures]
    if all_failures:
        print(f"\n{len(all_failures)} regression(s):", file=sys.stderr)
        for f in all_failures:
            print(f"  REGRESSION: {f}", file=sys.stderr)
        return 1
    print("\n# all gated metrics within tolerance of committed baselines")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
