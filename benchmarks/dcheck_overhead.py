"""DCheck overhead: serve_load smoke with the trace checker on vs off.

The recorder hook is designed to be zero-cost when detached (one ``is
None`` test per instrumentation point) and cheap when attached (an
append + digest under one lock).  This benchmark pins both claims to a
number and writes ``BENCH_dcheck.json`` so later PRs (sharded DStore,
dynamic DAGs) can see whether they regressed the checker's overhead.

Methodology: the serve_load SMOKE configuration (one rate, 10 Poisson
arrivals of the 4-stage Srv chain) runs once with no tracer, once with a
:class:`TraceRecorder` attached (no stress sleeps — those measure the
*scheduler*, not the checker), and the p50/p99/wall numbers are compared.
The traced run's events are then replayed through :class:`TraceChecker`
and its offline check time is reported separately — the checker never
sits on the serving path.

Run:  PYTHONPATH=src python -m benchmarks.dcheck_overhead [--out FILE]
"""

import argparse
import json
import time

from repro.core.check import TraceChecker, TraceRecorder
from repro.core.obs import bench_doc, bench_metric
from repro.core.serve import DServe, poisson_arrivals
from repro.core.workloads import serving_chain

SMOKE = dict(rate=8.0, n=10, stages=4, exec_time=0.03, cold_start=0.15)


def _run_once(tracer, *, rate, n, stages, exec_time, cold_start):
    wf = serving_chain(stages=stages, exec_time=exec_time,
                       cold_start=cold_start, payload=16 * 1024)
    srv = DServe(wf, n_nodes=2, pattern="dataflow", keepalive=10.0,
                 max_per_node=16, tracer=tracer)
    rep = srv.run(poisson_arrivals(rate, n, seed=7),
                  inputs={"request": b"req"})
    assert rep.failures == 0, "instances failed during benchmark"
    return rep


def measure(cfg=SMOKE, repeats: int = 3):
    """Best-of-``repeats`` for each mode (thread-scheduling noise on a
    shared runner dwarfs the effect being measured otherwise)."""
    off = min((_run_once(None, **cfg) for _ in range(repeats)),
              key=lambda r: r.wall_time)
    recorders = []

    def traced():
        rec = TraceRecorder()
        recorders.append(rec)
        return _run_once(rec, **cfg)

    on = min((traced() for _ in range(repeats)),
             key=lambda r: r.wall_time)
    rec = max(recorders, key=len)
    t0 = time.perf_counter()
    violations = TraceChecker().check(rec.events())
    check_s = time.perf_counter() - t0
    assert not violations, [str(v) for v in violations]
    p99_ratio = round(on.p99 / max(off.p99, 1e-9), 3)
    wall_ratio = round(on.wall_time / max(off.wall_time, 1e-9), 3)
    # Standardized dflow-bench/v1 rows.  Ratios are gated (lower is
    # better; noise-relative, so they survive shared runners); absolute
    # wall-clock latencies are report-only.
    metrics = [
        bench_metric("dcheck", "p99_ratio", p99_ratio, "x",
                     direction="lower"),
        bench_metric("dcheck", "wall_ratio", wall_ratio, "x",
                     direction="lower"),
        bench_metric("dcheck", "p99_on_s", round(on.p99, 4), "s"),
        bench_metric("dcheck", "p99_off_s", round(off.p99, 4), "s"),
        bench_metric("dcheck", "offline_check_s", round(check_s, 5), "s"),
    ]
    return bench_doc(
        "dcheck_overhead", cfg, metrics,
        repeats=repeats,
        checker_off={"p50_s": round(off.p50, 4),
                     "p99_s": round(off.p99, 4),
                     "wall_s": round(off.wall_time, 4)},
        checker_on={"p50_s": round(on.p50, 4),
                    "p99_s": round(on.p99, 4),
                    "wall_s": round(on.wall_time, 4),
                    "events": len(rec)},
        overhead={"p99_ratio": p99_ratio, "wall_ratio": wall_ratio},
        offline_check={"events": len(rec),
                       "check_s": round(check_s, 5),
                       "violations": 0},
    )


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default="BENCH_dcheck.json",
                    help="output JSON path (default: BENCH_dcheck.json)")
    ap.add_argument("--repeats", type=int, default=3)
    args = ap.parse_args(argv)
    doc = measure(repeats=args.repeats)
    with open(args.out, "w", encoding="utf-8") as fh:
        json.dump(doc, fh, indent=2)
        fh.write("\n")
    print(json.dumps(doc, indent=2))
    ratio = doc["overhead"]["p99_ratio"]
    print(f"# checker-on p99 is {ratio:.2f}x checker-off "
          f"({doc['checker_on']['events']} events recorded, offline check "
          f"{doc['offline_check']['check_s'] * 1e3:.1f} ms)")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
