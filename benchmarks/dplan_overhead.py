"""DPlan overhead + payoff: plan build time and plan-driven serving deltas.

Two questions, one JSON (``BENCH_dplan.json``) so later PRs can track
both:

1. **Analysis cost** — how long does :func:`repro.core.plan.build_plan`
   take per built-in workload (partition + liveness + slack DP + transfer
   matrix)?  The plan is built once per (workflow, placement) and reused
   across every serving instance, so this must be microseconds-to-
   milliseconds, never request-path work.
2. **Runtime payoff** — the serve_load SMOKE configuration run with the
   keep-alive heuristic (evict at instance completion, prewarm at
   precursor launch) vs plan-driven (evict at statically-last read,
   slack-timed boots).  Reported: peak resident DStore bytes, request-
   path cold starts, p99.  Best-of-``repeats`` per mode — thread noise
   on a shared runner dwarfs the effect otherwise.

The plan-driven run is also trace-recorded and replayed through
:class:`~repro.core.check.PlanConformance`, so the benchmark doubles as
an end-to-end conformance check on a real concurrent serving trace.

Run:  PYTHONPATH=src python -m benchmarks.dplan_overhead [--out FILE]
"""

import argparse
import json
import time

from repro.core.check import PlanConformance, TraceRecorder
from repro.core.obs import bench_doc, bench_metric
from repro.core.partition import partition_workflow
from repro.core.plan import build_plan
from repro.core.serve import DServe, poisson_arrivals
from repro.core.workloads import BENCHMARKS, serving_chain

SMOKE = dict(rate=8.0, n=10, stages=4, exec_time=0.03, cold_start=0.15)


def plan_build_times(repeats: int = 5):
    """Best-of-``repeats`` build_plan wall time per builtin workload."""
    out = {}
    nodes = ["node0", "node1"]
    for name, mk in sorted(BENCHMARKS.items()):
        wf = mk()
        placement = partition_workflow(wf, nodes)
        best = float("inf")
        for _ in range(repeats):
            t0 = time.perf_counter()
            plan = build_plan(wf, placement)
            best = min(best, time.perf_counter() - t0)
        out[name] = {"build_us": round(best * 1e6, 1),
                     "functions": len(plan.functions),
                     "keys": len(plan.keys),
                     "evictable": len(plan.eviction_reads)}
    return out


def _run_once(*, plan, tracer=None, rate, n, stages, exec_time, cold_start):
    wf = serving_chain(stages=stages, exec_time=exec_time,
                       cold_start=cold_start, payload=16 * 1024)
    srv = DServe(wf, n_nodes=2, pattern="dataflow", keepalive=10.0,
                 max_per_node=16, plan=plan, tracer=tracer)
    rep = srv.run(poisson_arrivals(rate, n, seed=7),
                  inputs={"request": b"req"})
    assert rep.failures == 0, "instances failed during benchmark"
    return rep, srv


def measure(cfg=SMOKE, repeats: int = 3):
    heur = min((_run_once(plan=False, **cfg)[0] for _ in range(repeats)),
               key=lambda r: r.wall_time)
    planned = min((_run_once(plan=True, **cfg)[0] for _ in range(repeats)),
                  key=lambda r: r.wall_time)

    # One traced plan-driven run, conformance-checked end to end.
    rec = TraceRecorder()
    traced, srv = _run_once(plan=True, tracer=rec, **cfg)
    violations = PlanConformance(srv.plan).check(
        rec.events(), instances=[s.instance for s in traced.stats])
    assert not violations, [str(v) for v in violations]

    def row(rep):
        return {"p50_s": round(rep.p50, 4), "p99_s": round(rep.p99, 4),
                "wall_s": round(rep.wall_time, 4),
                "cold_starts": rep.cold_starts,
                "prewarm_boots": rep.prewarm_boots,
                "container_seconds": round(rep.container_seconds, 3),
                "peak_resident_bytes": rep.peak_resident_bytes}

    builds = plan_build_times()
    delta = {
        "peak_resident_ratio": round(
            planned.peak_resident_bytes
            / max(heur.peak_resident_bytes, 1), 3),
        "p99_ratio": round(planned.p99 / max(heur.p99, 1e-9), 3),
        "cold_starts": planned.cold_starts - heur.cold_starts,
    }
    # peak_resident_ratio is the plan's headline win (deterministic byte
    # accounting, 9x headroom to the <1.0 assert) — gated with a loose
    # tolerance.  p99_ratio rides thread-scheduling noise, report-only.
    metrics = [
        bench_metric("dplan", "peak_resident_ratio",
                     delta["peak_resident_ratio"], "x",
                     direction="lower", tolerance=1.0),
        bench_metric("dplan", "p99_ratio", delta["p99_ratio"], "x"),
        bench_metric("dplan", "request_cold_starts",
                     planned.cold_starts, "boots"),
        bench_metric("dplan", "build_us_worst",
                     max(b["build_us"] for b in builds.values()), "us"),
    ]
    return bench_doc(
        "dplan_overhead", cfg, metrics,
        repeats=repeats,
        plan_build=builds,
        heuristic=row(heur),
        plan_driven=row(planned),
        delta=delta,
        conformance={"events": len(rec), "violations": 0},
    )


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default="BENCH_dplan.json",
                    help="output JSON path (default: BENCH_dplan.json)")
    ap.add_argument("--repeats", type=int, default=3)
    args = ap.parse_args(argv)
    doc = measure(repeats=args.repeats)
    with open(args.out, "w", encoding="utf-8") as fh:
        json.dump(doc, fh, indent=2)
        fh.write("\n")
    print(json.dumps(doc, indent=2))
    d = doc["delta"]
    assert d["peak_resident_ratio"] < 1.0, (
        "plan-driven eviction must bound resident bytes below the "
        f"keep-alive baseline (got {d['peak_resident_ratio']}x)")
    print(f"# plan-driven serving: {d['peak_resident_ratio']:.2f}x peak "
          f"resident bytes, {d['p99_ratio']:.2f}x p99, "
          f"{d['cold_starts']:+d} request-path cold starts vs heuristic")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
