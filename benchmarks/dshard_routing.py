"""DShard routing efficiency: hop-count histogram, tier traffic, tail cost.

One JSON (``BENCH_dshard.json``) answering the ISSUE 8 acceptance gate:

1. **Hop counts (threaded)** — DServe over a sharded DStore at ``--nodes``
   nodes on the serving workloads (Srv chain, SrvF scatter/gather): the
   per-store histogram of Get resolutions.  0 hops = local bytes; 1 hop =
   routed straight to the producing shard; 2 hops = stale-table misroute
   (a trace-checker violation).  The gate: **>= 95% of routed (cross-
   shard) Gets resolve in exactly 1 hop** — in practice 100%, because
   routing tables are synced from the coordinator, never guessed.
2. **Transport tiers (threaded)** — the same runs priced through
   :class:`~repro.core.router.TieredTransport`: bytes over ipc (same
   container), mem (same node) and net (cross-node — the only tier that
   pays bandwidth), plus the plain cross-node byte counter for
   comparability with the single-store baseline.
3. **Tail cost (simulated, deterministic)** — ``dflow-shard`` vs
   ``dflow`` p99 on the paper's builtin workloads at Fig. 9 operating
   point: sharding must be free or better (local routing removes the
   central directory round-trip).  Asserted per workload.

Run:  PYTHONPATH=src python -m benchmarks.dshard_routing \
          [--smoke] [--nodes N] [--out FILE]
"""

import argparse
import json

from repro.core import make_workflow, run_open_loop
from repro.core.obs import (MetricsRegistry, Tracer, bench_doc,
                            bench_metric, plan_attribution,
                            write_spans_jsonl)
from repro.core.router import TIER_IPC, TIER_MEM, TIER_NET, TieredTransport
from repro.core.serve import DServe, poisson_arrivals
from repro.core.workloads import serving_chain, serving_fanout

FULL = dict(rate=8.0, n=30, repeats=3, sim_invocations=5)
SMOKE = dict(rate=8.0, n=8, repeats=1, sim_invocations=3)

SIM_BENCHES = ["WC", "Gen", "Soy"]


def _serve_workloads():
    return {
        "Srv": lambda: serving_chain(stages=4, exec_time=0.03,
                                     cold_start=0.15, payload=16 * 1024),
        "SrvF": lambda: serving_fanout(workers=4, exec_time=0.03,
                                       cold_start=0.15, payload=16 * 1024),
    }


def _serve_once(mk_wf, *, n_nodes, sharded, rate, n):
    transport = TieredTransport() if sharded else None
    srv = DServe(mk_wf(), n_nodes=n_nodes, pattern="dataflow",
                 keepalive=10.0, max_per_node=16, transport=transport,
                 sharded=sharded)
    rep = srv.run(poisson_arrivals(rate, n, seed=7),
                  inputs={"request": b"req"})
    assert rep.failures == 0, "instances failed during benchmark"
    return rep, srv


def routed_1hop_fraction(hop_hist):
    routed = sum(v for h, v in hop_hist.items() if h >= 1)
    return 1.0 if routed == 0 else hop_hist.get(1, 0) / routed


def measure_serving(name, mk_wf, *, n_nodes, rate, n, repeats):
    """Best-of-``repeats`` sharded run vs single-store baseline, plus the
    routing/tier counters of the best sharded run."""
    shard_best = None
    for _ in range(repeats):
        rep, srv = _serve_once(mk_wf, n_nodes=n_nodes, sharded=True,
                               rate=rate, n=n)
        if shard_best is None or rep.wall_time < shard_best[0].wall_time:
            shard_best = (rep, srv)
    single = min((_serve_once(mk_wf, n_nodes=n_nodes, sharded=False,
                              rate=rate, n=n)[0] for _ in range(repeats)),
                 key=lambda r: r.wall_time)

    rep, srv = shard_best
    hops = {int(k): v for k, v in srv.store.hop_hist.items()}
    t = srv.engine.transport
    return {
        "nodes": n_nodes,
        "requests": n,
        "hop_hist": hops,
        "one_hop_fraction": round(routed_1hop_fraction(hops), 4),
        "tier_gets": dict(srv.store.tier_gets),
        "tier_bytes": dict(t.tier_bytes),
        "cross_node_bytes": t.bytes_moved,
        "cross_node_transfers": t.transfers,
        "table_refreshes": sum(tb.refreshes
                               for tb in srv.store.tables.values()),
        "coordinator_syncs": srv.store.coordinator.syncs,
        "p99_s": round(rep.p99, 4),
        "p99_single_store_s": round(single.p99, 4),
        "p99_ratio": round(rep.p99 / max(single.p99, 1e-9), 3),
        "peak_resident_bytes": rep.peak_resident_bytes,
        "peak_resident_per_node": dict(rep.peak_resident_per_node),
    }


def measure_sim(*, sim_invocations):
    """Deterministic Fig. 9-point p99: dflow-shard vs dflow per builtin."""
    out = {}
    for bench in SIM_BENCHES:
        wf = make_workflow(bench)
        shard = run_open_loop("dflow-shard", wf, rate_per_min=6,
                              n_invocations=sim_invocations).p99
        plain = run_open_loop("dflow", wf, rate_per_min=6,
                              n_invocations=sim_invocations).p99
        assert shard <= plain + 1e-6, (bench, shard, plain)
        out[bench] = {"p99_shard_s": round(shard, 3),
                      "p99_single_s": round(plain, 3),
                      "ratio": round(shard / max(plain, 1e-9), 3)}
    return out


def measure(*, n_nodes, cfg):
    serving = {name: measure_serving(name, mk, n_nodes=n_nodes,
                                     rate=cfg["rate"], n=cfg["n"],
                                     repeats=cfg["repeats"])
               for name, mk in sorted(_serve_workloads().items())}
    sim = measure_sim(sim_invocations=cfg["sim_invocations"])
    # Standardized rows.  Gated: 1-hop fraction (higher), 2-hop count
    # (lower — committed 0, so ANY misroute fails) and the deterministic
    # sim p99 ratios (lower).  Threaded p99 ratios are report-only.
    metrics = []
    for name, row in sorted(serving.items()):
        metrics += [
            bench_metric(name, "one_hop_fraction",
                         row["one_hop_fraction"], "frac",
                         direction="higher", tolerance=0.05),
            bench_metric(name, "two_hop_gets",
                         row["hop_hist"].get(2, 0), "gets",
                         direction="lower"),
            bench_metric(name, "p99_ratio_vs_single",
                         row["p99_ratio"], "x"),
            bench_metric(name, "cross_node_bytes",
                         row["cross_node_bytes"], "B"),
        ]
    for bench, row in sorted(sim.items()):
        metrics.append(bench_metric(f"sim/{bench}", "p99_shard_ratio",
                                    row["ratio"], "x", direction="lower"))
    return bench_doc("dshard_routing", {"nodes": n_nodes, **cfg}, metrics,
                     serving=serving, sim_p99=sim)


def traced_run(out: str, *, n_nodes, rate, n):
    """One sharded plan-driven Srv run with DScope spans attached —
    includes the cross-shard ``hop`` spans nested under their Gets.
    Separate from the timed runs so tracing never perturbs them."""
    spans, metrics = Tracer(), MetricsRegistry()
    srv = DServe(serving_chain(stages=4, exec_time=0.03, cold_start=0.15,
                               payload=16 * 1024),
                 n_nodes=n_nodes, pattern="dataflow", keepalive=10.0,
                 max_per_node=16, transport=TieredTransport(),
                 sharded=True, plan=True, spans=spans, metrics=metrics)
    rep = srv.run(poisson_arrivals(rate, n, seed=7),
                  inputs={"request": b"req"})
    assert rep.failures == 0, "traced run failed"
    hops = sum(1 for s in spans.finished() if s.kind == "hop")
    write_spans_jsonl(spans.finished(), out,
                      plan=plan_attribution(srv.plan),
                      meta={"bench": "dshard_routing", "nodes": n_nodes,
                            "rate": rate, "n": n, "hop_spans": hops})
    print(f"# wrote {len(spans.finished())} span(s) ({hops} hop(s)) to "
          f"{out} (inspect: python -m repro.obs summarize {out} --tree 1)")


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default="BENCH_dshard.json",
                    help="output JSON path (default: BENCH_dshard.json)")
    ap.add_argument("--nodes", type=int, default=4)
    ap.add_argument("--smoke", action="store_true",
                    help="small/fast configuration (CI)")
    ap.add_argument("--spans", metavar="FILE",
                    help="also run one sharded plan-driven pass with "
                    "DScope spans attached (hop spans included) and "
                    "write them to FILE")
    args = ap.parse_args(argv)
    cfg = SMOKE if args.smoke else FULL
    doc = measure(n_nodes=args.nodes, cfg=cfg)
    with open(args.out, "w", encoding="utf-8") as fh:
        json.dump(doc, fh, indent=2)
        fh.write("\n")
    print(json.dumps(doc, indent=2))

    for name, row in doc["serving"].items():
        frac = row["one_hop_fraction"]
        assert frac >= 0.95, (
            f"{name}: only {frac:.1%} of routed Gets resolved in 1 hop "
            "(stale-table misroutes or directory bounces on the hot path)")
        assert row["hop_hist"].get(2, 0) == 0, (name, row["hop_hist"])
        assert row["tier_bytes"][TIER_NET] == row["cross_node_bytes"]
        print(f"# {name}: {frac:.1%} of routed Gets at exactly 1 hop, "
              f"{row['cross_node_bytes']} cross-node B "
              f"(ipc {row['tier_bytes'][TIER_IPC]} / "
              f"mem {row['tier_bytes'][TIER_MEM]} / "
              f"net {row['tier_bytes'][TIER_NET]}), "
              f"p99 {row['p99_ratio']:.2f}x single-store")
    worst = max(r["ratio"] for r in doc["sim_p99"].values())
    print(f"# sim p99 (dflow-shard vs dflow, Fig. 9 point): worst ratio "
          f"{worst:.3f} over {', '.join(SIM_BENCHES)} — sharding never "
          "costs tail latency")
    if args.spans:
        traced_run(args.spans, n_nodes=args.nodes, rate=cfg["rate"],
                   n=cfg["n"])
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
