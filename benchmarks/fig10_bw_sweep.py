"""Paper Fig. 10: p99 under varying bandwidth × invocation rate (Gen, Soy).

Derived column notes timeouts; the paper's claims: under low bandwidth all
baselines time out at high rates while DFlow survives; bandwidth-
utilisation improvement 2-4x vs CFlow, 1.5-3x vs the hybrid systems
(measured here as achieved transfer rate while the network is busy).
"""

import dataclasses

from repro.core import SYSTEMS, SimConfig, make_workflow, run_open_loop

BWS = (25e6, 50e6, 100e6)
RATES = (4.0, 8.0)
N = 6


def _edge_bytes(wf):
    return sum(wf.functions[p].size_of(k)
               for f in wf.functions.values() for k in f.inputs
               for p in [wf.producer.get(k)] if p and p != f.name)


def run():
    rows = []
    for bench in ("Gen", "Soy"):
        wf = make_workflow(bench)
        ebytes = _edge_bytes(wf)
        for bw in BWS:
            for rate in RATES:
                cfg = SimConfig(bandwidth=bw)
                goodput = {}
                for system in ("cflow", "faasflow", "faasflowredis",
                               "knix", "dflow"):
                    r = run_open_loop(system, wf, rate_per_min=rate,
                                      n_invocations=N, cfg=cfg)
                    done = len(r.latencies) - r.timeouts
                    # useful application bytes delivered per second — the
                    # paper's bandwidth-utilisation notion under load.
                    goodput[system] = done * ebytes / max(r.makespan, 1e-9)
                    rows.append((
                        f"fig10/{bench}/bw{int(bw / 1e6)}/rate{int(rate)}"
                        f"/{system}",
                        r.p99 * 1e6, f"timeouts={r.timeouts}"))
                rows.append((
                    f"fig10/{bench}/bw{int(bw / 1e6)}/rate{int(rate)}"
                    "/goodput_dflow_over_cflow", 0.0,
                    f"{goodput['dflow'] / max(goodput['cflow'], 1e-9):.2f}x"))
                worst = min(v for s, v in goodput.items() if s != "dflow")
                rows.append((
                    f"fig10/{bench}/bw{int(bw / 1e6)}/rate{int(rate)}"
                    "/goodput_dflow_over_worst_baseline", 0.0,
                    f"{goodput['dflow'] / max(worst, 1e-9):.2f}x"))
    return rows
