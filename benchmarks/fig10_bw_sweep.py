"""Paper Fig. 10: p99 under varying bandwidth × invocation rate (Gen, Soy).

Derived column notes timeouts; the paper's claims: under low bandwidth all
baselines time out at high rates while DFlow survives; bandwidth-
utilisation improvement 2-4x vs CFlow, 1.5-3x vs the hybrid systems
(measured here as achieved transfer rate while the network is busy).

Beyond-paper: the sweep also runs ``dflow-stream`` (DStream chunked
pipelining) and the chunk-aware large-output workloads (WC-L, Gen-L),
emitting ``p99_dflow_over_stream`` speedup rows, plus a real threaded-
engine wall-time comparison of streaming vs monolithic exchange under a
constrained Transport.
"""

import time as _time

from repro.core import SimConfig, make_workflow, run_open_loop
from repro.core.dag import FunctionSpec, Workflow
from repro.core.dscheduler import DFlowEngine
from repro.core.dstore import Transport

BWS = (25e6, 50e6, 100e6)
RATES = (4.0, 8.0)
N = 6
SWEEP_SYSTEMS = ("cflow", "faasflow", "faasflowredis", "knix",
                 "dflow", "dflow-stream")


def _edge_bytes(wf):
    return sum(wf.functions[p].size_of(k)
               for f in wf.functions.values() for k in f.inputs
               for p in [wf.producer.get(k)] if p and p != f.name)


def _real_engine_rows():
    """Threaded-engine wall time: chunked streaming vs monolithic exchange.

    A slow producer emits 4 MB incrementally; the consumer processes per
    chunk.  With DStream the consumer's pulls and processing overlap the
    producer's emission; monolithically everything serialises.  The
    Transport bandwidth (32 MB/s) makes any cross-node pull visible too.
    """
    chunk = 256 * 1024
    n_chunks = 16
    produce_gap = 0.012
    consume_gap = 0.004

    def producer_stream():
        def gen():
            for i in range(n_chunks):
                _time.sleep(produce_gap)
                yield bytes([i & 0xFF]) * chunk
        return {"blob": gen()}

    def producer_mono():
        parts = []
        for i in range(n_chunks):
            _time.sleep(produce_gap)
            parts.append(bytes([i & 0xFF]) * chunk)
        return {"blob": b"".join(parts)}

    def consumer_stream(blob):
        total = 0
        for c in blob:
            _time.sleep(consume_gap)
            total += len(c)
        return {"digest": total}

    def consumer_mono(blob):
        _time.sleep(consume_gap * n_chunks)
        return {"digest": len(blob)}

    size = {"blob": chunk * n_chunks}
    wf_stream = Workflow("rt-stream", [
        FunctionSpec("prod", (), ("blob",), fn=producer_stream,
                     exec_time=produce_gap * n_chunks, output_sizes=size,
                     stream_outputs=("blob",), chunk_size=chunk),
        FunctionSpec("cons", ("blob",), ("digest",), fn=consumer_stream,
                     exec_time=consume_gap * n_chunks,
                     stream_inputs=("blob",)),
    ])
    wf_mono = Workflow("rt-mono", [
        FunctionSpec("prod", (), ("blob",), fn=producer_mono,
                     exec_time=produce_gap * n_chunks, output_sizes=size),
        FunctionSpec("cons", ("blob",), ("digest",), fn=consumer_mono,
                     exec_time=consume_gap * n_chunks),
    ])
    walls = {}
    for label, wf in (("stream", wf_stream), ("mono", wf_mono)):
        # Warm-up run first: lazy imports (numpy in DStore._sizeof) and
        # thread-pool spin-up would otherwise land in the first timing.
        for attempt in range(2):
            eng = DFlowEngine(n_nodes=2, transport=Transport(bandwidth=32e6))
            rep = eng.run(wf)
            assert rep.outputs["digest"] == chunk * n_chunks
        walls[label] = rep.wall_time
    return [
        ("fig10/real_engine/mono_wall", walls["mono"] * 1e6, ""),
        ("fig10/real_engine/stream_wall", walls["stream"] * 1e6, ""),
        ("fig10/real_engine/stream_speedup", 0.0,
         f"{walls['mono'] / walls['stream']:.2f}x"),
    ]


def run():
    rows = []
    for bench in ("Gen", "Soy", "WC-L", "Gen-L"):
        wf = make_workflow(bench)
        ebytes = _edge_bytes(wf)
        for bw in BWS:
            for rate in RATES:
                cfg = SimConfig(bandwidth=bw)
                goodput = {}
                p99 = {}
                for system in SWEEP_SYSTEMS:
                    r = run_open_loop(system, wf, rate_per_min=rate,
                                      n_invocations=N, cfg=cfg)
                    done = len(r.latencies) - r.timeouts
                    # useful application bytes delivered per second — the
                    # paper's bandwidth-utilisation notion under load.
                    goodput[system] = done * ebytes / max(r.makespan, 1e-9)
                    p99[system] = r.p99
                    rows.append((
                        f"fig10/{bench}/bw{int(bw / 1e6)}/rate{int(rate)}"
                        f"/{system}",
                        r.p99 * 1e6, f"timeouts={r.timeouts}"))
                tag = f"fig10/{bench}/bw{int(bw / 1e6)}/rate{int(rate)}"
                rows.append((
                    f"{tag}/goodput_dflow_over_cflow", 0.0,
                    f"{goodput['dflow'] / max(goodput['cflow'], 1e-9):.2f}x"))
                worst = min(v for s, v in goodput.items()
                            if s not in ("dflow", "dflow-stream"))
                rows.append((
                    f"{tag}/goodput_dflow_over_worst_baseline", 0.0,
                    f"{goodput['dflow'] / max(worst, 1e-9):.2f}x"))
                # DStream vs monolithic DFlow: >1 means streaming is faster.
                rows.append((
                    f"{tag}/p99_dflow_over_stream", 0.0,
                    f"{p99['dflow'] / max(p99['dflow-stream'], 1e-9):.2f}x"))
    rows.extend(_real_engine_rows())
    return rows
