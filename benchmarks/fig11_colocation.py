"""Paper Fig. 11: co-location interference (solo-run vs co-run, closed
loop).  Paper: corun-CFlow degrades ~40%, corun-FaaSFlow ~12%, others ~2%;
DFlow keeps the best latency in both modes."""

from repro.core import SYSTEMS, make_workflow, run_closed_loop

BENCHES = ("WC", "FP", "Gen")
N_PER_CLIENT = 4


def run():
    rows = []
    for system in SYSTEMS:
        solo = {}
        for b in BENCHES:
            r = run_closed_loop(system, [make_workflow(b)],
                                n_per_client=N_PER_CLIENT)[0]
            solo[b] = r.mean
            rows.append((f"fig11/solo/{b}/{system}", r.mean * 1e6, ""))
        co = run_closed_loop(system, [make_workflow(b) for b in BENCHES],
                             n_per_client=N_PER_CLIENT)
        degr = []
        for b, r in zip(BENCHES, co):
            rows.append((f"fig11/corun/{b}/{system}", r.mean * 1e6, ""))
            degr.append(r.mean / max(solo[b], 1e-9) - 1.0)
        rows.append((f"fig11/degradation/{system}", 0.0,
                     f"{100 * sum(degr) / len(degr):.1f}%"))
    return rows
