"""Paper Fig. 12: cold-start latency (first run minus second run) for
CFlow / FaaSFlow / DFlow on the four scientific workflows.
Paper: DFlow ≈5.6x better than CFlow, ≈1.1x better than FaaSFlow."""

from repro.core import cold_start_latency, make_workflow

BENCHES = ("Cyc", "Epi", "Gen", "Soy")


def run():
    rows = []
    ratios_cf, ratios_ff = [], []
    for bench in BENCHES:
        wf = make_workflow(bench)
        vals = {s: cold_start_latency(s, wf)
                for s in ("cflow", "faasflow", "dflow")}
        for s, v in vals.items():
            rows.append((f"fig12/{bench}/{s}", v * 1e6, ""))
        ratios_cf.append(vals["cflow"] / max(vals["dflow"], 1e-9))
        ratios_ff.append(vals["faasflow"] / max(vals["dflow"], 1e-9))
    rows.append(("fig12/avg_ratio_cflow_over_dflow", 0.0,
                 f"{sum(ratios_cf) / len(ratios_cf):.2f}x (paper 5.6x)"))
    rows.append(("fig12/avg_ratio_faasflow_over_dflow", 0.0,
                 f"{sum(ratios_ff) / len(ratios_ff):.2f}x (paper 1.1x)"))
    return rows
