"""Paper Fig. 12: cold-start latency (first run minus second run) for
CFlow / FaaSFlow / DFlow on the four scientific workflows.
Paper: DFlow ≈5.6x better than CFlow, ≈1.1x better than FaaSFlow.

Serving-layer extension: the same §3.2 prewarm rule (a function's
container boots when its *precursor launches*) measured as request-path
cold-start **counts** on the real threaded DServe layer — prewarm on vs
off over the same Poisson arrival trace.  The container lifecycle behind
both halves is one implementation (repro.core.serve.ContainerPool)."""

from repro.core import cold_start_latency, make_workflow
from repro.core.serve import DServe, poisson_arrivals
from repro.core.workloads import serving_chain

BENCHES = ("Cyc", "Epi", "Gen", "Soy")


def serve_prewarm_comparison():
    """Request-path cold-start counts, prewarm on vs off (threaded)."""
    out = {}
    for prewarm in (True, False):
        wf = serving_chain(stages=4, exec_time=0.02, cold_start=0.1,
                           payload=16 * 1024)
        srv = DServe(wf, n_nodes=2, pattern="dataflow", prewarm=prewarm,
                     keepalive=10.0, max_per_node=16)
        out[prewarm] = srv.run(poisson_arrivals(6.0, 8, seed=1),
                               inputs={"request": b"x"})
    return out


def run():
    rows = []
    ratios_cf, ratios_ff = [], []
    for bench in BENCHES:
        wf = make_workflow(bench)
        vals = {s: cold_start_latency(s, wf)
                for s in ("cflow", "faasflow", "dflow")}
        for s, v in vals.items():
            rows.append((f"fig12/{bench}/{s}", v * 1e6, ""))
        ratios_cf.append(vals["cflow"] / max(vals["dflow"], 1e-9))
        ratios_ff.append(vals["faasflow"] / max(vals["dflow"], 1e-9))
    rows.append(("fig12/avg_ratio_cflow_over_dflow", 0.0,
                 f"{sum(ratios_cf) / len(ratios_cf):.2f}x (paper 5.6x)"))
    rows.append(("fig12/avg_ratio_faasflow_over_dflow", 0.0,
                 f"{sum(ratios_ff) / len(ratios_ff):.2f}x (paper 1.1x)"))

    # Serving layer: §3.2 prewarm trigger, cold-start counts on/off.
    reps = serve_prewarm_comparison()
    for prewarm, rep in reps.items():
        tag = "on" if prewarm else "off"
        rows.append((f"fig12/serve/prewarm_{tag}/cold_starts",
                     float(rep.cold_starts),
                     f"p99={rep.p99:.3f}s prewarm_hits={rep.prewarm_hits}"))
    on, off = reps[True], reps[False]
    rows.append(("fig12/serve/coldstart_drop", 0.0,
                 f"{off.cold_starts} -> {on.cold_starts} with prewarm "
                 f"({off.cold_starts - on.cold_starts} fewer)"))
    return rows
