"""Paper Fig. 13 / §5.5 invocation-pattern study: FaaSFlow vs
FaaSFlow+DStore vs DFlow on Gen at 100 MB/s over increasing request rates.

Paper: DStore alone gives FaaSFlow ≈60% speedup; at low rates DFlow is only
~5% ahead of FaaSFlow+DStore, but at high rates the controlflow systems
time out while DFlow sustains up to 6x the throughput."""

import dataclasses

from repro.core import SimConfig, make_workflow, run_open_loop

RATES = (5.0, 15.0, 30.0, 60.0)


def run():
    rows = []
    wf = make_workflow("Gen")
    cfg = SimConfig(bandwidth=100e6)
    low_rate_gap = None
    for rate in RATES:
        p99 = {}
        for system in ("faasflow", "faasflow+dstore", "dflow"):
            r = run_open_loop(system, wf, rate_per_min=rate,
                              n_invocations=8, cfg=cfg)
            p99[system] = r.p99
            rows.append((f"fig13/rate{int(rate)}/{system}", r.p99 * 1e6,
                         f"timeouts={r.timeouts}"))
        if rate == RATES[0]:
            low_rate_gap = p99["faasflow+dstore"] / p99["dflow"] - 1
            rows.append(("fig13/low_rate_dflow_gain_vs_fd", 0.0,
                         f"{100 * low_rate_gap:.1f}% (paper ~5%)"))
        rows.append((f"fig13/rate{int(rate)}/dstore_speedup_vs_faasflow",
                     0.0,
                     f"{p99['faasflow'] / max(p99['faasflow+dstore'], 1e-9):.2f}x"))
    return rows
