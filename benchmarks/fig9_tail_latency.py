"""Paper Fig. 9: 99%-ile latency, all benchmarks × all systems.

Setup per §5.2: 50 MB/s bandwidth, 6 invocations/min, open loop, 60 s
timeout recorded as 60 s.  Derived column: DFlow's p99 reduction vs the
baseline (paper: ~52-60% vs CFlow, 28-40% vs FaaSFlow, 20-25% vs
FaaSFlowRedis, 36-40% vs KNIX; and only CFlow-Cyc times out).
"""

from repro.core import SYSTEMS, make_workflow, run_open_loop

N_INVOCATIONS = 8
RATE = 6.0


def run():
    rows = []
    p99 = {}
    for bench in ("WC", "FP", "Cyc", "Epi", "Gen", "Soy"):
        wf = make_workflow(bench)
        for system in SYSTEMS:
            r = run_open_loop(system, wf, rate_per_min=RATE,
                              n_invocations=N_INVOCATIONS)
            p99[(bench, system)] = r.p99
            rows.append((f"fig9/{bench}/{system}", r.p99 * 1e6,
                         f"timeouts={r.timeouts}"))
    # average reductions vs DFlow
    for base in SYSTEMS:
        if base == "dflow":
            continue
        reds = [1 - p99[(b, "dflow")] / p99[(b, base)]
                for b in ("WC", "FP", "Cyc", "Epi", "Gen", "Soy")]
        rows.append((f"fig9/avg_reduction_vs_{base}",
                     0.0, f"{100 * sum(reds) / len(reds):.1f}%"))
    return rows
