"""Kernel micro-benchmarks (CPU: jnp reference path wall time + analytic
FLOPs; the Pallas kernels themselves are TPU-targeted and CPU interpret
timings would be meaningless)."""

import time

import jax
import jax.numpy as jnp

from repro.kernels.flash_attention import flash_attention
from repro.kernels.ssd import ssd


def _time(fn, *args, iters=3, **kw):
    fn(*args, **kw).block_until_ready()
    t0 = time.perf_counter()
    for _ in range(iters):
        out = fn(*args, **kw)
    out.block_until_ready()
    return (time.perf_counter() - t0) / iters


def run():
    rows = []
    # flash attention ref path
    B, S, H, Hk, D = 1, 1024, 8, 2, 64
    ks = jax.random.split(jax.random.key(0), 3)
    q = jax.random.normal(ks[0], (B, S, H, D), jnp.float32)
    k = jax.random.normal(ks[1], (B, S, Hk, D), jnp.float32)
    v = jax.random.normal(ks[2], (B, S, Hk, D), jnp.float32)
    t = _time(flash_attention, q, k, v, causal=True, use_kernel=False)
    flops = 4 * B * H * S * S * D / 2
    rows.append(("kernels/flash_attention_ref/B1xS1024xH8xD64",
                 t * 1e6, f"{flops / t / 1e9:.1f}GFLOP/s_cpu_ref"))

    # ssd ref path
    B, S, Hh, P, N = 1, 2048, 8, 64, 64
    ks = jax.random.split(jax.random.key(1), 5)
    x = jax.random.normal(ks[0], (B, S, Hh, P), jnp.float32)
    dt = jax.nn.softplus(jax.random.normal(ks[1], (B, S, Hh)))
    A = -jnp.exp(jax.random.normal(ks[2], (Hh,)))
    Bm = jax.random.normal(ks[3], (B, S, N), jnp.float32)
    Cm = jax.random.normal(ks[4], (B, S, N), jnp.float32)
    t = _time(ssd, x, dt, A, Bm, Cm, chunk=128, use_kernel=False)
    rows.append(("kernels/ssd_ref/B1xS2048xH8xP64xN64", t * 1e6,
                 f"chunked_scan"))
    return rows
