"""DScope overhead: serve_load smoke with full observability on vs off.

DScope's hooks follow the DCheck recorder discipline: one ``is None``
test per instrumentation point when detached.  The *off* arm is the
production default — DServe's internal :class:`MetricsRegistry` with
pull-only collectors (scraped once per run, zero hot-path work).  The
*on* arm attaches everything at once: an explicit registry (arming the
push histograms on the Get/stream/latency paths) plus a
:class:`Tracer` recording the full request → invoke → acquire →
Get/Put span tree.

The acceptance gate (asserted here AND standardized into
``BENCH_obs.json`` for ``bench_compare``): **obs-on p99 <= 1.05x
obs-off p99**.  Both arms are best-of-``repeats`` — thread-scheduling
noise on a shared runner dwarfs the effect otherwise.

``--trace-out FILE`` additionally exports the on-arm's span tree as
Chrome ``trace_event`` JSON (the CI perfetto artifact).

Run:  PYTHONPATH=src python -m benchmarks.obs_overhead \
          [--out FILE] [--trace-out FILE]
"""

import argparse
import json

from repro.core.obs import (MetricsRegistry, Tracer, bench_doc,
                            bench_metric, to_chrome_trace)
from repro.core.serve import DServe, poisson_arrivals
from repro.core.workloads import serving_chain

SMOKE = dict(rate=8.0, n=10, stages=4, exec_time=0.03, cold_start=0.15)

P99_GATE = 1.05


def _run_once(*, metrics=None, spans=None, rate, n, stages, exec_time,
              cold_start):
    wf = serving_chain(stages=stages, exec_time=exec_time,
                       cold_start=cold_start, payload=16 * 1024)
    srv = DServe(wf, n_nodes=2, pattern="dataflow", keepalive=10.0,
                 max_per_node=16, metrics=metrics, spans=spans)
    rep = srv.run(poisson_arrivals(rate, n, seed=7),
                  inputs={"request": b"req"})
    assert rep.failures == 0, "instances failed during benchmark"
    return rep, srv


def measure(cfg=SMOKE, repeats: int = 3):
    off = min((_run_once(**cfg)[0] for _ in range(repeats)),
              key=lambda r: r.wall_time)

    runs = []

    def instrumented():
        reg, tr = MetricsRegistry(), Tracer()
        rep, _ = _run_once(metrics=reg, spans=tr, **cfg)
        runs.append((rep, reg, tr))
        return rep

    on = min((instrumented() for _ in range(repeats)),
             key=lambda r: r.wall_time)
    rep, reg, tr = next(r for r in runs if r[0] is on)
    dump = reg.collect()
    spans = tr.finished()
    n_hist = sum(h["count"] for h in dump["histograms"].values())

    p99_ratio = round(on.p99 / max(off.p99, 1e-9), 3)
    wall_ratio = round(on.wall_time / max(off.wall_time, 1e-9), 3)
    metrics = [
        bench_metric("dscope", "p99_ratio", p99_ratio, "x",
                     direction="lower", tolerance=P99_GATE - 1.0),
        bench_metric("dscope", "wall_ratio", wall_ratio, "x",
                     direction="lower"),
        bench_metric("dscope", "p99_on_s", round(on.p99, 4), "s"),
        bench_metric("dscope", "p99_off_s", round(off.p99, 4), "s"),
        bench_metric("dscope", "spans", len(spans), "spans"),
    ]
    return bench_doc(
        "obs_overhead", cfg, metrics,
        repeats=repeats,
        obs_off={"p50_s": round(off.p50, 4), "p99_s": round(off.p99, 4),
                 "wall_s": round(off.wall_time, 4)},
        obs_on={"p50_s": round(on.p50, 4), "p99_s": round(on.p99, 4),
                "wall_s": round(on.wall_time, 4),
                "spans": len(spans),
                "histogram_observations": n_hist,
                "registry_series": (len(dump["counters"])
                                    + len(dump["gauges"])
                                    + len(dump["histograms"]))},
        overhead={"p99_ratio": p99_ratio, "wall_ratio": wall_ratio},
    ), spans


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default="BENCH_obs.json",
                    help="output JSON path (default: BENCH_obs.json)")
    ap.add_argument("--trace-out", metavar="FILE",
                    help="export the instrumented arm's span tree as "
                    "Chrome trace_event JSON (perfetto)")
    ap.add_argument("--repeats", type=int, default=3)
    args = ap.parse_args(argv)
    doc, spans = measure(repeats=args.repeats)
    with open(args.out, "w", encoding="utf-8") as fh:
        json.dump(doc, fh, indent=2)
        fh.write("\n")
    print(json.dumps(doc, indent=2))
    if args.trace_out:
        trace = to_chrome_trace(spans)
        with open(args.trace_out, "w", encoding="utf-8") as fh:
            json.dump(trace, fh)
            fh.write("\n")
        print(f"# wrote {len(trace['traceEvents'])} trace event(s) to "
              f"{args.trace_out}")
    ratio = doc["overhead"]["p99_ratio"]
    assert ratio <= P99_GATE, (
        f"full observability (registry + spans) cost {ratio:.3f}x p99 — "
        f"gate is {P99_GATE}x; an instrumentation point is doing hot-path "
        "work it shouldn't")
    print(f"# obs-on p99 is {ratio:.2f}x obs-off (gate {P99_GATE}x): "
          f"{doc['obs_on']['spans']} spans, "
          f"{doc['obs_on']['histogram_observations']} histogram "
          f"observations recorded")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
