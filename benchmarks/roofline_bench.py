"""Roofline summary rows from the dry-run records (§Roofline deliverable).

Reads results/dryrun/*.json if present; derived column reports the
dominant term and the useful/bound roofline fraction."""

import pathlib


def run():
    rows = []
    try:
        from repro.analysis.roofline import load_records
    except Exception:
        return [("roofline/unavailable", 0.0, "import failed")]
    outdir = pathlib.Path("results/dryrun")
    if not outdir.exists():
        return [("roofline/no_dryrun_results", 0.0,
                 "run: python -m repro.launch.dryrun")]
    recs = load_records(outdir)
    for r in recs:
        if r["mesh"] != "single":
            continue
        rows.append((
            f"roofline/{r['arch']}/{r['shape']}",
            r["bound_s"] * 1e6,
            f"dom={r['dominant']},frac={r['roofline_fraction']:.2f}"))
    return rows
