# One function per paper table/figure. Prints ``name,us_per_call,derived``
# CSV rows (us_per_call = simulated latency per invocation in microseconds
# for the workflow benchmarks, wall time for kernel micro-benchmarks).
"""Benchmark harness entry point: ``python -m benchmarks.run [--only X]``."""

import argparse
import sys
import time

MODULES = [
    ("fig9", "benchmarks.fig9_tail_latency"),
    ("fig10", "benchmarks.fig10_bw_sweep"),
    ("fig11", "benchmarks.fig11_colocation"),
    ("fig12", "benchmarks.fig12_coldstart"),
    ("fig13", "benchmarks.fig13_invocation"),
    ("serve", "benchmarks.serve_load"),
    ("kernels", "benchmarks.kernels_bench"),
    ("roofline", "benchmarks.roofline_bench"),
]


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default=None,
                    help="comma-separated subset: fig9,fig10,...")
    args = ap.parse_args(argv)
    only = set(args.only.split(",")) if args.only else None

    print("name,us_per_call,derived")
    failures = 0
    for key, modname in MODULES:
        if only and key not in only:
            continue
        t0 = time.time()
        try:
            mod = __import__(modname, fromlist=["run"])
            for name, us, derived in mod.run():
                print(f"{name},{us:.1f},{derived}", flush=True)
            print(f"# {key} done in {time.time() - t0:.1f}s",
                  file=sys.stderr)
        except Exception as e:   # noqa: BLE001 - keep the harness going
            failures += 1
            print(f"{key}/ERROR,0,{type(e).__name__}:{e}", flush=True)
    return 1 if failures else 0


if __name__ == "__main__":
    raise SystemExit(main())
