"""DScale autoscaling benchmark: tail latency vs container-seconds.

Drives the threaded DServe engine with a bursty open-loop trace
(``repro.core.scale.bursty_arrivals``) and compares four arms:

* ``controlflow``        — sequential-trigger baseline, keep-alive pools.
* ``dflow``              — dataflow + §3.2 prewarm, keep-alive pools only
  (the fixed-pool keep-alive baseline: demand-grown, TTL-reclaimed).
* ``dflow-scale``        — dataflow + the DScale rate-estimating pool
  autoscaler (unbudgeted prewarm).
* ``dflow-scale-budget`` — autoscaler + container-second prewarm budget
  and bounded admission (the full DScale configuration).

The keep-alive TTL is deliberately shorter than the inter-burst lull, so
the fixed-pool baseline re-pays its cold-start pileup at every burst and
idles a demand-sized pool for a full TTL afterwards.  The autoscaler
instead pins a small rate-derived target per pool (its floor outranks
TTL), so bursts after the first hit warm containers while lulls hold far
fewer container-seconds.

Emits a gated ``dflow-bench/v1`` doc (``BENCH_scale.json``, checked by
``benchmarks/bench_compare.py``):

* ``p99_ratio`` — budgeted-autoscaled p99 / fixed-pool p99 (lower).
* ``cs_ratio``  — container-seconds, same arms (lower).
* ``shed``      — requests shed by admission below the limit (0).

Run:
    PYTHONPATH=src python -m benchmarks.serve_autoscale --smoke
    PYTHONPATH=src python -m benchmarks.serve_autoscale --out BENCH_scale.json
"""

from __future__ import annotations

import argparse
import json

from repro.core.obs import bench_doc, bench_metric
from repro.core.scale import AutoscalerConfig, PrewarmBudget, bursty_arrivals
from repro.core.serve import DServe
from repro.core.workloads import serving_chain

ARMS = ("controlflow", "dflow", "dflow-scale", "dflow-scale-budget")
BASELINE_ARM = "dflow"                 # fixed-pool keep-alive
SCALED_ARM = "dflow-scale-budget"      # autoscaled + budgeted

SMOKE = dict(
    n=90, warm_n=12, stages=4, exec_time=0.08, cold_start=0.25,
    payload=16 * 1024,
    base_rate=2.0, burst_rate=30.0, burst_every=2.0, burst_len=0.5, seed=7,
    keepalive=1.2, max_per_node=12, max_inflight=32,
    interval=0.05, window=2.0, headroom=8.0, max_pool=12,
    scale_down_delay=6.0,
    budget_s=8.0, budget_refill=4.0,
    # The gate arms; smoke skips the ungated ones for speed.
    arms=(BASELINE_ARM, SCALED_ARM),
)

FULL = dict(SMOKE, arms=ARMS, burst_rates=(15.0, 30.0, 45.0), sweep_n=42)


def _trace(cfg: dict, n: int) -> list[float]:
    return bursty_arrivals(
        n, base_rate=cfg["base_rate"], burst_rate=cfg["burst_rate"],
        burst_every=cfg["burst_every"], burst_len=cfg["burst_len"],
        seed=cfg["seed"])


def _serve(arm: str, cfg: dict) -> DServe:
    wf = serving_chain(cfg["stages"], exec_time=cfg["exec_time"],
                       cold_start=cfg["cold_start"],
                       payload=cfg["payload"])
    kw: dict = dict(n_nodes=2, keepalive=cfg["keepalive"],
                    max_per_node=cfg["max_per_node"])
    if arm == "controlflow":
        kw["pattern"] = "controlflow"
    if arm.startswith("dflow-scale"):
        kw["autoscale"] = AutoscalerConfig(
            interval=cfg["interval"], window=cfg["window"],
            headroom=cfg["headroom"], max_pool=cfg["max_pool"],
            scale_down_delay=cfg["scale_down_delay"])
        kw["max_inflight"] = cfg["max_inflight"]
    if arm == SCALED_ARM:
        kw["prewarm_budget"] = PrewarmBudget(
            cfg["budget_s"], refill_per_s=cfg["budget_refill"])
    return DServe(wf, **kw)


def run_arm(arm: str, cfg: dict, *, n: int | None = None) -> dict:
    """One measured run of ``arm``: a warmup burst brings pools (and, for
    the scaled arms, autoscaler targets) to steady state, then the bursty
    trace is served and the per-run report row returned."""
    srv = _serve(arm, cfg)
    rate = cfg["burst_rate"]
    warmup = [i / rate for i in range(cfg["warm_n"])]
    srv.run(warmup, inputs={"request": b"warm"})
    rep = srv.run(_trace(cfg, n or cfg["n"]), inputs={"request": b"req"})
    row = rep.row()
    row["arm"] = arm
    row["decisions"] = (len(srv.autoscaler.decisions)
                       if srv.autoscaler is not None else 0)
    row["p99_s"] = rep.p99
    row["container_seconds"] = rep.container_seconds
    srv.containers.shutdown()
    return row


def _best(rows: list[dict]) -> dict:
    """Best-of-repeats: minimum p99 and minimum container-seconds over
    the repeats (wall-clock jitter only ever inflates both)."""
    best = dict(min(rows, key=lambda r: r["p99_s"]))
    best["p99_s"] = min(r["p99_s"] for r in rows)
    best["container_seconds"] = min(r["container_seconds"] for r in rows)
    best["shed"] = max(r["shed"] for r in rows)
    return best


def measure(config: dict = SMOKE, repeats: int = 2) -> dict:
    """Run the gate arms best-of-``repeats`` (plus, when the config
    carries ``burst_rates``, a one-shot rising-RPS sweep over every arm)
    and emit the gated ``dflow-bench/v1`` document."""
    arms: dict[str, dict] = {}
    for arm in config.get("arms", ARMS):
        rows = [run_arm(arm, config) for _ in range(repeats)]
        arms[arm] = _best(rows)

    base, scaled = arms[BASELINE_ARM], arms[SCALED_ARM]
    metrics = [
        bench_metric("dscale", "p99_ratio",
                     scaled["p99_s"] / base["p99_s"], "x",
                     direction="lower", tolerance=0.25),
        bench_metric("dscale", "cs_ratio",
                     scaled["container_seconds"]
                     / base["container_seconds"], "x",
                     direction="lower", tolerance=0.20),
        bench_metric("dscale", "shed", float(scaled["shed"]), "requests",
                     direction="lower", tolerance=0.0),
        bench_metric("dscale", "p99_scaled", scaled["p99_s"], "s"),
        bench_metric("dscale", "p99_fixed", base["p99_s"], "s"),
        bench_metric("dscale", "container_seconds_scaled",
                     scaled["container_seconds"], "s"),
        bench_metric("dscale", "container_seconds_fixed",
                     base["container_seconds"], "s"),
    ]

    sweep: list[dict] = []
    for rate in config.get("burst_rates", ()):
        for arm in ARMS:
            row = run_arm(arm, dict(config, burst_rate=rate),
                          n=config.get("sweep_n", config["n"]))
            row["burst_rate"] = rate
            sweep.append(row)

    return bench_doc("serve_autoscale", config, metrics, repeats=repeats,
                     arms=arms, sweep=sweep)


def _print_rows(rows: list[dict]) -> None:
    cols = ("arm", "burst_rate", "p99_s", "p95_s", "container_seconds",
            "cold_starts", "prewarm_boots", "max_concurrency", "queued",
            "shed", "decisions")
    print(",".join(cols))
    for r in rows:
        print(",".join(str(round(r[c], 4) if isinstance(r[c], float)
                           else r[c]) if c in r else "-" for c in cols))


def main(argv: list[str] | None = None) -> int:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--smoke", action="store_true",
                    help="quick gated run: baseline vs scaled+budgeted")
    ap.add_argument("--repeats", type=int, default=2)
    ap.add_argument("--out", default=None,
                    help="write the dflow-bench/v1 doc to this JSON file")
    args = ap.parse_args(argv)

    cfg = dict(SMOKE if args.smoke else FULL)
    doc = measure(cfg, repeats=args.repeats)

    rows = [dict(r, burst_rate=cfg["burst_rate"])
            for r in doc["arms"].values()]
    _print_rows(rows + doc["sweep"])

    base = doc["arms"][BASELINE_ARM]
    scaled = doc["arms"][SCALED_ARM]
    print(f"\np99: scaled {scaled['p99_s']:.3f}s vs fixed "
          f"{base['p99_s']:.3f}s  | container-seconds: "
          f"{scaled['container_seconds']:.1f} vs "
          f"{base['container_seconds']:.1f}")

    if args.out:
        with open(args.out, "w", encoding="utf-8") as fh:
            json.dump(doc, fh, indent=2)
            fh.write("\n")
        print(f"wrote {args.out}")

    # Gates (the --smoke CI contract; full runs assert them too): the
    # autoscaled + budgeted configuration must meet the fixed-pool
    # keep-alive baseline's tail at strictly fewer container-seconds,
    # without shedding below the admission limit, under real concurrency.
    assert scaled["shed"] == 0, f"shed below limit: {scaled['shed']}"
    assert scaled["max_concurrency"] >= 4, \
        f"insufficient concurrency: {scaled['max_concurrency']}"
    assert scaled["p99_s"] <= base["p99_s"], \
        f"scaled p99 {scaled['p99_s']:.3f}s > fixed {base['p99_s']:.3f}s"
    assert scaled["container_seconds"] < base["container_seconds"], \
        (f"scaled container-seconds {scaled['container_seconds']:.1f} not "
         f"< fixed {base['container_seconds']:.1f}")
    print("OK: scaled+budgeted p99 <= fixed keep-alive at strictly fewer "
          "container-seconds, shed == 0")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
