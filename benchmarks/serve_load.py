"""DServe load sweep: dataflow vs controlflow p99 under rising RPS.

Unlike the simulator figures, this drives the *real threaded engine* with
explicit container pools: Poisson arrivals push N concurrent instances of
the Srv request chain through one shared DStore; the dataflow pattern
additionally prewarms each function's container when its precursor
launches (paper §3.2), so cold boots come off the critical path.  Expected
shape: dataflow p99 < controlflow p99 at every rate, with the gap growing
as rising RPS forces more cold boots mid-burst.

Run:  PYTHONPATH=src python -m benchmarks.serve_load [--smoke]
"""

import argparse

from repro.core.obs import (MetricsRegistry, Tracer, plan_attribution,
                            write_spans_jsonl)
from repro.core.serve import DServe, poisson_arrivals
from repro.core.workloads import serving_chain

SMOKE = dict(rates=(8.0,), n=10, stages=4, exec_time=0.03, cold_start=0.15)
FULL = dict(rates=(2.0, 6.0, 12.0), n=16, stages=4, exec_time=0.03,
            cold_start=0.15)


def sweep(rates, n, stages, exec_time, cold_start,
          patterns=("controlflow", "dataflow")):
    """Returns (rows, reports) — reports[(rate, pattern)] = ServeReport.

    Pattern ``"dataflow+plan"`` runs the dataflow engine under a static
    :class:`~repro.core.plan.WorkflowPlan`: per-key eviction the moment
    the statically-last read returns (instead of keep-alive until
    instance completion) and slack-timed container prewarm (instead of
    fire-at-precursor-launch).
    """
    rows, reports = [], {}
    for rate in rates:
        for pattern in patterns:
            wf = serving_chain(stages=stages, exec_time=exec_time,
                               cold_start=cold_start, payload=16 * 1024)
            srv = DServe(wf, n_nodes=2,
                         pattern=pattern.removesuffix("+plan"),
                         keepalive=10.0, max_per_node=16,
                         plan=pattern.endswith("+plan"))
            rep = srv.run(poisson_arrivals(rate, n, seed=7),
                          inputs={"request": b"req"})
            reports[(rate, pattern)] = rep
            rows.append((
                f"serve/rps={rate:g}/{pattern}/p99", rep.p99 * 1e6,
                f"p50={rep.p50:.3f}s cold={rep.cold_starts} "
                f"conc={rep.max_concurrency} fail={rep.failures} "
                f"peak_resident={rep.peak_resident_bytes}"))
        df = reports[(rate, "dataflow")]
        cf = reports[(rate, "controlflow")]
        rows.append((
            f"serve/rps={rate:g}/p99_cf_over_df", 0.0,
            f"{cf.p99 / max(df.p99, 1e-9):.2f}x "
            f"(cold {cf.cold_starts} vs {df.cold_starts})"))
        if (rate, "dataflow+plan") in reports:
            dp = reports[(rate, "dataflow+plan")]
            rows.append((
                f"serve/rps={rate:g}/plan_peak_over_heuristic", 0.0,
                f"{dp.peak_resident_bytes / max(df.peak_resident_bytes, 1):.2f}x "
                f"({dp.peak_resident_bytes} vs {df.peak_resident_bytes} B, "
                f"cold {dp.cold_starts} vs {df.cold_starts})"))
    return rows, reports


def run():
    rows, _ = sweep(**FULL)
    return rows


def traced_run(out: str, *, rate: float, n: int, stages: int,
               exec_time: float, cold_start: float):
    """One plan-driven dataflow run with DScope spans attached, written
    as JSONL with the plan attribution document embedded — the input to
    ``python -m repro.obs summarize/attribute/perfetto``.  Runs separate
    from the timed sweep so tracing never perturbs the bench numbers."""
    wf = serving_chain(stages=stages, exec_time=exec_time,
                       cold_start=cold_start, payload=16 * 1024)
    spans, metrics = Tracer(), MetricsRegistry()
    srv = DServe(wf, n_nodes=2, pattern="dataflow", keepalive=10.0,
                 max_per_node=16, plan=True, spans=spans, metrics=metrics)
    rep = srv.run(poisson_arrivals(rate, n, seed=7),
                  inputs={"request": b"req"})
    assert rep.failures == 0, "traced run failed"
    write_spans_jsonl(spans.finished(), out,
                      plan=plan_attribution(srv.plan),
                      meta={"bench": "serve_load", "rate": rate, "n": n,
                            "p99_s": round(rep.p99, 4)})
    print(f"# wrote {len(spans.finished())} span(s) to {out} "
          f"(inspect: python -m repro.obs summarize {out} --tree 1)")


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="single-rate run with acceptance assertions")
    ap.add_argument("--plan", action="store_true",
                    help="add the plan-driven dataflow arm (DPlan "
                    "eviction + slack prewarm; asserted under --smoke)")
    ap.add_argument("--spans", metavar="FILE",
                    help="also run one plan-driven dataflow pass with "
                    "DScope spans attached and write them (JSONL, plan "
                    "attribution embedded) to FILE")
    args = ap.parse_args(argv)
    cfg = SMOKE if args.smoke else FULL
    patterns = ("controlflow", "dataflow") + (
        ("dataflow+plan",) if args.plan else ())
    rows, reports = sweep(**cfg, patterns=patterns)
    print("name,us_per_call,derived")
    for name, us, derived in rows:
        print(f"{name},{us:.1f},{derived}")
    if args.smoke:
        (rate,) = cfg["rates"]
        df = reports[(rate, "dataflow")]
        cf = reports[(rate, "controlflow")]
        assert df.failures == 0 and cf.failures == 0, "instances failed"
        assert df.max_concurrency >= 4, (
            f"want >=4 concurrent instances, got {df.max_concurrency}")
        assert df.p99 < cf.p99, (
            f"dataflow p99 {df.p99:.3f} !< controlflow p99 {cf.p99:.3f}")
        assert df.cold_starts < cf.cold_starts, (
            f"prewarm should cut request-path cold starts: "
            f"{df.cold_starts} !< {cf.cold_starts}")
        print(f"# smoke ok: dataflow p99 {df.p99:.3f}s < controlflow "
              f"{cf.p99:.3f}s at concurrency {df.max_concurrency}")
        if args.plan:
            dp = reports[(rate, "dataflow+plan")]
            assert dp.failures == 0, "plan-driven instances failed"
            assert dp.peak_resident_bytes < df.peak_resident_bytes, (
                f"plan eviction should bound resident bytes below the "
                f"keep-alive baseline: {dp.peak_resident_bytes} !< "
                f"{df.peak_resident_bytes}")
            # "equal-or-better p99": strictly dp.p99 <= df.p99 modulo
            # thread-scheduling jitter (both runs share one process).
            assert dp.p99 <= df.p99 * 1.10, (
                f"plan-driven p99 {dp.p99:.3f} regressed past heuristic "
                f"{df.p99:.3f}")
            assert dp.cold_starts <= df.cold_starts, (
                f"slack prewarm paid more cold boots than the heuristic: "
                f"{dp.cold_starts} !> {df.cold_starts}")
            print(f"# plan smoke ok: peak resident "
                  f"{dp.peak_resident_bytes} B < {df.peak_resident_bytes} "
                  f"B at p99 {dp.p99:.3f}s (heuristic {df.p99:.3f}s)")
    if args.spans:
        traced_run(args.spans, rate=cfg["rates"][0], n=cfg["n"],
                   stages=cfg["stages"], exec_time=cfg["exec_time"],
                   cold_start=cfg["cold_start"])
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
