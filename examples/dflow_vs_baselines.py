"""Reproduce the paper's headline comparison on the simulated cluster.

Runs the Fig. 9 experiment (50 MB/s, 6 invocations/min) for the Genome
benchmark across all six systems and prints the p99 table plus DFlow's
reductions — compare with the paper's 52-60% (CFlow), 28-40% (FaaSFlow),
20-25% (FaaSFlowRedis), 36-40% (KNIX).

Then repeats the invocation-pattern ablation on the *real threaded engine*
via the DServe serving layer: concurrent Poisson-arriving instances of the
Srv request chain, with explicit container pools — dataflow prewarms each
function's container at precursor launch (§3.2), controlflow boots on the
critical path.

Run:  PYTHONPATH=src python examples/dflow_vs_baselines.py
"""

from repro.core import SYSTEMS, make_workflow, run_open_loop
from repro.core.serve import DServe, poisson_arrivals
from repro.core.workloads import serving_chain


def serve_section():
    print("\nDServe (real threaded engine, container pools), Srv chain "
          "@ 8 rps:")
    print(f"{'pattern':14s} {'p50 (s)':>8s} {'p99 (s)':>8s} "
          f"{'cold':>5s} {'conc':>5s}")
    p99 = {}
    for pattern in ("controlflow", "dataflow"):
        wf = serving_chain(stages=4, exec_time=0.03, cold_start=0.15,
                           payload=16 * 1024)
        srv = DServe(wf, n_nodes=2, pattern=pattern, keepalive=10.0,
                     max_per_node=16)
        rep = srv.run(poisson_arrivals(8.0, 10, seed=7),
                      inputs={"request": b"req"})
        p99[pattern] = rep.p99
        print(f"{pattern:14s} {rep.p50:8.3f} {rep.p99:8.3f} "
              f"{rep.cold_starts:5d} {rep.max_concurrency:5d}")
    assert p99["dataflow"] < p99["controlflow"]
    print("dataflow-triggered prewarm wins on real threads too ✓")


def main():
    wf = make_workflow("Gen")
    print(f"benchmark Gen: {len(wf)} functions, "
          f"critical path {wf.critical_path_time():.1f}s")
    print(f"{'system':18s} {'p99 (s)':>8s} {'timeouts':>9s}")
    p99 = {}
    for system in SYSTEMS:
        r = run_open_loop(system, wf, rate_per_min=6, n_invocations=8)
        p99[system] = r.p99
        print(f"{system:18s} {r.p99:8.2f} {r.timeouts:9d}")
    print()
    for base in SYSTEMS:
        if base == "dflow":
            continue
        red = 100 * (1 - p99["dflow"] / p99[base])
        print(f"DFlow p99 reduction vs {base:16s}: {red:5.1f}%")
    # dflow-stream / dflow-shard are our beyond-paper extensions —
    # expected to beat dflow.
    assert all(p99["dflow"] <= p99[s] + 1e-9 for s in SYSTEMS
               if s not in ("dflow-stream", "dflow-shard"))
    print("\nDFlow wins on every paper baseline ✓")
    serve_section()


if __name__ == "__main__":
    main()
