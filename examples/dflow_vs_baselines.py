"""Reproduce the paper's headline comparison on the simulated cluster.

Runs the Fig. 9 experiment (50 MB/s, 6 invocations/min) for the Genome
benchmark across all six systems and prints the p99 table plus DFlow's
reductions — compare with the paper's 52-60% (CFlow), 28-40% (FaaSFlow),
20-25% (FaaSFlowRedis), 36-40% (KNIX).

Run:  PYTHONPATH=src python examples/dflow_vs_baselines.py
"""

from repro.core import SYSTEMS, make_workflow, run_open_loop


def main():
    wf = make_workflow("Gen")
    print(f"benchmark Gen: {len(wf)} functions, "
          f"critical path {wf.critical_path_time():.1f}s")
    print(f"{'system':18s} {'p99 (s)':>8s} {'timeouts':>9s}")
    p99 = {}
    for system in SYSTEMS:
        r = run_open_loop(system, wf, rate_per_min=6, n_invocations=8)
        p99[system] = r.p99
        print(f"{system:18s} {r.p99:8.2f} {r.timeouts:9d}")
    print()
    for base in SYSTEMS:
        if base == "dflow":
            continue
        red = 100 * (1 - p99["dflow"] / p99[base])
        print(f"DFlow p99 reduction vs {base:16s}: {red:5.1f}%")
    assert all(p99["dflow"] <= p99[s] + 1e-9 for s in SYSTEMS)
    print("\nDFlow wins on every baseline ✓")


if __name__ == "__main__":
    main()
