"""Quickstart: the paper's DFlow engine executing a real workflow.

Builds a word-count workflow from a YAML spec, binds real Python callables
(numpy payloads), and runs it under both invocation patterns — dataflow
(the paper's contribution) and controlflow (the baseline) — over a
bandwidth-limited transport.  The counts finish at staggered times, so the
dataflow pattern lets ``merge`` pull each count the moment it is produced
(fine-grained retrieval, §3.3.3) instead of fetching everything after the
last precursor completes.

Run:  PYTHONPATH=src python examples/quickstart.py
"""

import time

import numpy as np

from repro.core import DFlowEngine, Transport, parse_workflow

VOCAB = 50_000
SHARDS = 4

YAML = f"""
name: wordcount
functions:
  split:
    inputs: [corpus]
    outputs: [{", ".join(f"shard.{i}" for i in range(SHARDS))}]
    exec_time: 0.05
  count:
    foreach: {SHARDS}
    inputs: [shard.$i]
    outputs: [wc.$i]
    exec_time: 0.2
  merge:
    inputs: [wc.*]
    outputs: [result]
    exec_time: 0.05
"""


def split(corpus):
    parts = np.array_split(corpus, SHARDS)
    return {f"shard.{i}": parts[i] for i in range(SHARDS)}


def make_count(i):
    def count(**kw):
        time.sleep(0.1 + 0.1 * i)     # staggered completion times
        shard = kw[f"shard.{i}"]
        return {f"wc.{i}": np.bincount(shard, minlength=VOCAB)
                .astype(np.int64)}
    return count


def merge(**kw):
    total = sum(kw[f"wc.{i}"] for i in range(SHARDS))
    return {"result": total}


def main():
    rng = np.random.default_rng(0)
    corpus = rng.integers(0, VOCAB, size=400_000).astype(np.int32)

    fns = {"split": split, "merge": merge}
    for i in range(SHARDS):
        fns[f"count.{i}"] = make_count(i)
    wf = parse_workflow(YAML, fns)
    print(f"workflow: {len(wf)} functions, entries={wf.entry_points}")

    results = {}
    for pattern in ("dataflow", "controlflow"):
        engine = DFlowEngine(n_nodes=3, pattern=pattern,
                             transport=Transport(bandwidth=8e6))
        t0 = time.time()
        report = engine.run(wf, {"corpus": corpus})
        wall = time.time() - t0
        results[pattern] = report.outputs["result"]
        print(f"{pattern:12s}: {wall * 1e3:6.1f} ms  "
              f"({report.transfers} transfers, "
              f"{report.bytes_moved / 1e6:.1f} MB moved)")
    assert np.array_equal(results["dataflow"], results["controlflow"])
    assert int(results["dataflow"].sum()) == corpus.size
    print("identical results under both invocation patterns ✓")


if __name__ == "__main__":
    main()
