"""Batched serving example: prefill + decode on the MoE architecture.

Runs the reduced qwen3-moe config through the serving path (prefill a
prompt batch, then autoregressive decode), reporting per-phase timings —
the same code path the decode_32k / prefill_32k dry-run cells lower for the
production mesh.

Run:  PYTHONPATH=src python examples/serve_moe.py
"""

from repro.launch.serve import serve_loop


def main():
    out = serve_loop("qwen3-moe-235b-a22b", batch=4, prompt_len=32,
                     gen_tokens=16)
    print(f"prefill: {out['prefill_s']:.2f}s")
    print(f"decode : {out['decode_s']:.2f}s "
          f"({out['decode_tok_per_s']:.1f} tok/s)")
    print(f"sample continuation tokens: {out['tokens'][0][:10].tolist()}")
    assert out["tokens"].shape == (4, 16)
    print("batched MoE serving ✓")


if __name__ == "__main__":
    main()
