"""End-to-end training driver example (deliverable b).

Trains a ~1M-parameter reduced TinyLlama for a few hundred steps on the
synthetic pipeline, with checkpointing every 50 steps, and demonstrates
crash/restart fault tolerance: the loss curve after resume continues the
original trajectory exactly.

Run:  PYTHONPATH=src python examples/train_tinyllama.py [--steps 200]

(For the full-size assigned configs this same driver runs on TPU pods via
``python -m repro.launch.train --arch <id> --full``; this container is
CPU-only so the example uses the reduced config.)
"""

import argparse
import tempfile

from repro.launch.train import train_loop


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    args = ap.parse_args()

    with tempfile.TemporaryDirectory() as ckpt_dir:
        print("=== phase 1: train with a simulated crash mid-run ===")
        try:
            train_loop("tinyllama-1.1b", steps=args.steps, batch=args.batch,
                       seq=args.seq, ckpt_dir=ckpt_dir, ckpt_every=max(args.steps // 5, 1),
                       simulate_failure=args.steps // 2,
                       log_every=max(args.steps // 10, 1))
        except RuntimeError as e:
            print(f"!! {e} — restarting from the last checkpoint")

        print("=== phase 2: resume and finish ===")
        out = train_loop("tinyllama-1.1b", steps=args.steps,
                         batch=args.batch, seq=args.seq, ckpt_dir=ckpt_dir,
                         ckpt_every=max(args.steps // 5, 1), resume=True,
                         log_every=max(args.steps // 10, 1))
        print(f"resumed at step {out['start_step']}; "
              f"final loss {out['final_loss']:.4f}; "
              f"{out['tokens_per_s']:.0f} tokens/s")
        assert out["final_loss"] < out["losses"][0] - 0.1 \
            or out["final_loss"] < 5.5, "loss should clearly decrease"
        print("fault-tolerant end-to-end training ✓")


if __name__ == "__main__":
    main()
