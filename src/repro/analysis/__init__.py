"""Compiled-artifact analysis: HLO parsing, analytic FLOPs, roofline."""
