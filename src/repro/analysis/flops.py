"""Analytic FLOP/parameter accounting per architecture × shape cell.

``MODEL_FLOPS`` follows the brief: 6·N·D for dense training (N params,
D tokens), 6·N_active·D for MoE; decode/prefill use the forward-only 2·N·D
plus the attention term.  These are the "useful compute" yardsticks the
roofline compares XLA's HLO FLOPs against (ratio ≈ 1/3 for an ideal
remat-free fwd, <1 when remat recompute or causal over-compute inflates the
compiled program).
"""

from __future__ import annotations

from ..models.config import ModelConfig

__all__ = ["param_counts", "active_params", "model_flops"]


def _attn_params(cfg: ModelConfig) -> int:
    M, H, Hk, D = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    return M * H * D + 2 * M * Hk * D + H * D * M


def _mlp_params(cfg: ModelConfig, d_ff: int) -> int:
    mult = 3 if cfg.glu else 2
    return mult * cfg.d_model * d_ff


def _expert_params(cfg: ModelConfig) -> int:
    return _mlp_params(cfg, cfg.d_ff)


def _mamba_params(cfg: ModelConfig) -> int:
    M, DI, N, H = cfg.d_model, cfg.d_inner, cfg.ssm_state, cfg.ssm_heads
    return (2 * M * DI          # w_z, w_x
            + 2 * M * N         # w_B, w_C
            + M * H             # w_dt
            + DI * M)           # out_proj


def param_counts(cfg: ModelConfig) -> dict:
    """total / active parameter counts (embedding included once)."""
    V, M, L = cfg.vocab, cfg.d_model, cfg.n_layers
    embed = V * M * (1 if cfg.tie_embeddings else 2)
    fam = cfg.family
    if fam in ("dense", "vlm"):
        layer = _attn_params(cfg) + _mlp_params(cfg, cfg.d_ff)
        total = active = L * layer
    elif fam == "moe":
        shared = cfg.n_shared_experts * _mlp_params(cfg, cfg.d_ff)
        layer_fixed = _attn_params(cfg) + shared + M * cfg.n_experts
        total = L * (layer_fixed + cfg.n_experts * _expert_params(cfg))
        active = L * (layer_fixed + cfg.top_k * _expert_params(cfg))
    elif fam == "ssm":
        total = active = L * _mamba_params(cfg)
    elif fam == "hybrid":
        per = cfg.hybrid_period
        nb = L // per
        n_moe = per // cfg.hybrid_moe_every
        n_mlp = per - n_moe
        mixers = _attn_params(cfg) + (per - 1) * _mamba_params(cfg)
        ffn_total = (n_mlp * _mlp_params(cfg, cfg.d_ff)
                     + n_moe * cfg.n_experts * _expert_params(cfg))
        ffn_active = (n_mlp * _mlp_params(cfg, cfg.d_ff)
                      + n_moe * cfg.top_k * _expert_params(cfg))
        total = nb * (mixers + ffn_total)
        active = nb * (mixers + ffn_active)
    elif fam == "encdec":
        enc = cfg.n_encoder_layers * (_attn_params(cfg)
                                      + _mlp_params(cfg, cfg.d_ff))
        dec = cfg.n_layers * (2 * _attn_params(cfg)
                              + _mlp_params(cfg, cfg.d_ff))
        total = active = enc + dec
    else:
        raise ValueError(fam)
    return {"total": total + embed, "active": active + embed,
            "embed": embed}


def active_params(cfg: ModelConfig) -> int:
    return param_counts(cfg)["active"]


def _attn_quadratic_flops(cfg: ModelConfig, batch: int, seq: int,
                          n_attn_layers: int, causal: bool = True) -> float:
    """QK^T + PV matmul flops (2 matmuls × 2 flops/MAC), causal halved."""
    H, D = cfg.n_heads, cfg.head_dim
    full = 4.0 * batch * H * seq * seq * D
    return n_attn_layers * (full / 2 if causal else full)


def _n_attn_layers(cfg: ModelConfig) -> int:
    if cfg.family in ("dense", "vlm", "moe"):
        return cfg.n_layers
    if cfg.family == "ssm":
        return 0
    if cfg.family == "hybrid":
        return cfg.n_layers // cfg.hybrid_period
    if cfg.family == "encdec":
        return cfg.n_encoder_layers + 2 * cfg.n_layers
    raise ValueError(cfg.family)


def model_flops(cfg: ModelConfig, cell) -> dict:
    """MODEL_FLOPS for the cell (per executed step, whole mesh)."""
    counts = param_counts(cfg)
    Na = counts["active"]
    B, S = cell.batch, cell.seq
    kind = cell.kind
    if kind == "train":
        tokens = B * S
        matmul = 6.0 * Na * tokens
        attn = 3.0 * _attn_quadratic_flops(cfg, B, S, _n_attn_layers(cfg))
        return {"model_flops": matmul + attn, "matmul_6nd": matmul,
                "attention": attn, "tokens": tokens,
                "params_total": counts["total"],
                "params_active": counts["active"]}
    if kind == "prefill":
        tokens = B * S
        matmul = 2.0 * Na * tokens
        attn = _attn_quadratic_flops(cfg, B, S, _n_attn_layers(cfg))
        return {"model_flops": matmul + attn, "matmul_6nd": matmul,
                "attention": attn, "tokens": tokens,
                "params_total": counts["total"],
                "params_active": counts["active"]}
    # decode: one token per sequence against a seq-long cache
    tokens = B
    matmul = 2.0 * Na * tokens
    H, D = cfg.n_heads, cfg.head_dim
    attn = 4.0 * B * H * S * D * _n_attn_layers(cfg)
    return {"model_flops": matmul + attn, "matmul_6nd": matmul,
            "attention": attn, "tokens": tokens,
            "params_total": counts["total"],
            "params_active": counts["active"]}
