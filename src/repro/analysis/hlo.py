"""Optimized-HLO text analysis: collective bytes + while-loop trip counts.

``compiled.as_text()`` of an SPMD-partitioned module has *per-device*
shapes, so every byte count below is already per device.  Collectives that
sit inside ``while`` bodies (layer scans, microbatch accumulation) must be
multiplied by the loop trip count; we reconstruct the computation call
graph (body=/condition=/calls=/to_apply=) and propagate multipliers, taking
each while's trip count from the largest integer constant in its condition
computation (XLA canonicalizes counted loops to ``iter < C``).

Wire-byte model per collective (ring algorithms, n = participant count):

=================  ===========================================
all-reduce         2 · bytes · (n-1)/n
all-gather         out_bytes · (n-1)/n       (out is the full gather)
reduce-scatter     out_bytes · (n-1)          (out is the 1/n shard)
all-to-all         bytes · (n-1)/n
collective-permute bytes
=================  ===========================================
"""

from __future__ import annotations

import math
import re
from collections import defaultdict

__all__ = ["collective_summary", "count_scan_trips", "parse_collectives"]

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "c128": 16, "f8e4m3fn": 1, "f8e5m2": 1, "s4": 1, "u4": 1,
}

_COLL_RE = re.compile(
    r"(?P<kind>all-reduce|all-gather|reduce-scatter|all-to-all|"
    r"collective-permute)(?:-start)?\(")
_SHAPE_RE = re.compile(r"(?P<dt>[a-z0-9]+)\[(?P<dims>[0-9,]*)\]")
_COMP_DEF_RE = re.compile(r"^(?:%?)([\w\.\-]+)\s*(?:\([^)]*\))?\s*"
                          r"(?:->\s*[^{]*)?\{\s*$")
_CALL_RE = re.compile(r"(?:to_apply|body|condition|branch_computations|"
                      r"called_computations)=\{?%?([\w\.\-]+(?:,\s*%?[\w\.\-]+)*)\}?")
_GROUPS_LIST_RE = re.compile(r"replica_groups=\{\{([0-9,\s]+)\}")
_GROUPS_IOTA_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]")
_CONST_RE = re.compile(r"constant\((\d+)\)")
_WHILE_RE = re.compile(r"while\(")


def _shape_bytes(dt: str, dims: str) -> int:
    n = 1
    for d in dims.split(","):
        if d.strip():
            n *= int(d)
    return n * _DTYPE_BYTES.get(dt, 4)


def _group_size(line: str) -> int:
    m = _GROUPS_IOTA_RE.search(line)
    if m:
        return int(m.group(2))     # [groups, per_group]
    m = _GROUPS_LIST_RE.search(line)
    if m:
        return len([x for x in m.group(1).split(",") if x.strip()])
    return 1


def _result_bytes(line: str) -> int:
    """Sum of shaped outputs on the lhs (handles tuple results)."""
    lhs = line.split(" = ", 1)
    target = lhs[1] if len(lhs) == 2 else line
    # take shapes up to the op name (result portion of the line)
    m = _COLL_RE.search(target)
    head = target[:m.start()] if m else target
    total = 0
    for sm in _SHAPE_RE.finditer(head):
        if sm.group("dt") in _DTYPE_BYTES:
            total += _shape_bytes(sm.group("dt"), sm.group("dims"))
    return total


def _wire_bytes(kind: str, nbytes: int, n: int) -> float:
    if n <= 1:
        return 0.0
    if kind == "all-reduce":
        return 2.0 * nbytes * (n - 1) / n
    if kind == "all-gather":
        return nbytes * (n - 1) / n
    if kind == "reduce-scatter":
        return float(nbytes) * (n - 1)
    if kind == "all-to-all":
        return nbytes * (n - 1) / n
    if kind == "collective-permute":
        return float(nbytes)
    return float(nbytes)


def _split_computations(hlo: str) -> dict[str, list[str]]:
    """computation name -> its lines (flat brace tracking)."""
    comps: dict[str, list[str]] = {}
    current = None
    depth = 0
    for line in hlo.splitlines():
        stripped = line.strip()
        if current is None:
            m = re.match(r"^%?([\w\.\-]+)[^=]*\{$", stripped)
            if stripped.endswith("{") and ("(" in stripped or
                                           stripped.startswith("ENTRY")):
                name = stripped.split()[0].lstrip("%")
                if stripped.startswith("ENTRY"):
                    name = stripped.split()[1].lstrip("%")
                current = name
                comps[current] = []
                depth = 1
            continue
        depth += stripped.count("{") - stripped.count("}")
        if depth <= 0:
            current = None
            continue
        comps[current].append(line)
    return comps


def _call_edges(lines: list[str]) -> dict[str, list[str]]:
    """op-line attributes: body= / condition= / to_apply= targets."""
    edges = defaultdict(list)
    for line in lines:
        for m in re.finditer(r"(body|condition|to_apply)=%?([\w\.\-]+)",
                             line):
            edges[m.group(1)].append(m.group(2))
    return edges


def count_scan_trips(hlo: str) -> dict[str, int]:
    """while-body computation name -> inferred trip count."""
    comps = _split_computations(hlo)
    trips: dict[str, int] = {}
    for name, lines in comps.items():
        for line in lines:
            if "while(" not in line:
                continue
            bm = re.search(r"body=%?([\w\.\-]+)", line)
            cm = re.search(r"condition=%?([\w\.\-]+)", line)
            if not bm or not cm:
                continue
            cond_lines = comps.get(cm.group(1), [])
            consts = [int(x) for cl in cond_lines
                      for x in _CONST_RE.findall(cl)]
            trips[bm.group(1)] = max(consts) if consts else 1
    return trips


def parse_collectives(hlo: str) -> list[dict]:
    """Every collective op with its per-device wire bytes, loop-scaled."""
    comps = _split_computations(hlo)
    trips = count_scan_trips(hlo)

    # multiplier per computation: product of enclosing loop trip counts.
    mult: dict[str, float] = defaultdict(lambda: 1.0)

    # build parent->child edges for body/to_apply/condition
    children: dict[str, list[str]] = defaultdict(list)
    for name, lines in comps.items():
        for line in lines:
            for m in re.finditer(r"(body|condition|to_apply|"
                                 r"branch_computations)=\{?%?([\w\.\-]+)",
                                 line):
                kind, target = m.group(1), m.group(2)
                children[name].append(target)

    # propagate multipliers from the entry computation down.
    entry = None
    for name in comps:
        if "main" in name or entry is None:
            entry = name if entry is None else entry
    # find ENTRY computation: the one not referenced by others
    referenced = {t for ts in children.values() for t in ts}
    roots = [n for n in comps if n not in referenced]
    stack = [(r, 1.0) for r in roots]
    seen = set()
    while stack:
        name, m0 = stack.pop()
        if (name, m0) in seen:
            continue
        seen.add((name, m0))
        mult[name] = max(mult[name], m0)
        for child in children.get(name, ()):  # body gets ×trip
            factor = trips.get(child, 1) if child in trips else 1
            stack.append((child, m0 * factor))

    out = []
    for name, lines in comps.items():
        for line in lines:
            cm = _COLL_RE.search(line)
            if not cm or " = " not in line:
                continue
            kind = cm.group("kind")
            if f"{kind}-done" in line:
                continue        # counted at -start
            nbytes = _result_bytes(line)
            n = _group_size(line)
            wire = _wire_bytes(kind, nbytes, n)
            out.append({
                "kind": kind, "bytes": nbytes, "group": n,
                "wire_bytes": wire * mult[name],
                "computation": name, "multiplier": mult[name],
            })
    return out


_DOT_RE = re.compile(r" = (?P<rdt>[a-z0-9]+)\[(?P<rdims>[0-9,]*)\][^=]*? "
                     r"dot\((?P<args>.*)")
_CONTR_RE = re.compile(r"lhs_contracting_dims=\{([0-9,]+)\}")
_DEF_RE = re.compile(r"^\s*(?:ROOT\s+)?%?([\w\.\-]+)\s*=\s*"
                     r"(?:\()?([a-z0-9]+)\[([0-9,]*)\]")


def _symbol_shapes(hlo: str) -> dict[str, tuple[str, list[int]]]:
    """%name -> (dtype, dims) from each op's defining line (first shape of
    tuple results — sufficient for dot operands, which are arrays)."""
    table: dict[str, tuple[str, list[int]]] = {}
    for line in hlo.splitlines():
        m = _DEF_RE.match(line)
        if not m:
            continue
        dt = m.group(2)
        if dt not in _DTYPE_BYTES:
            continue
        dims = [int(x) for x in m.group(3).split(",") if x.strip()]
        table[m.group(1)] = (dt, dims)
    return table


def _computation_multipliers(hlo: str) -> dict[str, float]:
    comps = _split_computations(hlo)
    trips = count_scan_trips(hlo)
    children: dict[str, list[str]] = defaultdict(list)
    for name, lines in comps.items():
        for line in lines:
            for m in re.finditer(r"(body|condition|to_apply|"
                                 r"branch_computations)=\{?%?([\w\.\-]+)",
                                 line):
                children[name].append(m.group(2))
    referenced = {t for ts in children.values() for t in ts}
    roots = [n for n in comps if n not in referenced]
    mult: dict[str, float] = defaultdict(lambda: 1.0)
    stack = [(r, 1.0) for r in roots]
    seen = set()
    while stack:
        name, m0 = stack.pop()
        if (name, m0) in seen:
            continue
        seen.add((name, m0))
        mult[name] = max(mult[name], m0)
        for child in children.get(name, ()):
            factor = trips.get(child, 1) if child in trips else 1
            stack.append((child, m0 * factor))
    return dict(mult)


def matmul_flops(hlo: str) -> float:
    """Loop-scaled dot-op FLOPs per device parsed from optimized HLO.

    XLA's ``cost_analysis()`` counts a while body once; layer scans and
    blockwise-attention chunk loops therefore under-report by the trip
    counts.  FLOPs per dot = 2 · |result| · K (K = contracted extent from
    the lhs operand shape)."""
    comps = _split_computations(hlo)
    mult = _computation_multipliers(hlo)
    symbols = _symbol_shapes(hlo)
    total = 0.0
    for name, lines in comps.items():
        m0 = mult.get(name, 1.0)
        for line in lines:
            dm = _DOT_RE.search(line)
            if not dm or " dot(" not in line:
                continue
            out_elems = 1
            for d in dm.group("rdims").split(","):
                if d.strip():
                    out_elems *= int(d)
            cm = _CONTR_RE.search(line)
            if not cm:
                continue
            # lhs operand: inline shape if printed, else symbol lookup.
            args = dm.group("args")
            am = _SHAPE_RE.search(args.split(",")[0])
            if am:
                lhs_dims = [int(x) for x in am.group("dims").split(",")
                            if x.strip()]
            else:
                opname = args.lstrip("(").split(",")[0].strip().lstrip("%")
                entry = symbols.get(opname)
                if entry is None:
                    continue
                lhs_dims = entry[1]
            k = 1
            for ci in cm.group(1).split(","):
                idx = int(ci)
                if idx < len(lhs_dims):
                    k *= lhs_dims[idx]
            total += 2.0 * out_elems * k * m0
    return total


_SKIP_OPS = ("parameter(", "constant(", "get-tuple-element(", "tuple(",
             "bitcast(", "after-all(", "partition-id(", "replica-id(",
             "iota(")
_OPND_RE = re.compile(r"%([\w\.\-]+)")


def hbm_bytes(hlo: str) -> float:
    """Estimated per-device HBM traffic (bytes), loop-scaled.

    Sums result bytes (writes) + operand bytes (reads) of every *top-level*
    op; ops inside ``fused_computation`` bodies never touch HBM (only the
    fusion's operands/results do), so fusion-body computations are skipped
    entirely.  Aliasing pseudo-ops (bitcast/GTE/tuple/parameter) are free.
    """
    comps = _split_computations(hlo)
    mult = _computation_multipliers(hlo)
    symbols = _symbol_shapes(hlo)

    # fusion bodies = computations referenced via calls= on fusion ops
    fusion_bodies: set[str] = set()
    for name, lines in comps.items():
        for line in lines:
            if " fusion(" in line:
                for m in re.finditer(r"calls=%?([\w\.\-]+)", line):
                    fusion_bodies.add(m.group(1))
    # fusions whose body is an in-place windowed update (root DUS/scatter):
    # the fusion "result" aliases the whole buffer but only a window is
    # actually written (e.g. per-layer gradient accumulation into stacked
    # parameter buffers inside the backward scan).
    inplace_bodies = {
        name for name in fusion_bodies
        if any(("dynamic-update-slice(" in ln or " scatter(" in ln)
               for ln in comps.get(name, ()))}

    total = 0.0
    for name, lines in comps.items():
        if name in fusion_bodies:
            continue
        m0 = mult.get(name, 1.0)
        for line in lines:
            if " = " not in line:
                continue
            if any(op in line for op in _SKIP_OPS):
                continue
            dm = _DEF_RE.match(line)
            if not dm or dm.group(2) not in _DTYPE_BYTES:
                continue
            out_b = _shape_bytes(dm.group(2), dm.group(3))
            rhs = line.split(" = ", 1)[1]
            if " while(" in rhs:
                continue     # carry aliases through; body ops are counted
            is_inplace = (" dynamic-update-slice(" in rhs
                          or " scatter(" in rhs)
            if not is_inplace and " fusion(" in rhs:
                cm = re.search(r"calls=%?([\w\.\-]+)", rhs)
                is_inplace = bool(cm) and cm.group(1) in inplace_bodies
            if is_inplace:
                # aliased in-place update: traffic ≈ the written window
                # (smallest shaped operand), not the whole buffer.
                ops = _operand_shapes(rhs, symbols)
                small = [b for b in ops if b < out_b]
                total += 2.0 * (min(small) if small else out_b) * m0
                continue
            # Write-once/read-once model: each produced tensor is written
            # and read ~once downstream (2 × result bytes).  Operand sizes
            # are NOT summed — XLA fuses slice/elementwise chains, so an
            # op-line operand often names a far larger buffer than the
            # bytes actually touched per execution.
            total += 2.0 * out_b * m0
    return total


def _operand_shapes(rhs: str, symbols) -> list[int]:
    paren = rhs.find("(")
    close = rhs.find(")", paren)
    out: list[int] = []
    if paren != -1 and close != -1:
        for om in _OPND_RE.finditer(rhs[paren:close]):
            entry = symbols.get(om.group(1))
            if entry:
                out.append(_shape_bytes(entry[0],
                                        ",".join(map(str, entry[1]))))
    return out


def collective_summary(hlo: str) -> dict:
    ops = parse_collectives(hlo)
    by_kind: dict[str, dict] = {}
    for op in ops:
        rec = by_kind.setdefault(op["kind"],
                                 {"count": 0, "wire_bytes": 0.0})
        rec["count"] += 1
        rec["wire_bytes"] += op["wire_bytes"]
    return {
        "total_bytes": sum(o["wire_bytes"] for o in ops),
        "n_ops": len(ops),
        "by_kind": by_kind,
    }
