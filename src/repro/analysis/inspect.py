"""Top-contributor inspector for saved dry-run HLO (hillclimb tooling).

``python -m repro.analysis.inspect results/dryrun/<cell>.hlo.gz [--top 15]``

Prints the largest per-device HBM-traffic and collective contributors with
their loop multipliers and source metadata (op_name), so §Perf hypotheses
come from measured structure instead of guesswork.
"""

from __future__ import annotations

import argparse
import gzip
import pathlib
import re
from collections import defaultdict

from .hlo import (_DEF_RE, _DTYPE_BYTES, _SKIP_OPS, _computation_multipliers,
                  _operand_shapes, _shape_bytes, _split_computations,
                  _symbol_shapes, parse_collectives)

__all__ = ["top_memory_ops", "main"]

_META_RE = re.compile(r'op_name="([^"]+)"')


def top_memory_ops(hlo: str, top: int = 20):
    comps = _split_computations(hlo)
    mult = _computation_multipliers(hlo)
    symbols = _symbol_shapes(hlo)
    fusion_bodies: set[str] = set()
    for name, lines in comps.items():
        for line in lines:
            if " fusion(" in line:
                for m in re.finditer(r"calls=%?([\w\.\-]+)", line):
                    fusion_bodies.add(m.group(1))
    inplace = {
        name for name in fusion_bodies
        if any(("dynamic-update-slice(" in ln or " scatter(" in ln)
               for ln in comps.get(name, ()))}

    rows = []
    for name, lines in comps.items():
        if name in fusion_bodies:
            continue
        m0 = mult.get(name, 1.0)
        for line in lines:
            if " = " not in line or any(op in line for op in _SKIP_OPS):
                continue
            dm = _DEF_RE.match(line)
            if not dm or dm.group(2) not in _DTYPE_BYTES:
                continue
            out_b = _shape_bytes(dm.group(2), dm.group(3))
            rhs = line.split(" = ", 1)[1]
            if " while(" in rhs:
                continue
            is_inplace = (" dynamic-update-slice(" in rhs
                          or " scatter(" in rhs)
            if not is_inplace and " fusion(" in rhs:
                cm = re.search(r"calls=%?([\w\.\-]+)", rhs)
                is_inplace = bool(cm) and cm.group(1) in inplace
            if is_inplace:
                ops = _operand_shapes(rhs, symbols)
                small = [b for b in ops if b < out_b]
                bytes_ = 2.0 * (min(small) if small else out_b) * m0
            else:
                bytes_ = 2.0 * out_b * m0
            meta = _META_RE.search(line)
            shape = f"{dm.group(2)}[{dm.group(3)}]"
            rows.append((bytes_, m0, shape,
                         (meta.group(1) if meta else name)[:90]))
    rows.sort(key=lambda r: -r[0])
    return rows[:top]


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("path")
    ap.add_argument("--top", type=int, default=15)
    args = ap.parse_args(argv)
    p = pathlib.Path(args.path)
    hlo = gzip.open(p, "rt").read() if p.suffix == ".gz" \
        else p.read_text()

    print("== top HBM-traffic ops (per device, loop-scaled) ==")
    for b, m0, shape, meta in top_memory_ops(hlo, args.top):
        print(f"{b / 1e9:9.1f} GB  x{m0:6.0f}  {shape:34s} {meta}")

    print("\n== top collectives (wire bytes per device, loop-scaled) ==")
    colls = sorted(parse_collectives(hlo), key=lambda o: -o["wire_bytes"])
    agg = defaultdict(lambda: [0.0, 0])
    for o in colls:
        key = (o["kind"], o["bytes"], o["group"], o["multiplier"])
        agg[key][0] += o["wire_bytes"]
        agg[key][1] += 1
    for (kind, nbytes, grp, m0), (wb, cnt) in sorted(
            agg.items(), key=lambda kv: -kv[1][0])[:args.top]:
        print(f"{wb / 1e9:9.1f} GB  x{m0:6.0f}  {kind:20s} "
              f"{nbytes / 1e6:8.1f} MB/op  group={grp}  count={cnt}")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
