"""Roofline analysis over dry-run records (EXPERIMENTS.md §Roofline).

Hardware model (TPU v5e, per the brief):
  peak  = 197 TFLOP/s bf16 per chip
  HBM   = 819 GB/s per chip
  ICI   = ~50 GB/s per link

Three terms per (arch × shape × mesh) cell, all in seconds per step:

  compute    = dot_flops_per_device / peak
  memory     = hbm_bytes_per_device / HBM_bw
  collective = collective_wire_bytes_per_device / ICI_bw

``dot_flops_per_device`` comes from the loop-scaled HLO parse (XLA's
cost_analysis counts while bodies once — see analysis/hlo.py); HBM bytes
scale cost_analysis's "bytes accessed" by the same loop-correction ratio
(both are dominated by the loop bodies; the approximation is noted in the
report).  The dominant term is the bottleneck; the roofline fraction we
report for §Perf is

  useful = (MODEL_FLOPS / chips / peak) / max(terms)

i.e. how much of the bound time is spent on *useful* model FLOPs.
"""

from __future__ import annotations

import argparse
import json
import math
import pathlib

__all__ = ["PEAK_FLOPS", "HBM_BW", "ICI_BW", "analyze_record",
           "load_records", "table", "main"]

PEAK_FLOPS = 197e12          # bf16 FLOP/s per chip
HBM_BW = 819e9               # B/s per chip
ICI_BW = 50e9                # B/s per link
HBM_PER_CHIP = 16 * (1 << 30)


def analyze_record(rec: dict) -> dict:
    chips = rec["n_chips"]
    dot = rec.get("dot_flops_per_device") or 0.0
    raw_flops = rec.get("hlo_flops") or 0.0
    raw_bytes = rec.get("hlo_bytes") or 0.0
    hbm_bytes = rec.get("hbm_bytes_per_device")
    if not hbm_bytes:
        # fallback for old records: loop-correct cost_analysis bytes
        corr = (dot / raw_flops) if raw_flops else 1.0
        hbm_bytes = raw_bytes * max(corr, 1.0)
    coll = rec["collectives"]["total_bytes"]

    compute_s = dot / PEAK_FLOPS
    memory_s = hbm_bytes / HBM_BW
    collective_s = coll / ICI_BW
    terms = {"compute": compute_s, "memory": memory_s,
             "collective": collective_s}
    dominant = max(terms, key=terms.get)
    bound = max(terms.values())

    mf = rec["model_flops"]["model_flops"]
    useful_s = mf / chips / PEAK_FLOPS
    frac = useful_s / bound if bound > 0 else float("nan")
    flops_ratio = mf / (dot * chips) if dot else float("nan")

    temp = (rec.get("memory_analysis") or {}).get("temp_size_bytes")
    args_b = (rec.get("memory_analysis") or {}).get("argument_size_bytes")
    return {
        **{k: rec[k] for k in ("arch", "shape", "mesh", "kind", "n_chips")},
        "compute_s": compute_s, "memory_s": memory_s,
        "collective_s": collective_s, "dominant": dominant,
        "bound_s": bound, "useful_s": useful_s,
        "roofline_fraction": frac,
        "model_over_hlo_flops": flops_ratio,
        "hbm_gb_per_chip": ((temp or 0) + (args_b or 0)) / (1 << 30),
        "tag": rec.get("tag", "baseline"),
    }


def load_records(directory: str | pathlib.Path, tag: str = "baseline"):
    recs = []
    for p in sorted(pathlib.Path(directory).glob(f"*__{tag}.json")):
        rec = json.loads(p.read_text())
        rec["tag"] = tag
        recs.append(analyze_record(rec))
    return recs


def _fmt_s(x: float) -> str:
    if x >= 1:
        return f"{x:7.2f}s"
    if x >= 1e-3:
        return f"{x * 1e3:6.1f}ms"
    return f"{x * 1e6:6.0f}us"


def table(rows: list[dict], mesh: str = "single") -> str:
    out = ["| arch | shape | compute | memory | collective | bottleneck | "
           "useful/bound | MODEL/HLO |",
           "|---|---|---|---|---|---|---|---|"]
    for r in rows:
        if r["mesh"] != mesh:
            continue
        out.append(
            f"| {r['arch']} | {r['shape']} | {_fmt_s(r['compute_s'])} | "
            f"{_fmt_s(r['memory_s'])} | {_fmt_s(r['collective_s'])} | "
            f"**{r['dominant']}** | {r['roofline_fraction']:.2f} | "
            f"{r['model_over_hlo_flops']:.2f} |")
    return "\n".join(out)


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--in", dest="indir", default="results/dryrun")
    ap.add_argument("--tag", default="baseline")
    ap.add_argument("--mesh", default="single")
    ap.add_argument("--json-out", default=None)
    args = ap.parse_args(argv)
    rows = load_records(args.indir, args.tag)
    print(table(rows, args.mesh))
    if args.json_out:
        pathlib.Path(args.json_out).write_text(json.dumps(rows, indent=1))
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
