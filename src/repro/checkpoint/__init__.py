"""Checkpointing: topology-agnostic save/restore with async writes."""

from .ckpt import (CheckpointManager, latest_step, restore_state,
                   save_state)

__all__ = ["CheckpointManager", "latest_step", "restore_state", "save_state"]
