"""Topology-agnostic checkpoint save/restore (+ async saves).

Layout: one directory per step, one ``.npy`` per pytree leaf (path-encoded
file names) plus a ``manifest.json`` (tree structure, dtypes, step, config
fingerprint).  Leaves are written as *full* (unsharded) arrays keyed by
their tree path — never by device — so a checkpoint written on a 16×16 mesh
restores onto any other mesh or host count (elastic re-scaling): the
restore path simply ``device_put``s each leaf with the *new* mesh's
NamedSharding.

Async mode snapshots leaves to host memory synchronously (cheap) and writes
files on a daemon thread — the training loop continues immediately; this is
the paper's "producer frees its container at Put, metadata publish is
asynchronous" pattern applied to checkpoint I/O (DESIGN.md §3).

Fault tolerance contract (used by launch/train.py): crash at any point
leaves either a complete previous checkpoint or a complete new one —
directories are written under a temp name and atomically renamed; restarts
resume from ``latest_step`` and the data pipeline reproduces the exact
batch for that step (seeded by step index).
"""

from __future__ import annotations

import json
import pathlib
import re
import shutil
import threading
from typing import Any

import jax
import numpy as np

__all__ = ["save_state", "restore_state", "latest_step",
           "CheckpointManager"]

_SEP = "__"


def _flatten(tree) -> dict[str, Any]:
    flat = {}
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        key = _SEP.join(_fmt(p) for p in path)
        flat[key] = leaf
    return flat


def _fmt(p) -> str:
    if hasattr(p, "key"):
        return str(p.key)
    if hasattr(p, "idx"):
        return str(p.idx)
    if hasattr(p, "name"):
        return str(p.name)
    return str(p)


def save_state(directory: str | pathlib.Path, step: int, state,
               extra: dict | None = None) -> pathlib.Path:
    """Synchronous atomic save; returns the final directory."""
    directory = pathlib.Path(directory)
    final = directory / f"step_{step:08d}"
    tmp = directory / f".tmp_step_{step:08d}"
    if tmp.exists():
        shutil.rmtree(tmp)
    tmp.mkdir(parents=True)
    flat = _flatten(state)
    manifest = {"step": step, "leaves": {}, "extra": extra or {}}
    for key, leaf in flat.items():
        arr = np.asarray(jax.device_get(leaf))
        true_dtype = str(arr.dtype)
        if arr.dtype.kind == "V" or "bfloat16" in true_dtype:
            # ml_dtypes (bfloat16 etc.) don't round-trip through .npy:
            # store the raw bits and record the logical dtype.
            arr = arr.view(np.uint16 if arr.dtype.itemsize == 2
                           else np.uint8)
        np.save(tmp / f"{key}.npy", arr)
        manifest["leaves"][key] = {"dtype": true_dtype,
                                   "shape": list(arr.shape)}
    (tmp / "manifest.json").write_text(json.dumps(manifest))
    if final.exists():
        shutil.rmtree(final)
    tmp.rename(final)
    return final


def latest_step(directory: str | pathlib.Path) -> int | None:
    directory = pathlib.Path(directory)
    if not directory.exists():
        return None
    steps = [int(m.group(1)) for p in directory.iterdir()
             if (m := re.match(r"step_(\d+)$", p.name))]
    return max(steps) if steps else None


def restore_state(directory: str | pathlib.Path, step: int, like,
                  shardings=None):
    """Restore into the structure of ``like``; reshard onto ``shardings``
    (a matching tree of NamedSharding) when given — elastic restore."""
    directory = pathlib.Path(directory) / f"step_{step:08d}"
    manifest = json.loads((directory / "manifest.json").read_text())
    flat_like, treedef = jax.tree_util.tree_flatten_with_path(like)
    shard_leaves = (jax.tree.leaves(
        shardings, is_leaf=lambda x: hasattr(x, "spec"))
        if shardings is not None else [None] * len(flat_like))
    out = []
    for (path, leaf), sh in zip(flat_like, shard_leaves):
        key = _SEP.join(_fmt(p) for p in path)
        if key not in manifest["leaves"]:
            raise KeyError(f"checkpoint missing leaf {key!r}")
        arr = np.load(directory / f"{key}.npy")
        stored = manifest["leaves"][key]["dtype"]
        if "bfloat16" in stored and arr.dtype == np.uint16:
            import ml_dtypes

            arr = arr.view(ml_dtypes.bfloat16)
        want = jax.numpy.dtype(leaf.dtype) if hasattr(leaf, "dtype") else None
        if want is not None and arr.dtype != want:
            arr = arr.astype(want)
        if sh is not None:
            out.append(jax.device_put(arr, sh))
        else:
            out.append(jax.numpy.asarray(arr))
    return jax.tree_util.tree_structure(
        jax.tree.map(lambda x: 0, like)).unflatten(out)


class CheckpointManager:
    """Keeps the last ``keep`` checkpoints; optional async saves."""

    def __init__(self, directory: str | pathlib.Path, keep: int = 3,
                 async_save: bool = True):
        self.directory = pathlib.Path(directory)
        self.keep = keep
        self.async_save = async_save
        self._pending: threading.Thread | None = None
        self._lock = threading.Lock()

    def save(self, step: int, state, extra: dict | None = None) -> None:
        if self.async_save:
            # Snapshot to host synchronously, write on a worker thread.
            host_state = jax.tree.map(
                lambda x: np.asarray(jax.device_get(x)), state)
            self.wait()
            self._pending = threading.Thread(
                target=self._write, args=(step, host_state, extra),
                daemon=True)
            self._pending.start()
        else:
            self._write(step, state, extra)

    def _write(self, step, state, extra):
        with self._lock:
            save_state(self.directory, step, state, extra)
            self._gc()

    def _gc(self):
        steps = sorted(
            int(m.group(1)) for p in self.directory.iterdir()
            if (m := re.match(r"step_(\d+)$", p.name)))
        for s in steps[:-self.keep]:
            shutil.rmtree(self.directory / f"step_{s:08d}",
                          ignore_errors=True)

    def wait(self):
        if self._pending is not None:
            self._pending.join()
            self._pending = None

    def latest(self) -> int | None:
        self.wait()
        return latest_step(self.directory)

    def restore(self, like, step: int | None = None, shardings=None):
        step = step if step is not None else self.latest()
        if step is None:
            raise FileNotFoundError(f"no checkpoints in {self.directory}")
        return restore_state(self.directory, step, like, shardings), step
