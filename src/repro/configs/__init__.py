"""Assigned-architecture registry: ``--arch <id>`` resolution.

Ten architectures from the public pool (see each module's docstring for the
source citation), plus the reduced variants used by CPU smoke tests.
"""

from __future__ import annotations

import importlib

from repro.models.config import ModelConfig

__all__ = ["ARCHS", "get_config", "list_archs"]

# arch id (CLI form) -> module name
ARCHS = {
    "starcoder2-15b": "starcoder2_15b",
    "qwen3-14b": "qwen3_14b",
    "tinyllama-1.1b": "tinyllama_1_1b",
    "nemotron-4-15b": "nemotron_4_15b",
    "kimi-k2-1t-a32b": "kimi_k2_1t_a32b",
    "qwen3-moe-235b-a22b": "qwen3_moe_235b_a22b",
    "qwen2-vl-72b": "qwen2_vl_72b",
    "mamba2-370m": "mamba2_370m",
    "jamba-1.5-large-398b": "jamba_1_5_large_398b",
    "seamless-m4t-large-v2": "seamless_m4t_large_v2",
}


def get_config(arch: str, *, reduced: bool = False) -> ModelConfig:
    if arch not in ARCHS:
        raise KeyError(f"unknown arch {arch!r}; known: {sorted(ARCHS)}")
    mod = importlib.import_module(f"repro.configs.{ARCHS[arch]}")
    cfg = mod.config()
    return cfg.reduced() if reduced else cfg


def list_archs() -> list[str]:
    return list(ARCHS)
