"""jamba-1.5-large-398b — Mamba+attention hybrid MoE [arXiv:2403.19887; hf].

72L d_model=8192 64H (GQA kv=8) d_ff=24576 vocab=65536, MoE 16e top-2,
1 attention : 7 mamba interleave, MoE every other layer.
"""
from repro.models.config import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="jamba-1.5-large-398b", family="hybrid",
        n_layers=72, d_model=8192, n_heads=64, n_kv_heads=8,
        d_ff=24576, vocab=65536, head_dim=128,
        rope_theta=1e4, activation="silu", glu=True,
        n_experts=16, top_k=2,
        ssm_state=128, ssm_conv=4, ssm_head_dim=64, ssm_expand=2,
        hybrid_period=8, hybrid_attn_index=3, hybrid_moe_every=2,
        microbatches=8,
    )
