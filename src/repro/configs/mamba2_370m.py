"""mamba2-370m — SSD state-space model [arXiv:2405.21060; unverified].

48L d_model=1024 (attention-free), ssm_state=128, vocab=50280.
"""
from repro.models.config import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="mamba2-370m", family="ssm",
        n_layers=48, d_model=1024, n_heads=0, n_kv_heads=0,
        d_ff=0, vocab=50280, head_dim=64,
        ssm_state=128, ssm_conv=4, ssm_head_dim=64, ssm_expand=2,
        tie_embeddings=True,
    )
