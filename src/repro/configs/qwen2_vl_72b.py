"""qwen2-vl-72b — VLM backbone, M-RoPE [arXiv:2409.12191; hf].

80L d_model=8192 64H (GQA kv=8) d_ff=29568 vocab=152064.
Vision frontend is a stub: input_specs() provides precomputed patch
embeddings + 3D M-RoPE positions (per the assignment brief).
"""
from repro.models.config import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="qwen2-vl-72b", family="vlm",
        n_layers=80, d_model=8192, n_heads=64, n_kv_heads=8,
        d_ff=29568, vocab=152064, head_dim=128,
        rope_theta=1e6, use_mrope=True, activation="silu", glu=True,
        microbatches=4,
    )
