"""qwen3-14b — dense GQA with qk-norm [hf:Qwen/Qwen3-8B family; hf].

40L d_model=5120 40H (GQA kv=8) d_ff=17408 vocab=151936 — qk_norm, GQA.
"""
from repro.models.config import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="qwen3-14b", family="dense",
        n_layers=40, d_model=5120, n_heads=40, n_kv_heads=8,
        d_ff=17408, vocab=151936, head_dim=128,
        rope_theta=1e6, qk_norm=True, activation="silu", glu=True,
        pad_heads_to=48,   # 40 heads do not divide the 16-way model axis;
        # lowered with 8 zero-masked heads (output-exact, DESIGN.md)
    )
