"""qwen3-moe-235b-a22b — 128-expert top-8 MoE [hf:Qwen/Qwen3 family; hf].

94L d_model=4096 64H (GQA kv=4) expert d_ff=1536 vocab=151936,
MoE 128e top-8.
"""
from repro.models.config import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="qwen3-moe-235b-a22b", family="moe",
        n_layers=94, d_model=4096, n_heads=64, n_kv_heads=4,
        d_ff=1536, vocab=151936, head_dim=128,
        rope_theta=1e6, qk_norm=True, activation="silu", glu=True,
        n_experts=128, top_k=8,
        microbatches=4,
    )
