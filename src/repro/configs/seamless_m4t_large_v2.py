"""seamless-m4t-large-v2 — enc-dec multimodal [arXiv:2308.11596; hf].

24L encoder + 24L decoder, d_model=1024 16H (MHA kv=16) d_ff=8192
vocab=256206.  Modality frontend is a stub: input_specs() provides
precomputed frame embeddings (per the assignment brief).
"""
from repro.models.config import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="seamless-m4t-large-v2", family="encdec",
        n_layers=24, n_encoder_layers=24,
        d_model=1024, n_heads=16, n_kv_heads=16,
        d_ff=8192, vocab=256206, head_dim=64,
        rope_theta=1e4, activation="gelu", glu=False,
    )
