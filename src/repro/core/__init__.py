"""DFlow core — the paper's contribution (dataflow workflow execution).

Layers:

* :mod:`repro.core.dag`          — workflow DAG model + parser.
* :mod:`repro.core.partition`    — Global-Scheduler DAG partitioning.
* :mod:`repro.core.dstore`       — real threaded DStore (Table 1 API).
* :mod:`repro.core.router`       — DShard: per-node DStore shards behind
  local routing tables + a coordinator (1-hop transfers, transport tiers).
* :mod:`repro.core.stream`       — DStream: chunked pipelined Get/Put
  (beyond-paper; overlaps producer writes with consumer reads).
* :mod:`repro.core.dscheduler`   — real threaded DScheduler + engine.
* :mod:`repro.core.serve`        — DServe: concurrent multi-instance
  serving with explicit container pools (cold boot / keep-alive TTL /
  dataflow-triggered prewarm) and open-loop load generation.
* :mod:`repro.core.sim*`         — deterministic cluster simulator used by
  every paper-figure experiment (CFlow/FaaSFlow/.../KNIX baselines).
* :mod:`repro.core.workloads`    — paper benchmarks (WC/FP/Cyc/Epi/Gen/Soy).
* :mod:`repro.core.experiments`  — open/closed-loop drivers + metrics.
* :mod:`repro.core.lint`         — DCheck static workflow linter (stable
  DF diagnostic codes; ``python -m repro.lint`` CLI).
* :mod:`repro.core.check`        — DCheck dynamic invariant checker
  (trace recording + offline happens-before/immutability validation).
* :mod:`repro.core.scale`        — DScale: rate-estimating pool
  autoscaler, SLO-aware prewarm budgets (container-seconds), and
  inhomogeneous (diurnal / bursty) arrival generators.
* :mod:`repro.core.obs`          — DScope observability: MetricsRegistry,
  per-request span Tracer (JSONL/Perfetto exporters), plan-vs-actual
  attribution, and the standardized ``dflow-bench/v1`` schema
  (``python -m repro.obs`` CLI).
"""

from .check import (TraceChecker, TraceEvent, TraceRecorder, Violation,
                    content_digest)
from .dag import FunctionSpec, Workflow, parse_workflow
from .dscheduler import (DFlowEngine, GlobalScheduler, InstanceRun,
                         dataflow_initial_frontier, dataflow_next_frontier)
from .dstore import (DStore, DataDirectoryService, ImmutabilityError,
                     LocalStore, Transport)
from .lint import (Diagnostic, WorkflowLintError, check_workflow, lint,
                   lint_workflow)
from .obs import (MetricsRegistry, Span, Tracer, attribute,
                  bench_doc, bench_metric, compare_docs, plan_attribution,
                  read_spans_jsonl, to_chrome_trace, write_spans_jsonl)
from .experiments import (ExperimentResult, cold_start_latency,
                          percentile, run_closed_loop, run_open_loop)
from .partition import cut_bytes, partition_workflow, stage_node
from .router import (Coordinator, RoutingTable, ShardedDStore,
                     TieredTransport, routes_from_plan, static_routes)
from .scale import (AutoscalerConfig, PoolAutoscaler, PoolSpec,
                    PrewarmBudget, PrewarmGrant, RateEstimator,
                    ScaleDecision, allocate_prewarms, bursty_arrivals,
                    diurnal_arrivals)
from .serve import (ContainerPool, ContainerService, DServe, Lease,
                    ServeReport, poisson_arrivals, trace_arrivals)
from .sim_systems import SYSTEMS, make_system
from .simcluster import SimConfig
from .stream import StreamBroken, StreamReader, StreamWriter
from .workloads import BENCHMARKS, make_workflow

__all__ = [
    "FunctionSpec", "Workflow", "parse_workflow",
    "TraceChecker", "TraceEvent", "TraceRecorder", "Violation",
    "content_digest", "ImmutabilityError",
    "Diagnostic", "WorkflowLintError", "check_workflow", "lint",
    "lint_workflow",
    "DFlowEngine", "GlobalScheduler", "InstanceRun",
    "dataflow_initial_frontier", "dataflow_next_frontier",
    "DStore", "DataDirectoryService", "LocalStore", "Transport",
    "StreamBroken", "StreamReader", "StreamWriter",
    "ContainerPool", "ContainerService", "DServe", "Lease", "ServeReport",
    "poisson_arrivals", "trace_arrivals",
    "AutoscalerConfig", "PoolAutoscaler", "PoolSpec",
    "PrewarmBudget", "PrewarmGrant", "RateEstimator", "ScaleDecision",
    "allocate_prewarms", "bursty_arrivals", "diurnal_arrivals",
    "ExperimentResult", "cold_start_latency", "percentile",
    "run_closed_loop", "run_open_loop",
    "cut_bytes", "partition_workflow", "stage_node",
    "Coordinator", "RoutingTable", "ShardedDStore", "TieredTransport",
    "routes_from_plan", "static_routes",
    "SYSTEMS", "make_system", "SimConfig",
    "BENCHMARKS", "make_workflow",
    "MetricsRegistry", "Span", "Tracer", "attribute",
    "bench_doc", "bench_metric", "compare_docs", "plan_attribution",
    "read_spans_jsonl", "to_chrome_trace", "write_spans_jsonl",
]
