"""DCheck dynamic half — dataflow trace recording + invariant checking.

The §3.3 design note that "data in DStore is immutable" is carrying far
more weight than one sentence suggests: it is what makes duplicate
(straggler) re-execution safe, what lets a Get trust *any* replica, and
what allows instance-scoped eviction to reclaim keys without a reader
census.  This module makes those load-bearing invariants checkable:

* :class:`TraceRecorder` — a thread-safe event log with a global logical
  clock.  :class:`~repro.core.dstore.DStore` and
  :class:`~repro.core.stream.StreamDirectory` carry a *zero-cost-when-off*
  hook (``if self._tracer is not None``): attaching a recorder turns every
  put / metadata publish / get / chunk publish / evict / node failure into
  a :class:`TraceEvent`.  Events carry a content digest where the value is
  digestable, so equality claims are checkable offline.
* **Stress mode** — the recorder optionally injects tiny seeded random
  sleeps at instrumentation points (``stress=<seed>``), perturbing thread
  interleavings exactly where the data plane's ordering decisions are
  made, so a test run actually explores schedules instead of re-observing
  the same lucky one.
* :class:`TraceChecker` — offline replay of a recorded trace verifying
  four invariant classes:

  - **ordering** ("happens-before"): no ``get_return`` yields a value
    that was never made available (put / replica / publish) earlier in
    the trace, and the returned bytes match a published digest;
  - **immutability** (single producer): every write of one key carries
    one content digest — divergent co-writes are flagged;
  - **eviction safety**: no ``evict`` of a key while a reader is
    in-flight (``get_block`` without a matching return/fail);
  - **chunk sequence**: a closed stream's chunk indices are exactly
    ``0..total-1``, closes agree on ``total``, and duplicate chunk
    publishes are byte-identical;
  - **routing** (DShard, see router.py): every routed Get resolves in
    exactly one hop (``route`` events with ``hops != 1`` — a stale-table
    misroute or directory bounce — are hard failures), and it resolves at
    the key's producing shard (the home announced by the put/publish
    events' ``src``).

Recording points sit *before* the mutation they describe (inside the same
lock that orders the mutation), so trace order is a faithful linearization:
bytes can never be observed by a reader before the event that announces
them was recorded.
"""

from __future__ import annotations

import hashlib
import threading
import time
from dataclasses import dataclass, field
from typing import Any, Iterable

__all__ = ["TraceEvent", "TraceRecorder", "Violation", "TraceChecker",
           "PlanConformance", "content_digest"]


def content_digest(value: Any) -> str | None:
    """Stable hex digest of a value's content, or None when the value is
    opaque (no reliable byte representation — e.g. objects whose repr
    embeds a memory address, which would make identical re-executions
    look divergent)."""
    h = hashlib.blake2b(digest_size=16)
    if _feed(h, value):
        return h.hexdigest()
    return None


def _feed(h, value: Any) -> bool:
    if isinstance(value, (bytes, bytearray, memoryview)):
        h.update(b"b")
        h.update(bytes(value))
        return True
    if isinstance(value, str):
        h.update(b"s")
        h.update(value.encode())
        return True
    if value is None or isinstance(value, (bool, int, float)):
        h.update(repr(value).encode())
        return True
    if isinstance(value, (tuple, list)):
        h.update(b"l%d" % len(value))
        return all(_feed(h, v) for v in value)
    if isinstance(value, dict):
        h.update(b"d%d" % len(value))
        try:
            items = sorted(value.items())
        except TypeError:
            return False
        return all(_feed(h, k) and _feed(h, v) for k, v in items)
    tobytes = getattr(value, "tobytes", None)   # numpy/jax arrays
    if tobytes is not None:
        try:
            h.update(b"a")
            h.update(repr(getattr(value, "dtype", "")).encode())
            h.update(repr(getattr(value, "shape", "")).encode())
            h.update(tobytes())
            return True
        except Exception:       # pragma: no cover - exotic array types
            return False
    return False


@dataclass(frozen=True)
class TraceEvent:
    """One recorded data-plane action, ordered by a global logical clock."""

    clock: int
    kind: str         # put | publish | replica | get_block | get_return |
    #                   get_fail | put_chunk | stream_close | stream_abort |
    #                   evict | drop | fail_node
    key: str = ""
    node: str = ""
    idx: int | None = None           # chunk index (put_chunk)
    size: int = 0
    digest: str | None = None        # content digest; None = opaque value
    src: str = ""                    # DShard: key's home shard (put/route)
    tier: str = ""                   # DShard transport tier (route events)
    hops: int = 0                    # DShard: shard contacts for one Get

    def __str__(self) -> str:        # pragma: no cover - debugging aid
        extra = f"[{self.idx}]" if self.idx is not None else ""
        return (f"@{self.clock} {self.kind} {self.key}{extra} "
                f"({self.node})")


class TraceRecorder:
    """Append-only, thread-safe event log with optional schedule stress.

    ``stress`` seeds an LCG that injects a 0–1 ms sleep at roughly one in
    three instrumentation points.  The sleeps land *inside* the data
    plane's critical sections and wait loops — exactly where a different
    thread interleaving changes which replica a Get sees or whether a
    publish beats a block — so repeated runs with different seeds explore
    genuinely different schedules.
    """

    def __init__(self, *, stress: int | None = None,
                 stress_max_s: float = 0.001):
        self._lock = threading.Lock()
        self._events: list[TraceEvent] = []
        self._clock = 0
        self._stress = None if stress is None else (
            (stress * 2654435761 + 0x9E3779B9) & 0xFFFFFFFF)
        self._stress_max = float(stress_max_s)

    def record(self, kind: str, key: str = "", node: str = "", *,
               idx: int | None = None, size: int = 0,
               digest: str | None = None, src: str = "",
               tier: str = "", hops: int = 0) -> TraceEvent:
        delay = 0.0
        with self._lock:
            self._clock += 1
            ev = TraceEvent(self._clock, kind, key, node,
                            idx=idx, size=size, digest=digest,
                            src=src, tier=tier, hops=hops)
            self._events.append(ev)
            if self._stress is not None:
                self._stress = (1103515245 * self._stress + 12345) \
                    & 0x7FFFFFFF
                u = self._stress / 0x7FFFFFFF
                if u < 0.34:
                    delay = u * 3.0 * self._stress_max
        if delay:
            time.sleep(delay)
        return ev

    def tick(self) -> int:
        """Advance and return the logical clock without recording an
        event — lets DScope spans share this ordering domain so span
        ``seq`` values interleave consistently with trace events."""
        with self._lock:
            self._clock += 1
            return self._clock

    def events(self) -> list[TraceEvent]:
        with self._lock:
            return list(self._events)

    def clear(self) -> None:
        with self._lock:
            self._events.clear()

    def __len__(self) -> int:
        with self._lock:
            return len(self._events)


@dataclass(frozen=True)
class Violation:
    """One invariant breach found by :class:`TraceChecker`."""

    invariant: str       # ordering | immutability | eviction |
    #                      chunk_sequence | routing
    message: str
    events: tuple[TraceEvent, ...] = ()

    def __str__(self) -> str:
        return f"[{self.invariant}] {self.message}"


# Events that make a key's value observable to readers.
_AVAILABILITY = ("put", "replica", "publish")


@dataclass
class _KeyState:
    digests: set[str] = field(default_factory=set)   # non-opaque writes
    available: bool = False
    opaque_writes: int = 0
    in_flight: dict[str, int] = field(default_factory=dict)  # node -> gets
    first_write: TraceEvent | None = None
    home: str = ""       # DShard: producing shard (from put/publish src)


class TraceChecker:
    """Offline replay of a recorded trace against the DFlow invariants.

    ``check`` returns every violation found (empty list = trace is
    consistent).  The checker is conservative about opaque values (digest
    None): it never claims divergence it cannot prove.
    """

    def check(self, events: Iterable[TraceEvent]) -> list[Violation]:
        out: list[Violation] = []
        keys: dict[str, _KeyState] = {}
        # stream key -> {idx: (digest, event)}; closes: key -> totals
        chunks: dict[str, dict[int, tuple[str | None, TraceEvent]]] = {}
        closes: dict[str, list[TraceEvent]] = {}
        aborted: set[str] = set()

        def st(key: str) -> _KeyState:
            return keys.setdefault(key, _KeyState())

        def judge_stream(key: str) -> None:
            """Coverage/total checks for one completed stream generation."""
            close_evs = closes[key]
            totals = {e.size for e in close_evs}
            if len(totals) > 1:
                out.append(Violation(
                    "chunk_sequence",
                    f"stream {key!r} closed with divergent totals "
                    f"{sorted(totals)}", tuple(close_evs)))
                return
            total = totals.pop()
            idxs = set(chunks.get(key, ()))
            beyond = {i for i in idxs if i >= total}
            missing = set(range(total)) - idxs
            if beyond:
                out.append(Violation(
                    "chunk_sequence",
                    f"stream {key!r} published chunk(s) {sorted(beyond)} "
                    f"at/after its close total {total}",
                    tuple(chunks[key][i][1] for i in sorted(beyond))))
            if missing:
                out.append(Violation(
                    "chunk_sequence",
                    f"stream {key!r} closed at total {total} but "
                    f"chunk(s) {sorted(missing)} were never published",
                    tuple(close_evs)))

        for ev in sorted(events, key=lambda e: e.clock):
            s = st(ev.key) if ev.key else None
            if ev.kind in _AVAILABILITY:
                s.available = True
                if ev.src:
                    s.home = ev.src      # last announced home shard wins
                if s.first_write is None:
                    s.first_write = ev
                if ev.digest is None:
                    s.opaque_writes += 1
                else:
                    s.digests.add(ev.digest)
                    # -- immutability: all writes of one key agree.
                    if len(s.digests) > 1:
                        out.append(Violation(
                            "immutability",
                            f"key {ev.key!r} written with divergent "
                            f"content ({len(s.digests)} distinct "
                            f"digests); first write {s.first_write}",
                            (s.first_write, ev)))
            elif ev.kind == "get_block":
                s.in_flight[ev.node] = s.in_flight.get(ev.node, 0) + 1
            elif ev.kind in ("get_return", "get_fail"):
                n = s.in_flight.get(ev.node, 0)
                if n > 0:
                    s.in_flight[ev.node] = n - 1
                if ev.kind == "get_return":
                    # -- ordering: the value must have been made
                    # available earlier in the trace, with matching
                    # content where both sides are digestable.
                    if not s.available:
                        out.append(Violation(
                            "ordering",
                            f"Get({ev.key!r}) on {ev.node!r} returned at "
                            f"clock {ev.clock} but no put/publish of "
                            "that key precedes it", (ev,)))
                    elif (ev.digest is not None and s.digests
                          and ev.digest not in s.digests):
                        out.append(Violation(
                            "ordering",
                            f"Get({ev.key!r}) returned bytes that match "
                            "no published content for that key "
                            "(stale or torn read)", (ev,)))
            elif ev.kind == "route":
                # -- routing (DShard): a routed Get contacts exactly one
                # shard — the key's statically-known producing shard.
                if ev.hops != 1:
                    out.append(Violation(
                        "routing",
                        f"Get({ev.key!r}) on {ev.node!r} resolved in "
                        f"{ev.hops} hop(s) (stale-table misroute or "
                        "directory bounce); DShard requires exactly 1",
                        (ev,)))
                if ev.src and s.home and ev.src != s.home:
                    out.append(Violation(
                        "routing",
                        f"Get({ev.key!r}) resolved at shard {ev.src!r} "
                        f"but the key's producing shard is {s.home!r}",
                        (ev,)))
            elif ev.kind == "put_chunk":
                rec = chunks.setdefault(ev.key, {})
                prev = rec.get(ev.idx)
                if prev is None:
                    rec[ev.idx] = (ev.digest, ev)
                else:
                    pd, pev = prev
                    # -- chunk co-writes must be byte-identical.
                    if pd is not None and ev.digest is not None \
                            and pd != ev.digest:
                        out.append(Violation(
                            "chunk_sequence",
                            f"stream {ev.key!r} chunk {ev.idx} co-written "
                            "with divergent bytes", (pev, ev)))
            elif ev.kind == "stream_close":
                closes.setdefault(ev.key, []).append(ev)
            elif ev.kind == "stream_abort":
                aborted.add(ev.key)
            elif ev.kind == "evict":
                # -- eviction safety: no reclaim under an in-flight read.
                readers = sum(s.in_flight.values())
                if readers:
                    out.append(Violation(
                        "eviction",
                        f"key {ev.key!r} evicted at clock {ev.clock} "
                        f"with {readers} reader(s) still in flight",
                        (ev,)))
                # Eviction ends the key's lifetime: a later instance may
                # legitimately reuse the name (serving restarts instance
                # numbering per run), so judge any completed stream
                # generation now and reset the key's state.
                if ev.key in closes:
                    judge_stream(ev.key)
                chunks.pop(ev.key, None)
                closes.pop(ev.key, None)
                aborted.discard(ev.key)
                keys[ev.key] = _KeyState()
            elif ev.kind in ("drop", "fail_node"):
                # Fault path: replicas vanish; recovery re-publishes.
                if s is not None:
                    s.available = False

        # -- chunk-sequence closure checks (end of trace).
        for key in closes:
            judge_stream(key)
        # Streams with chunks but neither close nor abort leaked.
        for key in chunks:
            if key not in closes and key not in aborted:
                out.append(Violation(
                    "chunk_sequence",
                    f"stream {key!r} published chunks but was never "
                    "closed or aborted", ()))
        return out

    def check_or_raise(self, events: Iterable[TraceEvent]) -> None:
        violations = self.check(events)
        if violations:
            lines = "\n  ".join(str(v) for v in violations)
            raise AssertionError(
                f"trace violates {len(violations)} dataflow "
                f"invariant(s):\n  {lines}")


# Container-lifecycle events (recorded by serve.ContainerService, key =
# image) that change the count of unleased — bootable-into — containers.
_CONTAINER_DELTA = {"prewarm_boot": 1, "container_release": 1,
                    "warm_hit": -1, "prewarm_hit": -1, "container_evict": -1}


class PlanConformance:
    """Replay a recorded trace against a static :class:`~repro.core.plan.
    WorkflowPlan` (duck-typed: anything with ``eviction_reads``) and flag
    dynamic events that contradict a static claim.

    * ``plan_eviction`` — a read (Get or replica pull) of a planned key
      after its evict event, or more ``get_return``\\ s of a key than the
      plan's statically-derived read count: either means the liveness
      analysis under-counted consumers, so the "provably-safe" eviction
      was not safe.  An evict *before* the count is reached is legal —
      instance-scoped eviction mops up at completion.
    * ``plan_prewarm`` — a cold boot paid while an unleased container of
      the same (node, image) existed in the trace: the boot the prewarm
      schedule issued was available, so the request path should not have
      paid a cold start.

    ``instances`` lists the key-namespace instances the plan was applied
    to (``""`` = un-namespaced single run); container events are global.
    """

    def __init__(self, plan):
        self.plan = plan

    def check(self, events: Iterable[TraceEvent], *,
              instances: Iterable[str] = ("",)) -> list[Violation]:
        planned: dict[str, int] = {}
        for inst in instances:
            prefix = f"{inst}:" if inst else ""
            for k, n in self.plan.eviction_reads.items():
                planned[prefix + k] = n
        out: list[Violation] = []
        seen: dict[str, int] = {}
        evicted: dict[str, TraceEvent] = {}
        unleased: dict[tuple[str, str], int] = {}
        for ev in sorted(events, key=lambda e: e.clock):
            if ev.kind in ("get_block", "get_return", "replica"):
                if ev.key not in planned:
                    continue
                first_evict = evicted.get(ev.key)
                if first_evict is not None:
                    out.append(Violation(
                        "plan_eviction",
                        f"key {ev.key!r} observed by {ev.kind} at clock "
                        f"{ev.clock} after its planned eviction at clock "
                        f"{first_evict.clock} — the liveness analysis "
                        "missed a consumer", (first_evict, ev)))
                if ev.kind == "get_return":
                    seen[ev.key] = seen.get(ev.key, 0) + 1
                    if seen[ev.key] > planned[ev.key]:
                        out.append(Violation(
                            "plan_eviction",
                            f"key {ev.key!r} returned {seen[ev.key]} "
                            f"Gets but the plan claims exactly "
                            f"{planned[ev.key]} reads", (ev,)))
            elif ev.kind == "evict":
                if ev.key in planned:
                    evicted.setdefault(ev.key, ev)
            elif ev.kind == "cold_boot":
                n = unleased.get((ev.node, ev.key), 0)
                if n > 0:
                    out.append(Violation(
                        "plan_prewarm",
                        f"cold boot of {ev.key!r} on {ev.node!r} at clock "
                        f"{ev.clock} while {n} unleased container(s) "
                        "existed — the prewarm schedule had hidden this "
                        "boot and the request path paid it anyway", (ev,)))
            elif ev.kind in _CONTAINER_DELTA:
                kk = (ev.node, ev.key)
                unleased[kk] = max(
                    0, unleased.get(kk, 0) + _CONTAINER_DELTA[ev.kind])
        return out

    def check_or_raise(self, events: Iterable[TraceEvent], *,
                       instances: Iterable[str] = ("",)) -> None:
        violations = self.check(events, instances=instances)
        if violations:
            lines = "\n  ".join(str(v) for v in violations)
            raise AssertionError(
                f"trace contradicts the plan in {len(violations)} "
                f"place(s):\n  {lines}")
