"""Workflow DAG model + parser (paper §3.1, Figure 5 "DAG Parser").

A serverless workflow is a DAG whose nodes are functions and whose edges are
*data* dependencies: an edge u→v exists iff some output key of u is an input
key of v.  This is the representation every scheduler (DFlow's DScheduler and
all controlflow baselines) consumes.

The parser accepts the paper's ``workflow.yaml`` shape::

    name: wordcount
    functions:
      split:
        inputs: [corpus]            # keys not produced by any function are
        outputs: [shard.0, shard.1] # workflow inputs (external data)
        exec_time: 0.4              # seconds (simulator)
        output_sizes: {shard.0: 8MB, shard.1: 8MB}
      count:
        foreach: 2                  # expand into count.0, count.1 ...
        inputs: [shard.$i]
        outputs: [wc.$i]
        ...
      merge:
        inputs: [wc.*]              # glob over produced keys
        outputs: [result]

``foreach`` (paper §1: "supports complex workflows involving constructs such
as 'foreach'") expands a template into N concrete functions with ``$i``
substituted.  ``inputs`` may use a trailing ``*`` glob which is resolved
against the union of all produced keys after expansion.
"""

from __future__ import annotations

import io
import re
from dataclasses import dataclass, field, replace
from typing import Any, Callable, Iterable, Mapping

__all__ = [
    "FunctionSpec",
    "Workflow",
    "parse_workflow",
    "parse_size",
]

_SIZE_RE = re.compile(r"^\s*([0-9]*\.?[0-9]+)\s*([KMGT]?B?)\s*$", re.I)
_SIZE_MULT = {
    "": 1, "B": 1,
    "KB": 1 << 10, "K": 1 << 10,
    "MB": 1 << 20, "M": 1 << 20,
    "GB": 1 << 30, "G": 1 << 30,
    "TB": 1 << 40, "T": 1 << 40,
}


def parse_size(v: int | float | str) -> int:
    """'8MB' → 8388608.  Ints/floats pass through as bytes."""
    if isinstance(v, (int, float)):
        return int(v)
    m = _SIZE_RE.match(v)
    if not m:
        raise ValueError(f"unparsable size: {v!r}")
    return int(float(m.group(1)) * _SIZE_MULT[m.group(2).upper()])


@dataclass(frozen=True)
class FunctionSpec:
    """One node of the workflow DAG.

    ``fn`` is the real callable (threaded engine); the simulator uses
    ``exec_time``/``output_sizes``/``cold_start`` instead and never calls it.

    DStream (chunked pipelining, see :mod:`repro.core.stream`):
    ``stream_inputs`` names inputs delivered to ``fn`` as blocking chunk
    iterators instead of whole values; ``stream_outputs`` names outputs the
    engine publishes chunk-by-chunk — ``fn`` may return bytes or any
    iterable/generator of byte chunks for those keys, and downstream
    consumers start pulling while this function is still emitting.
    """

    name: str
    inputs: tuple[str, ...] = ()
    outputs: tuple[str, ...] = ()
    fn: Callable[..., Mapping[str, Any]] | None = None
    exec_time: float = 0.1           # seconds of pure compute (warm)
    output_sizes: Mapping[str, int] = field(default_factory=dict)
    cold_start: float = 0.5          # container init if no warm container
    cpu: float = 1.0                 # cores occupied while running
    stream_inputs: tuple[str, ...] = ()    # consumed as chunk iterators
    stream_outputs: tuple[str, ...] = ()   # produced via put_stream
    chunk_size: int = 1 << 18              # streaming chunk size (bytes)

    def __post_init__(self) -> None:
        object.__setattr__(self, "stream_inputs", tuple(self.stream_inputs))
        object.__setattr__(self, "stream_outputs", tuple(self.stream_outputs))
        bad = set(self.stream_inputs) - set(self.inputs)
        if bad:
            raise ValueError(
                f"{self.name}: stream_inputs {sorted(bad)} not in inputs")
        bad = set(self.stream_outputs) - set(self.outputs)
        if bad:
            raise ValueError(
                f"{self.name}: stream_outputs {sorted(bad)} not in outputs")
        if self.chunk_size <= 0:
            raise ValueError(f"{self.name}: chunk_size must be positive")
        # output_sizes naming a non-output key used to be silently ignored
        # (size_of fell back to the 1 MB default) — a typo'd key made every
        # simulator transfer-time estimate wrong with no signal.
        bad = set(self.output_sizes) - set(self.outputs)
        if bad:
            raise ValueError(
                f"{self.name}: output_sizes for non-output keys "
                f"{sorted(bad)} (outputs: {sorted(self.outputs)})")

    def size_of(self, key: str) -> int:
        return int(self.output_sizes.get(key, 1 << 20))  # default 1 MB


class Workflow:
    """Immutable DAG of :class:`FunctionSpec` with derived dependency maps."""

    def __init__(self, name: str, functions: Iterable[FunctionSpec],
                 external_inputs: Mapping[str, int] | None = None):
        self.name = name
        self.functions: dict[str, FunctionSpec] = {}
        for f in functions:
            if f.name in self.functions:
                raise ValueError(f"duplicate function {f.name!r}")
            self.functions[f.name] = f

        self.producer: dict[str, str] = {}      # data key -> producing fn
        for f in self.functions.values():
            for k in f.outputs:
                if k in self.producer:
                    raise ValueError(
                        f"key {k!r} produced by both {self.producer[k]!r} "
                        f"and {f.name!r} (DStore data is immutable)")
                self.producer[k] = f.name

        # Keys consumed but never produced are workflow (external) inputs.
        # The explicitly declared set is kept separately so the linter can
        # flag typo'd input keys that silently default into externals.
        self.declared_external: frozenset[str] = frozenset(
            external_inputs or ())
        self.external_inputs: dict[str, int] = dict(external_inputs or {})
        for f in self.functions.values():
            for k in f.inputs:
                if k not in self.producer:
                    self.external_inputs.setdefault(k, 1 << 20)

        # fn -> set of fn edges (dedup'd), from data dependencies.
        self.successors: dict[str, tuple[str, ...]] = {}
        self.predecessors: dict[str, tuple[str, ...]] = {}
        succ: dict[str, list[str]] = {n: [] for n in self.functions}
        pred: dict[str, list[str]] = {n: [] for n in self.functions}
        for f in self.functions.values():
            for k in f.inputs:
                p = self.producer.get(k)
                if p is not None and p != f.name:
                    if f.name not in succ[p]:
                        succ[p].append(f.name)
                    if p not in pred[f.name]:
                        pred[f.name].append(p)
        self.successors = {n: tuple(v) for n, v in succ.items()}
        self.predecessors = {n: tuple(v) for n, v in pred.items()}

        self.entry_points: tuple[str, ...] = tuple(
            n for n in self.functions if not self.predecessors[n])
        self.exit_points: tuple[str, ...] = tuple(
            n for n in self.functions if not self.successors[n])
        self.topo_order: tuple[str, ...] = self._toposort()

    # ------------------------------------------------------------------
    def _toposort(self) -> tuple[str, ...]:
        indeg = {n: len(self.predecessors[n]) for n in self.functions}
        ready = sorted(n for n, d in indeg.items() if d == 0)
        order: list[str] = []
        while ready:
            n = ready.pop(0)
            order.append(n)
            newly = []
            for s in self.successors[n]:
                indeg[s] -= 1
                if indeg[s] == 0:
                    newly.append(s)
            # Keep determinism: stable-sorted insertion.
            for s in sorted(newly):
                ready.append(s)
        if len(order) != len(self.functions):
            cyc = [n for n, d in indeg.items() if d > 0]
            raise ValueError(f"workflow {self.name!r} has a cycle: {cyc}")
        return tuple(order)

    # ------------------------------------------------------------------
    def __len__(self) -> int:
        return len(self.functions)

    def __repr__(self) -> str:  # pragma: no cover - debugging aid
        return (f"Workflow({self.name!r}, {len(self)} fns, "
                f"{sum(len(s) for s in self.successors.values())} edges)")

    def critical_path_time(self) -> float:
        """Lower bound on makespan: longest exec_time chain (no comms)."""
        dist: dict[str, float] = {}
        for n in self.topo_order:
            base = max((dist[p] for p in self.predecessors[n]), default=0.0)
            dist[n] = base + self.functions[n].exec_time
        return max(dist.values()) if dist else 0.0

    def total_exec_time(self) -> float:
        return sum(f.exec_time for f in self.functions.values())

    def key_bytes(self, key: str) -> int:
        """Declared size of ``key`` regardless of who produced it.

        The one sizing authority shared by the partitioner's cut model
        and the planner's transfer matrix, so the two can never disagree
        (stream-declared keys included: chunking changes the transfer
        granularity, not the byte count).
        """
        p = self.producer.get(key)
        if p is not None:
            return self.functions[p].size_of(key)
        return self.external_inputs.get(key, 1 << 20)

    def with_functions(self, **overrides: FunctionSpec) -> "Workflow":
        fns = [overrides.get(n, f) for n, f in self.functions.items()]
        return Workflow(self.name, fns, self.external_inputs)


# ----------------------------------------------------------------------
# Parser
# ----------------------------------------------------------------------

def _expand_foreach(name: str, spec: Mapping[str, Any]) -> list[tuple[str, dict]]:
    n = int(spec.get("foreach", 0))
    if not n:
        return [(name, dict(spec))]
    out = []
    for i in range(n):
        sub = {}
        for k, v in spec.items():
            if k == "foreach":
                continue
            sub[k] = _subst(v, i)
        out.append((f"{name}.{i}", sub))
    return out


def _subst(v: Any, i: int) -> Any:
    if isinstance(v, str):
        return v.replace("$i", str(i))
    if isinstance(v, list):
        return [_subst(x, i) for x in v]
    if isinstance(v, dict):
        return {_subst(k, i): _subst(x, i) for k, x in v.items()}
    return v


def parse_workflow(doc: Mapping[str, Any] | str,
                   fns: Mapping[str, Callable] | None = None) -> Workflow:
    """Parse a workflow description (dict or YAML text) into a Workflow.

    ``fns`` optionally binds real callables by (expanded) function name for
    the threaded engine; the simulator leaves them None.
    """
    if isinstance(doc, str):
        import yaml  # local import: simulator path never needs it

        doc = yaml.safe_load(io.StringIO(doc))
    name = doc.get("name", "workflow")
    raw = doc["functions"]

    expanded: list[tuple[str, dict]] = []
    for fname, spec in raw.items():
        expanded.extend(_expand_foreach(fname, spec))

    seen: set[str] = set()
    for fname, _ in expanded:
        if fname in seen:
            raise ValueError(
                f"function {fname!r} declared twice: a foreach expansion "
                f"collides with an explicitly declared function")
        seen.add(fname)

    produced: set[str] = set()
    for _, spec in expanded:
        produced.update(spec.get("outputs", ()) or ())

    def resolve_inputs(inputs: Iterable[str]) -> tuple[str, ...]:
        out: list[str] = []
        for k in inputs or ():
            if k.endswith("*"):
                pre = k[:-1]
                matches = sorted(p for p in produced if p.startswith(pre))
                if not matches:
                    raise ValueError(f"glob {k!r} matches no produced key")
                out.extend(matches)
            else:
                out.append(k)
        return tuple(out)

    specs: list[FunctionSpec] = []
    for fname, spec in expanded:
        sizes = {k: parse_size(v)
                 for k, v in (spec.get("output_sizes") or {}).items()}
        specs.append(FunctionSpec(
            name=fname,
            inputs=resolve_inputs(spec.get("inputs", ())),
            outputs=tuple(spec.get("outputs", ()) or ()),
            fn=(fns or {}).get(fname),
            exec_time=float(spec.get("exec_time", 0.1)),
            output_sizes=sizes,
            cold_start=float(spec.get("cold_start", 0.5)),
            cpu=float(spec.get("cpu", 1.0)),
            stream_inputs=resolve_inputs(spec.get("stream_inputs", ())),
            stream_outputs=tuple(spec.get("stream_outputs", ()) or ()),
            chunk_size=parse_size(spec.get("chunk_size", 1 << 18)),
        ))
    ext = {k: parse_size(v)
           for k, v in (doc.get("external_inputs") or {}).items()}
    return Workflow(name, specs, ext)
