"""DScheduler — real (threaded) two-tier scheduler executing callables.

The executable twin of the simulator's scheduling logic (§3.2):

* :class:`GlobalScheduler` — partitions the workflow onto nodes (same
  locality-first GS as the simulator / FaaSFlow) and pushes metadata
  (entry points, successor lists, placements) to the local schedulers.
* :class:`InstanceRun` — one in-flight workflow instance implementing
  paper Algorithm 1 (dataflow) or the FaaSFlow-style baseline
  (controlflow).  Each launched function runs in its own thread,
  immediately calls ``Get`` for every input (fine-grained retrieval: one
  blocking fetch per input), executes when the data arrives, and ``Put``s
  its outputs, which wakes downstream blocked fetches.  Execution is
  therefore out-of-order and overlap-rich.
* :class:`DFlowEngine` — facade: ``run()`` executes one instance on a
  private DStore (the classic single-shot path); ``start()`` returns the
  :class:`InstanceRun` so a serving layer (:class:`repro.core.serve.DServe`)
  can drive many concurrent instances over a *shared* DStore with
  per-instance key namespacing and a shared container service.

Serving integration (paper §3.2 cold-start optimization): when the engine
carries a container service, every function acquires a container before
fetching inputs, and — under the dataflow pattern with ``prewarm`` — the
containers of a function's successors start booting the moment the
function *launches* (precursor launch, not input arrival), so boot time
overlaps precursor execution instead of sitting on the critical path.

Beyond-paper (documented in DESIGN.md §7): duplicate-issue straggler
mitigation (first-writer-wins is safe because DStore data is immutable) and
incremental fault recovery (only functions whose outputs were lost re-run;
the paper's §3.3.5 restarts the whole workflow).
"""

from __future__ import annotations

import math
import threading
import time
from contextlib import nullcontext
from dataclasses import dataclass, field
from typing import Any, Mapping

from .dag import FunctionSpec, Workflow
from .dstore import DStore, Transport
from .partition import partition_workflow, stage_node
from .router import ShardedDStore
from .stream import StreamBroken, base_key

__all__ = ["GlobalScheduler", "DFlowEngine", "InstanceRun", "RunReport",
           "dataflow_initial_frontier", "dataflow_next_frontier"]


def dataflow_initial_frontier(wf: Workflow) -> list[str]:
    """Algorithm 1 lines 1-7: entry points + their direct successors."""
    out: list[str] = []
    for e in wf.entry_points:
        out.append(e)
        out.extend(wf.successors[e])
    return list(dict.fromkeys(out))


def dataflow_next_frontier(wf: Workflow, finished: str) -> list[str]:
    """Algorithm 1 lines 8-15: successors of the finished fn's successors."""
    out: list[str] = []
    for s in wf.successors[finished]:
        out.extend(wf.successors[s])
    return list(dict.fromkeys(out))


@dataclass
class RunReport:
    outputs: dict[str, Any]
    wall_time: float
    per_function: dict[str, float] = field(default_factory=dict)
    transfers: int = 0
    bytes_moved: int = 0
    reexecuted: list[str] = field(default_factory=list)
    duplicates_won: list[str] = field(default_factory=list)
    cold_starts: int = 0            # request-path cold boots this instance


class GlobalScheduler:
    """Partition + metadata push (paper §3.2)."""

    def __init__(self, nodes: list[str]):
        self.nodes = list(nodes)

    def assign(self, wf: Workflow) -> dict[str, str]:
        return partition_workflow(wf, self.nodes)


class _InstanceState:
    def __init__(self, wf: Workflow):
        self.lock = threading.Lock()
        self.launched: set[str] = set()
        self.completed: dict[str, float] = {}
        self.failed: dict[str, BaseException] = {}
        self.all_done = threading.Event()
        self.wf = wf

    def mark_done(self, fname: str, t: float) -> None:
        with self.lock:
            self.completed[fname] = t
            if len(self.completed) == len(self.wf.functions):
                self.all_done.set()

    def mark_failed(self, fname: str, exc: BaseException) -> None:
        with self.lock:
            self.failed[fname] = exc
            self.all_done.set()


class InstanceRun:
    """One workflow instance in flight.

    Namespacing: when ``instance`` is set, every DStore key (external
    inputs, function outputs, stream chunks) is stored as
    ``"<instance>:<key>"`` so concurrent instances sharing one DStore never
    collide — the real-path twin of the simulator's ``key(inst, k)``.
    """

    def __init__(self, engine: "DFlowEngine", wf: Workflow,
                 inputs: Mapping[str, Any] | None, *,
                 store: DStore | None = None, instance: str | None = None,
                 placement: dict[str, str] | None = None,
                 inject_failure: str | None = None,
                 plan=None, spans=None, budget=None):
        self.engine = engine
        self.wf = wf
        self.inputs = dict(inputs or {})
        if store is not None:
            self.store = store
        elif engine.sharded:
            self.store = ShardedDStore(engine.nodes, engine.transport)
        else:
            self.store = DStore(engine.nodes, engine.transport)
        self.instance = instance
        self._ns = f"{instance}:" if instance else ""
        self.placement = dict(placement) if placement is not None \
            else engine.gs.assign(wf)
        # DPlan (plan.py WorkflowPlan): static eviction read-counts are
        # installed in the store and container boots follow the slack
        # schedule instead of the fire-at-precursor-launch heuristic.
        # Incompatible with duplicate execution (stragglers) and failure
        # recovery: their extra Gets would drain read counts early and
        # evict keys a re-execution still needs.
        if plan is not None and (inject_failure or engine.straggler_factor):
            raise ValueError("plan-driven eviction cannot be combined with "
                             "straggler duplicates or failure injection")
        self.plan = plan
        # DScope span tracer (obs.py), zero-cost when None.  A shared
        # store is instrumented by the first instance that carries one.
        self.spans = spans if spans is not None else engine.spans
        self._span = None
        self._invoke_spans: list[Any] = []
        if self.spans is not None and \
                getattr(self.store, "_spans", None) is None:
            self.store.attach_spans(self.spans)
        self._prewarm_timers: list[threading.Timer] = []
        # DScale prewarm budget (scale.py PrewarmBudget): when present,
        # every prewarm — slack-scheduled or heuristic — must be granted
        # container-seconds first, and unfired grants are refunded when
        # the instance completes or is evicted.
        self._budget = budget
        self._grants: list[Any] = []
        self._prewarms_cancelled = False
        self.state = _InstanceState(wf)
        self.report = RunReport(outputs={}, wall_time=0.0)
        self._inject_failure = inject_failure
        self._failure_armed = threading.Event()
        if inject_failure:
            self._failure_armed.set()
        self._started = False
        self.t0 = 0.0

    # -- key namespacing ---------------------------------------------------
    def ns(self, key: str) -> str:
        return self._ns + key

    def strip_ns(self, key: str) -> str | None:
        """Namespaced key -> raw key, or None if it belongs elsewhere."""
        if not self._ns:
            return key
        if key.startswith(self._ns):
            return key[len(self._ns):]
        return None

    def image(self, fname: str) -> str:
        return f"{self.wf.name}/{fname}"

    # -- lifecycle ---------------------------------------------------------
    def start(self) -> "InstanceRun":
        if self._started:
            raise RuntimeError("instance already started")
        self._started = True
        self.t0 = time.monotonic()
        wf, placement, store = self.wf, self.placement, self.store
        # Sharded stores learn this instance's static routes (from the
        # placement, refined by the plan's transfer matrix) before any
        # staging Put so those Puts land on their planned home shards.
        register = getattr(store, "register_instance", None)
        if register is not None:
            register(self._ns, wf, placement, plan=self.plan)
        if self.spans is not None:
            trace = self.instance or wf.name
            self._span = self.spans.start(trace, "request", parent=None,
                                          trace=trace, workflow=wf.name)
        # Staging Puts run under the request span so their spans nest.
        with self.spans.activate(self._span) if self.spans is not None \
                else nullcontext():
            for k, v in self.inputs.items():
                # Stage external inputs on the node of each first consumer.
                node = stage_node(wf, k, placement, self.engine.nodes[0])
                store.put(node, self.ns(k), v)
        if self.plan is not None:
            store.set_plan_reads(self._ns, self.plan.eviction_reads)
            self._arm_prewarm()
        if self.engine.pattern == "dataflow":
            for fname in dataflow_initial_frontier(wf):
                self._launch(fname)
        else:
            for fname in wf.entry_points:
                self._launch(fname)
        return self

    def _arm_prewarm(self) -> None:
        """Boot containers per the plan's slack schedule (§3.2 refined):
        each function's container starts booting at ``est - cold_start``
        so it turns warm exactly when the frontier can reach the function
        — instead of the moment any precursor launches.

        Under a DScale budget the schedule is first filtered through
        :func:`repro.core.scale.allocate_prewarms` (slack-ranked grants:
        critical boots admitted first, highest-slack dropped when the
        budget tightens), and each timer fires through
        :meth:`_fire_prewarm` so revoked/cancelled boots never happen.
        """
        engine = self.engine
        if engine.containers is None or not engine.prewarm:
            return
        if self._budget is not None:
            from .scale import allocate_prewarms

            schedule = allocate_prewarms(self.plan, self._budget,
                                         now=self._budget_now())
            self._grants.extend(g for *_, g in schedule if g is not None)
        else:
            schedule = [(f, b, c, None)
                        for f, b, c in self.plan.prewarm_schedule]
        for fname, boot_at, cold, grant in schedule:
            node, image = self.placement[fname], self.image(fname)
            if boot_at <= 0.0:
                self._fire_prewarm(node, image, cold, grant)
            else:
                t = threading.Timer(boot_at, self._fire_prewarm,
                                    args=(node, image, cold, grant))
                t.daemon = True
                t.start()
                self._prewarm_timers.append(t)

    def _budget_now(self) -> float:
        return time.monotonic()

    def _fire_prewarm(self, node: str, image: str, cold: float,
                      grant=None) -> None:
        """Timer-safe prewarm: every guard a late-firing timer needs.
        No boot happens after the instance cancelled its prewarms, after
        the container service shut down or lost the node (the service
        itself rechecks under its lock), or after the budget revoked the
        grant; a granted boot that turns out to be a no-op is refunded."""
        if self._prewarms_cancelled:
            if grant is not None:
                self._budget.cancel(grant)
            return
        if grant is not None and not self._budget.settle(grant):
            return                      # revoked while the timer was armed
        booted = self.engine.containers.prewarm(node, image, cold)
        if grant is not None and not booted:
            self._budget.refund(grant)

    def _cancel_prewarms(self) -> None:
        """Cancel pending prewarm timers on every exit path (completion,
        failure, eviction) and refund their unfired budget grants."""
        self._prewarms_cancelled = True
        for t in self._prewarm_timers:
            t.cancel()
        if self._budget is not None:
            for g in self._grants:
                if not g.fired:
                    self._budget.cancel(g)

    def wait(self, timeout: float | None = None) -> RunReport:
        """Block until the instance completes; returns the report."""
        if self.spans is None:
            return self._wait_inner(timeout)
        try:
            # Sink-collection Gets below run under the request span; the
            # span closes once the instance's outcome is known.
            with self.spans.activate(self._span):
                report = self._wait_inner(timeout)
        except BaseException as exc:
            self._drain_invoke_spans()
            self.spans.end(self._span, error=type(exc).__name__)
            raise
        self._drain_invoke_spans()
        self.spans.end(self._span, ok=True)
        return report

    def _drain_invoke_spans(self, timeout: float = 2.0) -> None:
        """Worker threads close their invoke spans in a ``finally`` that can
        run just *after* the last ``mark_done`` unblocks :meth:`wait`; hold
        the request span open until they land so it contains its children
        (bounded — a failed instance may leave threads blocked on Gets)."""
        deadline = time.monotonic() + timeout
        with self.state.lock:
            pending = list(self._invoke_spans)
        for sp in pending:
            while math.isnan(sp.end) and time.monotonic() < deadline:
                time.sleep(0.0005)

    def _wait_inner(self, timeout: float | None = None) -> RunReport:
        state, wf = self.state, self.wf
        state.all_done.wait(timeout=timeout if timeout is not None
                            else self.engine.get_timeout * 2)
        self._cancel_prewarms()
        if state.failed:
            fname, exc = next(iter(state.failed.items()))
            raise RuntimeError(f"function {fname!r} failed") from exc
        if not state.all_done.is_set():
            raise TimeoutError("workflow did not complete")
        report = self.report
        report.wall_time = time.monotonic() - self.t0
        report.per_function = dict(state.completed)
        report.transfers = self.engine.transport.transfers
        report.bytes_moved = self.engine.transport.bytes_moved
        # Gather every *sink* datum (produced but never consumed) — exit
        # functions' outputs plus by-products like metrics/final state.
        consumed = {k for f in wf.functions.values() for k in f.inputs}
        for f in wf.functions.values():
            for k in f.outputs:
                if k not in consumed or f.name in wf.exit_points:
                    report.outputs[k] = self.store.get(
                        self.engine.nodes[0], self.ns(k),
                        timeout=self.engine.get_timeout)
        return report

    def evict(self) -> None:
        """Instance-scoped eviction: free every key this instance stored
        (bounded memory under sustained serving)."""
        self._cancel_prewarms()
        if self._ns:
            self.store.evict_instance(self._ns)

    # -- launch / execute --------------------------------------------------
    def _launch(self, fname: str) -> None:
        state, wf, engine = self.state, self.wf, self.engine
        with state.lock:
            if fname in state.launched:
                return
            state.launched.add(fname)
        node = self.placement[fname]
        th = threading.Thread(target=self._execute, args=(fname, node),
                              daemon=True,
                              name=f"dflow-{self.instance or wf.name}-{fname}")
        th.start()
        # Dataflow-triggered prewarm (§3.2): this function's launch is its
        # successors' precursor-launch signal — their containers start
        # booting now, overlapping with this function's own execution.
        # Strictly a dataflow-pattern mechanism: the controlflow baseline
        # (§5.5 ablation) must boot only when a function becomes ready.
        # A static plan supersedes this heuristic (slack-timed boots are
        # armed once at start()).
        if (engine.containers is not None and engine.prewarm
                and engine.pattern == "dataflow" and self.plan is None):
            for s in wf.successors[fname]:
                self._prewarm_successor(s)
        if engine.straggler_factor and wf.functions[fname].exec_time:
            budget = engine.straggler_factor * wf.functions[fname].exec_time

            def watchdog():
                th.join(budget)
                with state.lock:
                    done = fname in state.completed
                if not done and not state.failed:
                    alt = next(n for n in engine.nodes if n != node)
                    threading.Thread(
                        target=self._execute, args=(fname, alt),
                        kwargs={"duplicate": True}, daemon=True).start()
            threading.Thread(target=watchdog, daemon=True).start()

    def _prewarm_successor(self, s: str) -> None:
        """Heuristic (§3.2, no plan) successor prewarm.  With a DScale
        budget the boot is charged ``cold_start`` container-seconds at
        slack 0 (a heuristic has no slack estimate); denial drops the
        boot, and a no-op prewarm (idle container already there) refunds
        the grant."""
        wf = self.wf
        node, image = self.placement[s], self.image(s)
        cold = wf.functions[s].cold_start
        if self._budget is None:
            self.engine.containers.prewarm(node, image, cold)
            return
        grant = self._budget.request(s, cold, slack=0.0,
                                     now=self._budget_now())
        if grant is None or not self._budget.settle(grant):
            return
        booted = self.engine.containers.prewarm(node, image, cold)
        if not booted:
            self._budget.refund(grant)

    def _execute(self, fname: str, node: str, *,
                 duplicate: bool = False) -> None:
        spans = self.spans
        if spans is None:
            return self._execute_inner(fname, node, duplicate=duplicate)
        # Function threads don't inherit thread-local context: the invoke
        # span is parented on the request span explicitly, then activated
        # so this thread's Gets/Puts (and stream pumps) nest under it.
        sp = spans.start(fname, "invoke", parent=self._span, node=node,
                         duplicate=duplicate)
        if not duplicate:
            with self.state.lock:
                self._invoke_spans.append(sp)
        try:
            with spans.activate(sp):
                return self._execute_inner(fname, node, duplicate=duplicate)
        finally:
            spans.end(sp)

    def _acquire(self, node: str, fname: str, cold_start: float):
        """Container acquire, span-wrapped (the ``cold`` attribute is what
        plan-vs-actual attribution reads for prewarm accuracy).  Returns
        the :class:`~repro.core.serve.Lease` token that must be handed
        back on release — the token pins *which* container this function
        holds."""
        containers, spans = self.engine.containers, self.spans
        if spans is None:
            return containers.acquire(node, self.image(fname), cold_start)
        sp = spans.start(fname, "acquire", node=node)
        try:
            lease = containers.acquire(node, self.image(fname), cold_start)
        except BaseException:
            spans.end(sp, error=True)
            raise
        spans.end(sp, cold=lease.cold)
        return lease

    def _execute_inner(self, fname: str, node: str, *,
                       duplicate: bool = False) -> None:
        state, wf, engine = self.state, self.wf, self.engine
        f = wf.functions[fname]
        containers = engine.containers
        lease = None
        plan_mode = self.plan is not None
        try:
            if containers is not None and not plan_mode:
                # Container acquire happens at launch time — before the
                # input fetches below block — so a cold boot overlaps the
                # precursor's execution under the dataflow pattern.
                lease = self._acquire(node, fname, f.cold_start)
                if lease.cold:
                    with state.lock:
                        self.report.cold_starts += 1
            # A StreamBroken during fetch/execute/emit means an upstream
            # producer's node died mid-stream; recovery re-runs it and
            # re-claims the stream, so the consumer retries (bounded)
            # instead of failing the whole instance.
            for attempt in range(3):
                try:
                    kwargs = self._fetch_inputs(node, f)
                    if containers is not None and lease is None:
                        # Plan mode: acquire only once inputs are in hand,
                        # so the container is not leased during the input
                        # wait and the slack-timed prewarm (armed at
                        # start()) has it booted by now.
                        lease = self._acquire(node, fname, f.cold_start)
                        if lease.cold:
                            with state.lock:
                                self.report.cold_starts += 1
                    if containers is not None:
                        with containers.slot(node):
                            result = f.fn(**kwargs) if f.fn else {}
                    else:
                        result = f.fn(**kwargs) if f.fn else {}
                    if not isinstance(result, Mapping):
                        raise TypeError(
                            f"{fname} must return a mapping of outputs")
                    missing = set(f.outputs) - set(result)
                    if missing:
                        raise KeyError(f"{fname} missing outputs {missing}")
                    with state.lock:
                        first = fname not in state.completed
                    self._emit_outputs(node, f, result)
                    break
                except StreamBroken:
                    if attempt == 2:
                        raise
                    time.sleep(0.05)
            if duplicate and first:
                self.report.duplicates_won.append(fname)
            if not first:
                return
            state.mark_done(fname, time.monotonic() - self.t0)
            # -- optional fault injection: node dies after its first
            # completion; lost outputs trigger incremental re-execution.
            if self._inject_failure == node and self._failure_armed.is_set():
                self._failure_armed.clear()
                lost = self.store.fail_node(node)
                self.recover(lost)
            self._on_complete(fname)
        except BaseException as exc:   # noqa: BLE001 - report upward
            state.mark_failed(fname, exc)
        finally:
            if lease is not None:
                containers.release(node, self.image(fname), lease)

    def _on_complete(self, fname: str) -> None:
        state, wf = self.state, self.wf
        if self.engine.pattern == "dataflow":
            for t in dataflow_next_frontier(wf, fname):
                self._launch(t)
        else:
            for s in wf.successors[fname]:
                with state.lock:
                    ready = all(p in state.completed
                                for p in wf.predecessors[s])
                if ready:
                    self._launch(s)

    # -- input fetch / output publication ----------------------------------
    def _fetch_inputs(self, node: str, f: FunctionSpec) -> dict[str, Any]:
        """One blocking fetch per input (fine-grained retrieval).  Streaming
        inputs arrive as blocking chunk iterators instead: the callable
        starts consuming chunk 0 while its precursor is still emitting
        chunk N (DStream pipelining)."""
        store, timeout = self.store, self.engine.get_timeout
        return {
            k: (store.get_stream(node, self.ns(k), timeout=timeout)
                if k in f.stream_inputs
                else store.get(node, self.ns(k), timeout=timeout))
            for k in f.inputs}

    def _emit_outputs(self, node: str, f: FunctionSpec,
                      result: Mapping[str, Any]) -> None:
        """Publish outputs: plain Put, or chunked ``put_stream`` for keys in
        ``f.stream_outputs`` (bytes or any iterable of byte chunks).
        Draining a generator here is what overlaps production with
        downstream pulls; a generator that raises aborts the stream so
        blocked consumers fail fast instead of hanging until timeout."""
        store = self.store
        for k in f.outputs:
            if k not in f.stream_outputs:
                store.put(node, self.ns(k), result[k])
                continue
            value = result[k]
            writer = store.put_stream(node, self.ns(k),
                                      chunk_size=f.chunk_size)
            try:
                if isinstance(value, (bytes, bytearray, memoryview)):
                    writer.write(value)
                else:
                    for chunk in value:
                        writer.write(chunk)
            except BaseException:
                writer.abort()
                raise
            writer.close()

    # -- beyond-paper incremental recovery --------------------------------
    def recover(self, lost_keys: list[str]) -> None:
        """Re-execute only producers of lost keys *belonging to this
        instance* (paper §3.3.5 restarts the whole workflow; we re-run the
        minimal affected subgraph).  ``lost_keys`` are namespaced store
        keys, e.g. straight from :meth:`DStore.fail_node` — a serving layer
        hands the same list to every active instance and each recovers its
        own slice."""
        wf, state = self.wf, self.state
        mine = [raw for k in lost_keys
                if (raw := self.strip_ns(k)) is not None]
        # External inputs have no producer to re-run — re-stage them from
        # the retained trigger payload (losing the staging node used to
        # wedge every consumer until Get timed out).
        for k in mine:
            if k in self.inputs and k not in wf.producer:
                node = stage_node(wf, k, self.placement,
                                  self.engine.nodes[0])
                self.store.put(node, self.ns(k), self.inputs[k])
        # Chunk records of an in-flight stream map back to the stream key,
        # whose producer must re-run (it re-claims the aborted stream and
        # republishes idempotently).
        lost_fns = {wf.producer[b] for k in mine
                    if (b := base_key(k)) in wf.producer}
        if not lost_fns:
            return
        survivors = list(self.engine.nodes)
        relaunch: list[str] = []
        with state.lock:
            for fname in sorted(lost_fns):
                state.completed.pop(fname, None)
                state.launched.discard(fname)
        for fname in sorted(lost_fns):
            # move to a surviving node (round-robin by hash for determinism)
            self.placement[fname] = survivors[hash(fname) % len(survivors)]
            self.report.reexecuted.append(fname)
            relaunch.append(fname)
        for fname in relaunch:
            self._launch(fname)


class DFlowEngine:
    """Execute Workflows of real callables with dataflow invocation.

    ``pattern`` ∈ {"dataflow", "controlflow"} — the §5.5 ablation in real
    (threaded) form.  ``transport`` may carry a bandwidth to make network
    time observable.  ``straggler_factor`` (beyond-paper): when a launched
    function has run longer than factor × its spec exec_time, a duplicate
    is issued on another node; DStore immutability makes the race benign.
    ``containers`` (serving): a :class:`repro.core.serve.ContainerService`
    providing explicit container lifecycle (cold boot / keep-alive /
    prewarm) and bounded per-node execution slots; ``prewarm`` enables the
    §3.2 dataflow-triggered prewarm of successor containers at launch.
    ``sharded`` (DShard, router.py): instances get a
    :class:`~repro.core.router.ShardedDStore` — per-node directory shards
    with local routing tables and 1-hop transfers — instead of the
    single-directory :class:`DStore`; results are byte-identical.
    """

    def __init__(self, n_nodes: int = 2, *, pattern: str = "dataflow",
                 transport: Transport | None = None,
                 get_timeout: float = 120.0,
                 straggler_factor: float | None = None,
                 containers=None, prewarm: bool = True,
                 lint: bool = True, sharded: bool = False,
                 spans=None):
        if pattern not in ("dataflow", "controlflow"):
            raise ValueError(pattern)
        self.nodes = [f"node{i}" for i in range(n_nodes)]
        self.gs = GlobalScheduler(self.nodes)
        self.pattern = pattern
        self.transport = transport or Transport()
        self.get_timeout = get_timeout
        self.straggler_factor = straggler_factor
        self.containers = containers
        self.prewarm = prewarm
        self.lint = lint
        self.sharded = sharded
        # DScope span tracer (obs.py): every instance launched through
        # this engine inherits it unless it brings its own.
        self.spans = spans

    # ------------------------------------------------------------------
    def start(self, wf: Workflow, inputs: Mapping[str, Any] | None = None,
              *, store: DStore | None = None, instance: str | None = None,
              placement: dict[str, str] | None = None,
              inject_failure: str | None = None,
              plan=None, spans=None, budget=None) -> InstanceRun:
        """Launch one instance and return its handle (non-blocking) —
        the entry point serving layers use to run many instances
        concurrently over a shared ``store``."""
        if self.lint:
            # Pre-flight gate (DCheck): an error-severity diagnostic —
            # e.g. an unbound fn that produces outputs — would otherwise
            # surface mid-run as a GetTimeout on some downstream input,
            # minutes away from its actual cause.
            from .lint import check_workflow

            check_workflow(wf, require_fns=True)
        return InstanceRun(self, wf, inputs, store=store, instance=instance,
                           placement=placement,
                           inject_failure=inject_failure, plan=plan,
                           spans=spans, budget=budget).start()

    def run(self, wf: Workflow, inputs: Mapping[str, Any] | None = None,
            *, inject_failure: str | None = None,
            plan=None) -> RunReport:
        """Execute one workflow instance; returns exit-function outputs.

        ``inject_failure``: name of a node that "crashes" right after the
        first function on it completes — exercises incremental recovery.
        ``plan``: a :class:`repro.core.plan.WorkflowPlan` switches the
        instance to plan-driven eviction + slack-timed prewarm.
        """
        return self.start(wf, inputs, inject_failure=inject_failure,
                          plan=plan).wait()
