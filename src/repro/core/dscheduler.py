"""DScheduler — real (threaded) two-tier scheduler executing callables.

The executable twin of the simulator's scheduling logic (§3.2):

* :class:`GlobalScheduler` — partitions the workflow onto nodes (same
  locality-first GS as the simulator / FaaSFlow) and pushes metadata
  (entry points, successor lists, placements) to the local schedulers.
* :class:`DataflowLocalScheduler` — paper Algorithm 1.  Each launched
  function runs in its own thread, immediately calls ``Get`` for every
  input (fine-grained retrieval: one blocking fetch per input), executes
  when the data arrives, and ``Put``s its outputs, which wakes downstream
  blocked fetches.  Execution is therefore out-of-order and overlap-rich.
* :class:`ControlflowLocalScheduler` — the FaaSFlow-style baseline: a
  function launches only once **all** its precursors completed.

Beyond-paper (documented in DESIGN.md §7): duplicate-issue straggler
mitigation (first-writer-wins is safe because DStore data is immutable) and
incremental fault recovery (only functions whose outputs were lost re-run;
the paper's §3.3.5 restarts the whole workflow).
"""

from __future__ import annotations

import threading
import traceback
from dataclasses import dataclass, field
from typing import Any, Callable, Mapping

from .dag import FunctionSpec, Workflow
from .dstore import DStore, Transport
from .partition import partition_workflow

__all__ = ["GlobalScheduler", "DFlowEngine", "RunReport",
           "dataflow_initial_frontier", "dataflow_next_frontier"]


def dataflow_initial_frontier(wf: Workflow) -> list[str]:
    """Algorithm 1 lines 1-7: entry points + their direct successors."""
    out: list[str] = []
    for e in wf.entry_points:
        out.append(e)
        out.extend(wf.successors[e])
    return list(dict.fromkeys(out))


def dataflow_next_frontier(wf: Workflow, finished: str) -> list[str]:
    """Algorithm 1 lines 8-15: successors of the finished fn's successors."""
    out: list[str] = []
    for s in wf.successors[finished]:
        out.extend(wf.successors[s])
    return list(dict.fromkeys(out))


@dataclass
class RunReport:
    outputs: dict[str, Any]
    wall_time: float
    per_function: dict[str, float] = field(default_factory=dict)
    transfers: int = 0
    bytes_moved: int = 0
    reexecuted: list[str] = field(default_factory=list)
    duplicates_won: list[str] = field(default_factory=list)


class GlobalScheduler:
    """Partition + metadata push (paper §3.2)."""

    def __init__(self, nodes: list[str]):
        self.nodes = list(nodes)

    def assign(self, wf: Workflow) -> dict[str, str]:
        return partition_workflow(wf, self.nodes)


class _InstanceState:
    def __init__(self, wf: Workflow):
        self.lock = threading.Lock()
        self.launched: set[str] = set()
        self.completed: dict[str, float] = {}
        self.failed: dict[str, BaseException] = {}
        self.all_done = threading.Event()
        self.wf = wf

    def mark_done(self, fname: str, t: float) -> None:
        with self.lock:
            self.completed[fname] = t
            if len(self.completed) == len(self.wf.functions):
                self.all_done.set()

    def mark_failed(self, fname: str, exc: BaseException) -> None:
        with self.lock:
            self.failed[fname] = exc
            self.all_done.set()


class DFlowEngine:
    """Execute a Workflow of real callables with dataflow invocation.

    ``pattern`` ∈ {"dataflow", "controlflow"} — the §5.5 ablation in real
    (threaded) form.  ``transport`` may carry a bandwidth to make network
    time observable.  ``straggler_factor`` (beyond-paper): when a launched
    function has run longer than factor × its spec exec_time, a duplicate
    is issued on another node; DStore immutability makes the race benign.
    """

    def __init__(self, n_nodes: int = 2, *, pattern: str = "dataflow",
                 transport: Transport | None = None,
                 get_timeout: float = 120.0,
                 straggler_factor: float | None = None):
        if pattern not in ("dataflow", "controlflow"):
            raise ValueError(pattern)
        self.nodes = [f"node{i}" for i in range(n_nodes)]
        self.gs = GlobalScheduler(self.nodes)
        self.pattern = pattern
        self.transport = transport or Transport()
        self.get_timeout = get_timeout
        self.straggler_factor = straggler_factor

    # ------------------------------------------------------------------
    def run(self, wf: Workflow, inputs: Mapping[str, Any] | None = None,
            *, inject_failure: str | None = None) -> RunReport:
        """Execute one workflow instance; returns exit-function outputs.

        ``inject_failure``: name of a node that "crashes" right after the
        first function on it completes — exercises incremental recovery.
        """
        import time as _time

        placement = self.gs.assign(wf)
        store = DStore(self.nodes, self.transport)
        state = _InstanceState(wf)
        t0 = _time.monotonic()
        report = RunReport(outputs={}, wall_time=0.0)
        failure_armed = threading.Event()
        if inject_failure:
            failure_armed.set()

        for k, v in (inputs or {}).items():
            # Stage external inputs on the node of each first consumer.
            consumers = [f.name for f in wf.functions.values()
                         if k in f.inputs]
            node = placement[consumers[0]] if consumers else self.nodes[0]
            store.put(node, k, v)

        def execute(fname: str, node: str, *, duplicate: bool = False):
            f = wf.functions[fname]
            try:
                kwargs = self._fetch_inputs(store, node, f)
                result = f.fn(**kwargs) if f.fn else {}
                if not isinstance(result, Mapping):
                    raise TypeError(
                        f"{fname} must return a mapping of outputs")
                missing = set(f.outputs) - set(result)
                if missing:
                    raise KeyError(f"{fname} missing outputs {missing}")
                with state.lock:
                    first = fname not in state.completed
                self._emit_outputs(store, node, f, result)
                if duplicate and first:
                    report.duplicates_won.append(fname)
                if not first:
                    return
                state.mark_done(fname, _time.monotonic() - t0)
                # -- optional fault injection: node dies after its first
                # completion; lost outputs trigger incremental re-execution.
                if (inject_failure == node and failure_armed.is_set()):
                    failure_armed.clear()
                    lost = store.fail_node(node)
                    self._recover(wf, placement, store, state, lost,
                                  report, on_complete)
                on_complete(fname)
            except BaseException as exc:   # noqa: BLE001 - report upward
                state.mark_failed(fname, exc)

        def launch(fname: str):
            with state.lock:
                if fname in state.launched:
                    return
                state.launched.add(fname)
            node = placement[fname]
            th = threading.Thread(target=execute, args=(fname, node),
                                  daemon=True, name=f"dflow-{fname}")
            th.start()
            if self.straggler_factor and wf.functions[fname].exec_time:
                budget = self.straggler_factor * wf.functions[fname].exec_time

                def watchdog():
                    th.join(budget)
                    with state.lock:
                        done = fname in state.completed
                    if not done and not state.failed:
                        alt = next(n for n in self.nodes if n != node)
                        threading.Thread(
                            target=execute, args=(fname, alt),
                            kwargs={"duplicate": True}, daemon=True).start()
                threading.Thread(target=watchdog, daemon=True).start()

        def on_complete(fname: str):
            if self.pattern == "dataflow":
                for t in dataflow_next_frontier(wf, fname):
                    launch(t)
            else:
                for s in wf.successors[fname]:
                    with state.lock:
                        ready = all(p in state.completed
                                    for p in wf.predecessors[s])
                    if ready:
                        launch(s)

        if self.pattern == "dataflow":
            for fname in dataflow_initial_frontier(wf):
                launch(fname)
        else:
            for fname in wf.entry_points:
                launch(fname)

        state.all_done.wait(timeout=self.get_timeout * 2)
        if state.failed:
            fname, exc = next(iter(state.failed.items()))
            raise RuntimeError(f"function {fname!r} failed") from exc
        if not state.all_done.is_set():
            raise TimeoutError("workflow did not complete")

        report.wall_time = _time.monotonic() - t0
        report.per_function = dict(state.completed)
        report.transfers = self.transport.transfers
        report.bytes_moved = self.transport.bytes_moved
        # Gather every *sink* datum (produced but never consumed) — exit
        # functions' outputs plus by-products like metrics/final state.
        consumed = {k for f in wf.functions.values() for k in f.inputs}
        for f in wf.functions.values():
            for k in f.outputs:
                if k not in consumed or f.name in wf.exit_points:
                    report.outputs[k] = store.get(self.nodes[0], k,
                                                  timeout=self.get_timeout)
        return report

    # -- input fetch / output publication ----------------------------------
    def _fetch_inputs(self, store: DStore, node: str,
                      f: FunctionSpec) -> dict[str, Any]:
        """One blocking fetch per input (fine-grained retrieval).  Streaming
        inputs arrive as blocking chunk iterators instead: the callable
        starts consuming chunk 0 while its precursor is still emitting
        chunk N (DStream pipelining)."""
        return {
            k: (store.get_stream(node, k, timeout=self.get_timeout)
                if k in f.stream_inputs
                else store.get(node, k, timeout=self.get_timeout))
            for k in f.inputs}

    @staticmethod
    def _emit_outputs(store: DStore, node: str, f: FunctionSpec,
                      result: Mapping[str, Any]) -> None:
        """Publish outputs: plain Put, or chunked ``put_stream`` for keys in
        ``f.stream_outputs`` (bytes or any iterable of byte chunks).
        Draining a generator here is what overlaps production with
        downstream pulls; a generator that raises aborts the stream so
        blocked consumers fail fast instead of hanging until timeout."""
        for k in f.outputs:
            if k not in f.stream_outputs:
                store.put(node, k, result[k])
                continue
            value = result[k]
            writer = store.put_stream(node, k, chunk_size=f.chunk_size)
            try:
                if isinstance(value, (bytes, bytearray, memoryview)):
                    writer.write(value)
                else:
                    for chunk in value:
                        writer.write(chunk)
            except BaseException:
                writer.abort()
                raise
            writer.close()

    # -- beyond-paper incremental recovery --------------------------------
    def _recover(self, wf: Workflow, placement: dict[str, str],
                 store: DStore, state: _InstanceState, lost_keys: list[str],
                 report: RunReport, on_complete) -> None:
        """Re-execute only producers of lost keys (paper §3.3.5 restarts the
        whole workflow; we re-run the minimal affected subgraph)."""
        lost_fns = {wf.producer[k] for k in lost_keys if k in wf.producer}
        if not lost_fns:
            return
        survivors = [n for n in self.nodes]
        for fname in sorted(lost_fns):
            with state.lock:
                state.completed.pop(fname, None)
                state.launched.discard(fname)
            # move to a surviving node (round-robin by hash for determinism)
            placement[fname] = survivors[hash(fname) % len(survivors)]
            report.reexecuted.append(fname)
        for fname in sorted(lost_fns):
            with state.lock:
                if fname in state.launched:
                    continue
                state.launched.add(fname)
            node = placement[fname]
            f = wf.functions[fname]

            def rerun(fname=fname, node=node, f=f):
                try:
                    kwargs = self._fetch_inputs(store, node, f)
                    result = f.fn(**kwargs) if f.fn else {}
                    self._emit_outputs(store, node, f, result)
                    import time as _t
                    state.mark_done(fname, _t.monotonic())
                    on_complete(fname)
                except BaseException as exc:  # noqa: BLE001
                    state.mark_failed(fname, exc)
            threading.Thread(target=rerun, daemon=True).start()
