"""DStore — the paper's distributed in-memory KV store (real, threaded).

This is the executable twin of the simulator's :class:`DStorePlane`: the same
design (§3.3) implemented with real threads so the orchestrator can run
actual Python/JAX callables as DFlow workflows:

* **data directory service** (:class:`DataDirectoryService`) — metadata only:
  key → (size, replica locations, per-replica access frequency).  Writing a
  metadata record wakes every consumer blocked on that key (the *auto
  blocking / waking-up* mechanism, §3.3.2).
* **local store** per node (:class:`LocalStore`) — the bytes.
* **Get/Put** core API (Table 1): ``Get`` blocks until the key's metadata
  exists, then pulls the value — locally when the replica is co-resident,
  otherwise *receiver-driven* from the least-access-frequency replica
  (§3.3.1, §3.3.4), registering the new replica in the directory afterwards.
* Data is **immutable**: a key can only be put once ("the updated version
  must be stored ... with a new, unique identifier", §3.3) — which is also
  what makes duplicate/straggler re-execution safe (first-writer-wins).

A pluggable :class:`Transport` lets tests emulate a slow network (bytes/s)
so the out-of-order overlap is observable in wall-clock time.
"""

from __future__ import annotations

import threading
import time
from contextlib import nullcontext
from dataclasses import dataclass, field
from typing import Any, Callable, Mapping

__all__ = ["DataDirectoryService", "LocalStore", "DStore", "Transport",
           "GetTimeout", "ImmutabilityError"]


class GetTimeout(TimeoutError):
    """Raised when Get blocks longer than the configured timeout."""


class ImmutabilityError(ValueError):
    """A key was co-written with divergent content.

    First-writer-wins duplicate safety (§3.3) presumes deterministic
    functions: a straggler re-execution must produce the *same bytes* as
    the original, otherwise which copy a consumer sees depends on replica
    choice.  The directory records a content digest at first publish and
    rejects any later publish whose digest disagrees."""


# stream.py lazily imports GetTimeout, so this import must come after it.
from .check import TraceRecorder, content_digest  # noqa: E402
from .stream import (DEFAULT_CHUNK, StreamDirectory, StreamReader,  # noqa: E402
                     StreamWriter, chunk_key, is_chunk_key)


# Imported at module load, not inside _sizeof: a lazy import there put a
# ~100 ms one-time cost on the first Put of the process — which under
# DServe lands squarely on the first request's critical path.
try:
    import numpy as _np
except Exception:  # pragma: no cover - numpy is optional for sizing
    _np = None


def _sizeof(value: Any) -> int:
    try:
        if hasattr(value, "nbytes"):
            return int(value.nbytes)
        if isinstance(value, (bytes, bytearray)):
            return len(value)
        if _np is not None and isinstance(value, _np.ndarray):
            return int(value.nbytes)
    except Exception:  # pragma: no cover - best effort sizing
        pass
    return 64  # opaque object: metadata-only size


# nullcontext is reentrant and stateless, so one shared instance serves
# every un-instrumented Get.
_NULL_CTX = nullcontext()


def _trace_of(key: str) -> str:
    """Instance id from a ``#``-namespaced key (``wf#0:out`` → ``wf#0``),
    used to tag spans emitted outside any request context (evictions)."""
    head, sep, _ = key.partition(":")
    return head if sep and "#" in head else ""


@dataclass
class _Meta:
    key: str
    size: int
    locations: dict[str, int] = field(default_factory=dict)
    digest: str | None = None     # content digest of first publish (None =
    #                               opaque value, equality unverifiable)


class DataDirectoryService:
    """Thread-safe metadata directory with blocking lookups."""

    def __init__(self) -> None:
        self._lock = threading.Lock()
        self._cv = threading.Condition(self._lock)
        self._meta: dict[str, _Meta] = {}

    def publish(self, key: str, size: int, node: str,
                digest: str | None = None) -> None:
        with self._cv:
            m = self._meta.get(key)
            if m is None:
                m = self._meta[key] = _Meta(key, size, digest=digest)
            elif digest is not None:
                if m.digest is None:
                    m.digest = digest       # first verifiable publish wins
                elif m.digest != digest:
                    raise ImmutabilityError(
                        f"key {key!r} co-written with divergent content "
                        f"(existing digest {m.digest[:12]}…, new "
                        f"{digest[:12]}…): DStore data is immutable")
            m.locations.setdefault(node, 0)
            self._cv.notify_all()          # wake blocked Gets (§3.3.2)

    def wait(self, key: str, timeout: float | None = None) -> _Meta:
        deadline = None if timeout is None else time.monotonic() + timeout
        with self._cv:
            while key not in self._meta:
                remaining = None
                if deadline is not None:
                    remaining = deadline - time.monotonic()
                    if remaining <= 0:
                        raise GetTimeout(f"Get({key!r}) timed out")
                self._cv.wait(remaining)
            return self._meta[key]

    def peek(self, key: str) -> _Meta | None:
        with self._lock:
            return self._meta.get(key)

    def choose_replica(self, key: str) -> str:
        """Least-access-frequency replica; increments its counter."""
        with self._lock:
            m = self._meta[key]
            node = min(m.locations.items(), key=lambda kv: (kv[1], kv[0]))[0]
            m.locations[node] += 1
            return node

    def release_replica(self, key: str, node: str) -> None:
        with self._lock:
            m = self._meta.get(key)
            if m and node in m.locations and m.locations[node] > 0:
                m.locations[node] -= 1

    def drop_replica(self, key: str, node: str) -> None:
        """Remove one phantom replica (registered by a Put that raced a node
        failure); deletes the record when no replica remains, so consumers
        block again until a recovery re-execution re-publishes."""
        with self._cv:
            m = self._meta.get(key)
            if m is None:
                return
            m.locations.pop(node, None)
            if not m.locations:
                del self._meta[key]

    def keys(self) -> list[str]:
        with self._lock:
            return list(self._meta)

    def drop(self, keys: list[str]) -> None:
        """Fault handling (§3.3.5): delete metadata of a failed workflow."""
        with self._cv:
            for k in keys:
                self._meta.pop(k, None)

    def drop_prefix(self, prefix: str) -> list[str]:
        """Instance-scoped eviction: delete every record whose key starts
        with ``prefix`` (a completed instance's namespace); returns them."""
        with self._cv:
            dropped = [k for k in self._meta if k.startswith(prefix)]
            for k in dropped:
                del self._meta[k]
        return dropped

    def drop_node(self, node: str) -> list[str]:
        """Remove every replica hosted on a failed node; returns keys that
        lost their last replica (those must be recomputed)."""
        lost: list[str] = []
        with self._cv:
            for k, m in list(self._meta.items()):
                m.locations.pop(node, None)
                if not m.locations:
                    del self._meta[k]
                    lost.append(k)
        return lost


class LocalStore:
    """Per-node in-memory object store (byte-accounted: the DPlan peak-
    resident metric and eviction benchmarks read ``resident_bytes``)."""

    def __init__(self, node: str):
        self.node = node
        self._lock = threading.Lock()
        self._data: dict[str, Any] = {}
        self._bytes = 0
        self._peak = 0

    def write(self, key: str, value: Any) -> None:
        with self._lock:
            if key in self._data:
                self._bytes -= _sizeof(self._data[key])
            self._data[key] = value
            self._bytes += _sizeof(value)
            if self._bytes > self._peak:
                self._peak = self._bytes

    def read(self, key: str) -> Any:
        with self._lock:
            return self._data[key]

    def has(self, key: str) -> bool:
        with self._lock:
            return key in self._data

    def drop_all(self) -> None:
        with self._lock:
            self._data.clear()
            self._bytes = 0

    def drop_prefix(self, prefix: str) -> None:
        with self._lock:
            for k in [k for k in self._data if k.startswith(prefix)]:
                self._bytes -= _sizeof(self._data.pop(k))

    def drop_key(self, key: str) -> None:
        with self._lock:
            if key in self._data:
                self._bytes -= _sizeof(self._data.pop(key))

    @property
    def resident_bytes(self) -> int:
        with self._lock:
            return self._bytes

    @property
    def peak_bytes(self) -> int:
        """High-water mark of this node's resident bytes — the per-node
        figure DPlan's ``peak_resident`` prediction is comparable to."""
        with self._lock:
            return self._peak

    def reset_peak(self) -> None:
        with self._lock:
            self._peak = self._bytes


class Transport:
    """Inter-node copy model: optional bandwidth (B/s) + per-op latency."""

    def __init__(self, bandwidth: float | None = None, latency: float = 0.0):
        self.bandwidth = bandwidth
        self.latency = latency
        self._lock = threading.Lock()
        self.bytes_moved = 0
        self.transfers = 0

    def move(self, size: int) -> None:
        if self.latency:
            time.sleep(self.latency)
        if self.bandwidth:
            time.sleep(size / self.bandwidth)
        with self._lock:
            self.bytes_moved += size
            self.transfers += 1


class DStore:
    """Cluster-wide store: one directory + one LocalStore per node."""

    def __init__(self, nodes: list[str],
                 transport: Transport | None = None):
        self.directory = DataDirectoryService()
        self.streams = StreamDirectory()
        self.stores = {n: LocalStore(n) for n in nodes}
        self.transport = transport or Transport()
        # Serialises writes against fail_node: without it a Put interleaving
        # with a failure (write → store wiped → publish) would register a
        # replica whose bytes are gone, invisible to recovery.
        self._write_lock = threading.Lock()
        # DCheck hook (see check.py): None = recording off, zero cost.
        self._tracer: TraceRecorder | None = None
        # DScope hooks (see obs.py), same zero-cost-when-off pattern:
        # _spans is a Tracer producing per-request span trees, _metrics a
        # MetricsRegistry receiving hot-path latency observations.
        self._spans = None
        self._metrics = None
        # DPlan eviction hints: key -> Gets remaining before the key is
        # provably dead (installed per instance by set_plan_reads).  Own
        # lock so the countdown never nests inside _write_lock.
        self._plan_lock = threading.Lock()
        self._plan_reads: dict[str, int] = {}
        self._peak_bytes = 0

    def attach_tracer(self, tracer: TraceRecorder | None) -> None:
        """Attach (or detach, with None) a :class:`TraceRecorder`.  Every
        data-plane action is recorded from then on; stream-level events
        (close/abort) are recorded by the shared StreamDirectory."""
        self._tracer = tracer
        self.streams.tracer = tracer

    def attach_spans(self, spans) -> None:
        """Attach (or detach, with None) a DScope span
        :class:`~repro.core.obs.Tracer`.  Every Get/Put/chunk/evict from
        then on emits a span parented under the calling thread's active
        span (the function-invocation span the engine activated)."""
        self._spans = spans

    def attach_metrics(self, registry) -> None:
        """Attach a :class:`~repro.core.obs.MetricsRegistry` for hot-path
        latency histograms (per-Get/Put) *and* register the pull
        collectors.  Passing None detaches the push hooks."""
        self._metrics = registry
        if registry is not None:
            self.register_metrics(registry)

    def register_metrics(self, registry) -> None:
        """Register pull-style collectors only (no hot-path cost): per-node
        resident/peak bytes and transport traffic, scraped at
        ``registry.collect()`` time."""
        def _scrape() -> None:
            for node, s in self.stores.items():
                registry.gauge("dstore_resident_bytes",
                               node=node).set(s.resident_bytes)
                registry.gauge("dstore_peak_resident_bytes",
                               node=node).set(s.peak_bytes)
            registry.counter("transport_bytes_moved").set(
                self.transport.bytes_moved)
            registry.counter("transport_transfers").set(
                self.transport.transfers)
        registry.register_collector(_scrape)

    # -- Table 1 core API ------------------------------------------------
    def put(self, node: str, key: str, value: Any) -> None:
        """Create data with the given key (immutable; §3.3).

        Duplicate (straggler) co-writes are safe only because functions are
        deterministic — the directory verifies it: a co-write whose content
        digest diverges from the first publish raises
        :class:`ImmutabilityError` instead of silently registering a second
        replica with different bytes.
        """
        spans = self._spans
        if spans is None:
            return self._put(node, key, value)
        sp = spans.start(key, "put", node=node, size=_sizeof(value))
        try:
            return self._put(node, key, value)
        finally:
            spans.end(sp)

    def _put(self, node: str, key: str, value: Any) -> None:
        store = self.stores[node]
        digest = content_digest(value)
        tracer = self._tracer
        with self._write_lock:
            meta = self.directory.peek(key)
            if meta is not None:
                if (digest is not None and meta.digest is not None
                        and meta.digest != digest):
                    raise ImmutabilityError(
                        f"put({key!r}) from {node!r} diverges from the "
                        f"first writer's content: DStore data is immutable")
                if store.has(key):
                    return              # duplicate write: first-writer-wins
            # Recorded before the bytes land so the trace's availability
            # event precedes any Get that could observe them.
            if tracer is not None:
                tracer.record("put", key, node, size=_sizeof(value),
                              digest=digest)
            store.write(key, value)
            # Metadata publish is what wakes consumers; in the real system it
            # is asynchronous w.r.t. the producer container, here just cheap.
            self.directory.publish(key, _sizeof(value), node, digest=digest)
            self._note_peak()
        self.streams.notify_plain(key)   # wake get_stream fallbacks

    def get(self, node: str, key: str,
            timeout: float | None = None) -> Any:
        """Blocking Get (Table 1): may wait for the producer (§3.3.2).

        A replica whose bytes are gone (its Put raced a node failure, so the
        directory record points at a wiped store) is dropped and the wait
        restarts — recovery re-publishes the key and wakes us again.
        """
        spans = self._spans
        metrics = self._metrics
        if spans is None and metrics is None:
            return self._get_recorded(node, key, timeout)
        t0 = time.monotonic()
        sp = None
        if spans is not None:
            sp = spans.start(key, "chunk" if is_chunk_key(key) else "get",
                             node=node)
        try:
            # Activated so cross-shard hop spans nest under this Get.
            with spans.activate(sp) if spans is not None else _NULL_CTX:
                value = self._get_recorded(node, key, timeout)
        except BaseException:
            if sp is not None:
                spans.end(sp, error=True)
            raise
        if sp is not None:
            spans.end(sp, size=_sizeof(value))
        if metrics is not None:
            metrics.histogram("dstore_get_seconds").observe(
                time.monotonic() - t0)
        return value

    def _get_recorded(self, node: str, key: str,
                      timeout: float | None = None) -> Any:
        tracer = self._tracer
        if tracer is None:
            value = self._get(node, key, timeout)
        else:
            tracer.record("get_block", key, node)
            try:
                value = self._get(node, key, timeout)
            except BaseException:
                tracer.record("get_fail", key, node)
                raise
            tracer.record("get_return", key, node,
                          digest=content_digest(value))
        # The plan countdown runs after get_return is recorded: the trace
        # shows this read completing before any eviction it triggers.
        if self._plan_reads:
            self._plan_note_read(key)
        return value

    def _get(self, node: str, key: str,
             timeout: float | None = None) -> Any:
        store = self.stores[node]
        deadline = None if timeout is None else time.monotonic() + timeout
        while True:
            if store.has(key):
                return store.read(key)
            remaining = None
            if deadline is not None:
                remaining = max(deadline - time.monotonic(), 0.0)
            meta = self.directory.wait(key, remaining)
            if store.has(key):
                return store.read(key)
            try:
                src = self.directory.choose_replica(key)
            except KeyError:
                continue               # record vanished while unlocked
            try:
                value = self.stores[src].read(key)
            except KeyError:
                self.directory.release_replica(key, src)
                self.directory.drop_replica(key, src)  # phantom replica
                continue
            try:
                self.transport.move(meta.size)     # receiver-driven pull
            finally:
                self.directory.release_replica(key, src)
            # Same write→publish atomicity vs fail_node as put(): without
            # the lock a failure of `node` here would leave a phantom
            # replica that masks the data loss from recovery.
            with self._write_lock:
                if self._tracer is not None:
                    self._tracer.record("replica", key, node,
                                        size=meta.size, digest=meta.digest)
                store.write(key, value)
                self.directory.publish(key, meta.size, node,  # new replica
                                       digest=meta.digest)
                self._note_peak()
            return value

    # -- DStream chunked API (beyond-paper; see stream.py) -----------------
    def put_stream(self, node: str, key: str, *,
                   chunk_size: int = DEFAULT_CHUNK) -> StreamWriter:
        """Open a chunked writer for ``key``; chunks publish as they fill
        and wake blocked readers per chunk (§3.3.2 at chunk granularity)."""
        return StreamWriter(self, node, key, chunk_size)

    def get_stream(self, node: str, key: str,
                   timeout: float | None = None,
                   prefetch: bool = True) -> StreamReader:
        """Blocking chunk iterator over ``key``: yields chunk 0 while the
        producer may still be emitting chunk N.  Falls back to chunking a
        monolithically-Put value."""
        return StreamReader(self, node, key, timeout, prefetch)

    def put_chunk(self, node: str, key: str, idx: int, chunk: bytes) -> None:
        """One stream chunk: bytes in the local store, a directory record
        of its own (so remote pulls are chunk-granular and receiver-driven),
        and a stream-directory publish that wakes blocked readers."""
        spans = self._spans
        if spans is None:
            return self._put_chunk(node, key, idx, chunk)
        sp = spans.start(chunk_key(key, idx), "chunk_put", node=node,
                         size=len(chunk))
        try:
            return self._put_chunk(node, key, idx, chunk)
        finally:
            spans.end(sp)

    def _put_chunk(self, node: str, key: str, idx: int,
                   chunk: bytes) -> None:
        ck = chunk_key(key, idx)
        digest = content_digest(chunk)
        with self._write_lock:
            if self._tracer is not None:
                self._tracer.record("put_chunk", key, node, idx=idx,
                                    size=len(chunk), digest=digest)
                self._tracer.record("put", ck, node, size=len(chunk),
                                    digest=digest)
            self.stores[node].write(ck, chunk)
            self.directory.publish(ck, len(chunk), node, digest=digest)
            self._note_peak()
        self.streams.publish_chunk(key, idx, len(chunk))

    # -- DPlan eviction hints (see plan.py) --------------------------------
    def set_plan_reads(self, prefix: str, reads: "Mapping[str, int]") -> None:
        """Install the plan's eviction schedule for one instance: each raw
        key's statically-known read count, namespaced under ``prefix``.
        The countdown in :meth:`get` evicts a key the moment its last
        planned read returns."""
        with self._plan_lock:
            for k, n in reads.items():
                if n > 0:
                    self._plan_reads[prefix + k] = n

    def _plan_note_read(self, key: str) -> None:
        evict = False
        with self._plan_lock:
            n = self._plan_reads.get(key)
            if n is None:
                return
            if n <= 1:
                del self._plan_reads[key]
                evict = True
            else:
                self._plan_reads[key] = n - 1
        if evict:
            self.evict_key(key)

    def evict_key(self, key: str) -> None:
        """Single-key eviction: reclaim the bytes on every node plus the
        directory record.  Safe exactly when no future Get of the key can
        exist — which is what the plan's liveness analysis proves."""
        with self._write_lock:
            existed = self.directory.peek(key) is not None
            if self._tracer is not None and existed:
                self._tracer.record("evict", key)
            for store in self.stores.values():
                store.drop_key(key)
            self.directory.drop([key])
        if existed and self._spans is not None:
            self._spans.event(key, "evict", parent=None,
                              trace=_trace_of(key))

    def resident_bytes(self) -> int:
        """Bytes currently held across all node-local stores."""
        return sum(s.resident_bytes for s in self.stores.values())

    @property
    def peak_resident_bytes(self) -> int:
        """Cluster-wide peak of summed resident bytes (historic metric)."""
        return self._peak_bytes

    def peak_resident_per_node(self) -> dict[str, int]:
        """Per-node high-water marks — what capacity planning actually
        needs (a node provisions for ITS peak, not the cluster sum), and
        the measured twin of ``WorkflowPlan.peak_resident``."""
        return {n: s.peak_bytes for n, s in self.stores.items()}

    def reset_peak(self) -> None:
        self._peak_bytes = self.resident_bytes()
        for s in self.stores.values():
            s.reset_peak()

    def _note_peak(self) -> None:
        # Called with _write_lock held, right after bytes land.
        cur = self.resident_bytes()
        if cur > self._peak_bytes:
            self._peak_bytes = cur

    def evict_instance(self, prefix: str) -> None:
        """Instance-scoped eviction (serving): when a workflow instance
        completes, reclaim every key in its namespace — bytes in all local
        stores, directory records, and stream records (chunk keys share the
        instance prefix, so they are swept by the same pass).  Bounded
        memory under sustained multi-instance serving."""
        swept: list[str] = []
        with self._write_lock:
            if self._tracer is not None or self._spans is not None:
                # Recorded before the bytes are reclaimed: an in-flight
                # reader recorded earlier is a real use-after-evict hazard.
                for k in self.directory.keys():
                    if k.startswith(prefix):
                        if self._tracer is not None:
                            self._tracer.record("evict", k)
                        swept.append(k)
            for store in self.stores.values():
                store.drop_prefix(prefix)
            self.directory.drop_prefix(prefix)
        self.streams.evict_prefix(prefix)
        if self._spans is not None:
            for k in swept:
                self._spans.event(k, "evict", parent=None,
                                  trace=_trace_of(k))
        if self._plan_reads:
            with self._plan_lock:
                for k in [k for k in self._plan_reads
                          if k.startswith(prefix)]:
                    del self._plan_reads[k]

    # -- fault handling ----------------------------------------------------
    def fail_node(self, node: str) -> list[str]:
        """Simulate a node loss; returns data keys that must be recomputed."""
        # Open streams abort (blocked readers get a clean error); closed
        # streams are evicted so a recovery rerun can re-claim them.
        self.streams.fail_owner(node)
        with self._write_lock:
            if self._tracer is not None:
                self._tracer.record("fail_node", node=node)
            self.stores[node].drop_all()
            lost = self.directory.drop_node(node)
            if self._tracer is not None:
                for k in lost:
                    self._tracer.record("drop", k, node)
            return lost
