"""Experiment drivers reproducing the paper's evaluation methodology (§5).

* :func:`run_open_loop`  — Poisson-less deterministic open loop at a fixed
  invocation rate (the paper's throughput axis); p99 with the 60 s timeout
  clamp ("if one benchmark is timeout, we record its 99%-ile latency as 60s").
* :func:`run_closed_loop` — one in-flight invocation per client (the paper's
  co-location study, §5.3).
* :func:`cold_start_latency` — first-run minus second-run end-to-end latency
  (§5.4's definition).
* bandwidth utilisation = aggregate inter-node bytes moved / makespan —
  the achieved cluster-wide transfer rate the paper's §5.2 discussion uses.
"""

from __future__ import annotations

import math
from dataclasses import dataclass, field

from .dag import Workflow
# percentile is re-exported here (public API); one implementation, shared
# with the serving layer (serve.py cannot import this module — it would
# close an import cycle via sim_systems).
from .serve import percentile, poisson_arrivals
from .sim import Env, all_of
from .sim_systems import SimSystem, make_system
from .simcluster import Cluster, SimConfig

__all__ = ["ExperimentResult", "run_open_loop", "run_closed_loop",
           "cold_start_latency", "percentile"]




@dataclass
class ExperimentResult:
    system: str
    workflow: str
    latencies: list[float] = field(default_factory=list)
    timeouts: int = 0
    makespan: float = 0.0
    internode_bytes: float = 0.0
    network_busy_time: float = 0.0
    cold_starts: int = 0

    @property
    def p99(self) -> float:
        return percentile(self.latencies, 99.0)

    @property
    def p50(self) -> float:
        return percentile(self.latencies, 50.0)

    @property
    def mean(self) -> float:
        return sum(self.latencies) / max(len(self.latencies), 1)

    @property
    def bandwidth_utilization(self) -> float:
        """Achieved aggregate transfer rate while the network is in use
        (B/s): application bytes / union-of-busy-intervals.  This is the
        paper's bandwidth-utilisation notion — how much of the cluster's
        aggregate capacity the data plane can actually exploit."""
        return self.internode_bytes / max(self.network_busy_time, 1e-9)

    def row(self) -> dict:
        return {
            "system": self.system, "workflow": self.workflow,
            "p50_s": round(self.p50, 3), "p99_s": round(self.p99, 3),
            "mean_s": round(self.mean, 3), "timeouts": self.timeouts,
            "bw_util_MBps": round(self.bandwidth_utilization / (1 << 20), 2),
            "cold_starts": self.cold_starts,
        }


def _collect(sys_: SimSystem, cluster: Cluster, cfg: SimConfig,
             makespan: float) -> ExperimentResult:
    res = ExperimentResult(system=sys_.name, workflow=sys_.wf.name)
    for inst in sys_.results:
        lat = inst.latency
        if not math.isfinite(lat) or lat > cfg.timeout:
            res.timeouts += 1
            lat = cfg.timeout
        res.latencies.append(lat)
    res.makespan = makespan
    res.internode_bytes = cluster.internode_bytes()
    res.network_busy_time = cluster.network.busy_time
    res.cold_starts = cluster.cold_starts()
    return res


def run_open_loop(system: str, wf: Workflow, *, rate_per_min: float,
                  n_invocations: int = 30,
                  cfg: SimConfig | None = None,
                  warm: bool = True,
                  poisson_seed: int | None = None,
                  spans=None) -> ExperimentResult:
    """Fire ``n_invocations`` at fixed inter-arrival 60/rate seconds, or —
    with ``poisson_seed`` — at deterministic Poisson arrivals of the same
    mean rate (the serving layer's open-loop arrival process).

    ``spans``: a DScope :class:`~repro.core.obs.Tracer` — rebound to the
    virtual clock — records request/invoke/acquire spans with ``env.now``
    durations (the warm throwaway's spans are cleared)."""
    cfg = cfg or SimConfig()
    env = Env()
    if spans is not None:
        spans.set_clock(lambda: env.now)
    cluster = Cluster(env, cfg)
    sys_ = make_system(system, env, cluster, wf, spans=spans)
    gap = 60.0 / rate_per_min
    if poisson_seed is None:
        gaps = [gap] * n_invocations
    else:
        arr = poisson_arrivals(rate_per_min / 60.0, n_invocations,
                               seed=poisson_seed)
        gaps = [b - a for a, b in zip([0.0] + arr[:-1], arr)]

    if warm:
        # One throwaway invocation to populate warm containers, as the
        # paper's steady-state latency experiments do.
        sys_.invoke()
        env.run(until=cfg.timeout + 5.0)
        sys_.results.clear()
        cluster.network.log.clear()
        cluster.network.busy_time = 0.0
        if spans is not None:
            spans.clear()

    def driver():
        for g in gaps:
            sys_.invoke()
            yield env.timeout(g)
    start = env.now
    env.process(driver())
    # Horizon from the ACTUAL last arrival (Poisson gap sums can exceed
    # gap*n by several sigma; a fixed-gap horizon would cut the tail off
    # and silently clamp its latencies to the timeout).
    horizon = start + sum(gaps) + cfg.timeout * 3
    env.run(until=horizon)
    return _collect(sys_, cluster, cfg, makespan=env.now - start)


def run_closed_loop(system: str, workflows: list[Workflow], *,
                    n_per_client: int = 8,
                    cfg: SimConfig | None = None) -> list[ExperimentResult]:
    """One client per workflow, next request only after the previous
    completes (paper §5.3 co-run when len(workflows)>1, solo otherwise)."""
    cfg = cfg or SimConfig()
    env = Env()
    cluster = Cluster(env, cfg)
    systems = [make_system(system, env, cluster, wf) for wf in workflows]

    def client(sys_: SimSystem):
        for _ in range(n_per_client):
            r = sys_.invoke()
            yield r.done
    procs = [env.process(client(s)) for s in systems]
    env.run(until=(cfg.timeout * n_per_client * 4))
    makespan = env.now
    return [_collect(s, cluster, cfg, makespan) for s in systems]


def cold_start_latency(system: str, wf: Workflow,
                       cfg: SimConfig | None = None) -> float:
    """First-run latency minus second-run latency (paper §5.4)."""
    cfg = cfg or SimConfig()
    env = Env()
    cluster = Cluster(env, cfg)
    sys_ = make_system(system, env, cluster, wf)
    r1 = sys_.invoke()
    env.run(until=cfg.timeout * 3)
    first = r1.latency
    r2 = sys_.invoke()
    env.run(until=env.now + cfg.timeout * 3)
    second = r2.latency
    return first - second
