"""DCheck static half — workflow linter with stable diagnostic codes.

DFlow's correctness rests on structural properties of the workflow DAG
(§3.1/§3.3): single-producer keys, data edges derived purely from key
names, stream contracts agreed between producer and consumer, keys that
never collide with the serving layer's instance-namespace scheme.  Today a
violation of any of these either raises a bare ``ValueError`` deep inside
:class:`~repro.core.dag.Workflow`, silently degrades (a typo'd
``output_sizes`` key used to default every estimate to 1 MB), or — worst —
deadlocks the threaded engine at run time (a self-consumed key drops its
edge in the DAG build and the function then blocks on a Get of its own
output).

``lint()`` turns each of those defect classes into a :class:`Diagnostic`
with a stable code (``DF001``...), a severity, and a fix-it hint:

=======  ========  =====================================================
code     severity  meaning
=======  ========  =====================================================
DF000    error     workflow does not parse / construct at all
DF001    info      by-product output: produced by a non-exit function but
                   never consumed (still collected as a sink result)
DF002    warning   disconnected function: no inputs at all and no
                   consumed outputs — no data edge ties it to the DAG
DF003    error     self-consumed key: function consumes its own output
                   (the edge is dropped; the engine deadlocks on Get)
DF004    info      stream output consumed monolithically (pipelining
                   lost on that edge; the monolithic twin is used)
DF005    info      stream input whose producer does not stream the key
                   (reader falls back to chunking the whole value)
DF006    warning   producer/consumer chunk_size disagreement on a
                   streamed edge
DF007    error     output_sizes entry names a non-output key (size
                   estimates silently fell back to the 1 MB default)
DF008    error     key contains ':' or '#' — collides with DServe's
                   "<wf>#<i>:<key>" instance namespace / DStream's
                   "::chunk.<i>" scheme
DF009    warning*  suspicious glob: matches no produced key (error),
                   keys of multiple distinct producer families, or the
                   declaring function's own outputs
DF010    error*    missing fn binding for a function with declared
                   outputs when an engine run is requested (warning for
                   a mixed bound/unbound workflow without that request)
DF011    error     duplicate producer: two functions output one key
DF012    error     foreach expansion collides with an explicitly
                   declared function name
DF013    error     dependency cycle
DF014    warning   undeclared external input: external_inputs declares
                   some keys but another consumed key silently defaults
                   to a 1 MB external (likely a typo'd input)
DF015    error     invalid resource spec (negative exec_time/cold_start,
                   non-positive cpu)
DF016    warning   declared stream edge can never pipeline (emitted by
                   the DPlan analyzer, not ``lint_workflow``: the
                   consumer also waits on data that only exists after
                   the stream closes)
DF017    info      chunk size defeats stream pipelining (emitted by the
                   DPlan analyzer: the whole stream fits one chunk)
=======  ========  =====================================================

Two entry points: :func:`lint_workflow` checks a constructed
:class:`~repro.core.dag.Workflow`; :func:`lint` additionally accepts a
raw document (dict or YAML text), running the doc-level passes (DF007,
DF009, DF011-DF013) *before* construction so defects that
``parse_workflow`` rejects still get a code instead of a traceback.
:func:`check_workflow` is the engine hook: raise :class:`WorkflowLintError`
when any error-severity diagnostic fires.
"""

from __future__ import annotations

import re
from dataclasses import dataclass
from typing import Any, Callable, Iterable, Mapping

from .dag import Workflow, _expand_foreach

__all__ = [
    "Diagnostic", "WorkflowLintError", "CODES", "SEVERITIES",
    "lint", "lint_workflow", "lint_doc", "check_workflow", "max_severity",
]

SEVERITIES = ("info", "warning", "error")

#: code -> (default severity, one-line title)
CODES: dict[str, tuple[str, str]] = {
    "DF000": ("error", "workflow fails to parse/construct"),
    "DF001": ("info", "by-product output (produced, never consumed)"),
    "DF002": ("warning", "disconnected function (no data edges)"),
    "DF003": ("error", "self-consumed key (dropped edge; engine deadlock)"),
    "DF004": ("info", "stream output consumed monolithically"),
    "DF005": ("info", "stream input from a non-streaming producer"),
    "DF006": ("warning", "chunk_size mismatch on streamed edge"),
    "DF007": ("error", "output_sizes names a non-output key"),
    "DF008": ("error", "key collides with instance-namespace separators"),
    "DF009": ("warning", "suspicious glob resolution"),
    "DF010": ("error", "missing fn binding for engine run"),
    "DF011": ("error", "duplicate producer for key"),
    "DF012": ("error", "foreach expansion name collision"),
    "DF013": ("error", "dependency cycle"),
    "DF014": ("warning", "undeclared external input"),
    "DF015": ("error", "invalid resource spec"),
    # DF016/DF017 are registered here for stable numbering/severities but
    # emitted by the DPlan analyzer (repro.core.plan), which sees sizes
    # and placement; lint_workflow stays purely structural.
    "DF016": ("warning", "declared stream edge can never pipeline"),
    "DF017": ("info", "chunk size defeats stream pipelining"),
}

# Separators reserved by the data plane: DServe namespaces instance keys
# as "<wf>#<i>:<key>" (strip_ns prefix-matches on ':'), DStream appends
# "::chunk.<i>" to stream keys.
_RESERVED = (":", "#")


@dataclass(frozen=True)
class Diagnostic:
    """One linter finding with a stable code and a fix-it hint."""

    code: str
    message: str
    function: str | None = None      # offending function, when attributable
    key: str | None = None           # offending data key, when attributable
    hint: str | None = None
    severity: str = ""               # defaults to the code's registry entry

    def __post_init__(self) -> None:
        if not self.severity:
            object.__setattr__(self, "severity", CODES[self.code][0])

    def format(self) -> str:
        where = f" [{self.function}]" if self.function else ""
        hint = f"  (fix: {self.hint})" if self.hint else ""
        return f"{self.code} {self.severity}{where}: {self.message}{hint}"


class WorkflowLintError(ValueError):
    """Raised by :func:`check_workflow` when error diagnostics fire."""

    def __init__(self, wf_name: str, diagnostics: list[Diagnostic]):
        self.diagnostics = diagnostics
        lines = "\n  ".join(d.format() for d in diagnostics)
        super().__init__(
            f"workflow {wf_name!r} failed lint "
            f"({len(diagnostics)} error(s)):\n  {lines}")


def max_severity(diagnostics: Iterable[Diagnostic]) -> str | None:
    """Highest severity present, or None for a clean result."""
    worst = -1
    for d in diagnostics:
        worst = max(worst, SEVERITIES.index(d.severity))
    return SEVERITIES[worst] if worst >= 0 else None


# ----------------------------------------------------------------------
# Workflow-level passes (constructed Workflow objects)
# ----------------------------------------------------------------------

def lint_workflow(wf: Workflow, *,
                  require_fns: bool = False) -> list[Diagnostic]:
    """Run every semantic pass over a constructed Workflow.

    ``require_fns`` marks an intended *engine* run: every function with
    declared outputs must carry a real callable (the engine raises a
    KeyError mid-flight otherwise).
    """
    out: list[Diagnostic] = []
    consumed: dict[str, list[str]] = {}
    for f in wf.functions.values():
        for k in f.inputs:
            consumed.setdefault(k, []).append(f.name)
    exit_set = set(wf.exit_points)

    for f in wf.functions.values():
        # DF003 — before anything else: dag.py's edge derivation skips
        # p == f.name, so the dependency silently vanishes and the engine
        # blocks on a Get of a key only this very function will ever Put.
        for k in f.inputs:
            if k in f.outputs:
                out.append(Diagnostic(
                    "DF003", f"{f.name!r} consumes its own output {k!r}; "
                    "the edge is dropped and the engine deadlocks on Get",
                    function=f.name, key=k,
                    hint="rename the output or read the upstream key"))

        # DF001 — a non-exit function's output nobody reads.  It is still
        # collected as a sink by-product, so only informational.
        if f.name not in exit_set:
            for k in f.outputs:
                if k not in consumed:
                    out.append(Diagnostic(
                        "DF001", f"output {k!r} of {f.name!r} is never "
                        "consumed (collected as a by-product sink)",
                        function=f.name, key=k,
                        hint="consume it, or drop it from outputs"))

        # DF002 — no data edge at all ties the function to the workflow.
        if (len(wf) > 1 and not f.inputs
                and not any(k in consumed for k in f.outputs)):
            out.append(Diagnostic(
                "DF002", f"{f.name!r} has no inputs and none of its "
                "outputs are consumed — disconnected from the DAG",
                function=f.name,
                hint="wire it to the workflow or remove it"))

        # DF008 — reserved separators in data keys.
        for k in (*f.outputs, *f.inputs):
            if any(s in k for s in _RESERVED):
                out.append(Diagnostic(
                    "DF008", f"key {k!r} contains a reserved separator "
                    "(':' or '#'); DServe namespaces keys as "
                    "'<wf>#<i>:<key>' and DStream as '<key>::chunk.<i>'",
                    function=f.name, key=k,
                    hint="use '.', '-' or '_' inside key names"))

        # DF015 — resource fields FunctionSpec does not validate.
        if f.exec_time < 0 or f.cold_start < 0 or f.cpu <= 0:
            out.append(Diagnostic(
                "DF015", f"{f.name!r} has invalid resources "
                f"(exec_time={f.exec_time}, cold_start={f.cold_start}, "
                f"cpu={f.cpu})", function=f.name,
                hint="exec_time/cold_start must be >= 0 and cpu > 0"))

        # DF005 / DF006 — consumer-side stream contract.
        for k in f.stream_inputs:
            p = wf.producer.get(k)
            if p is None or p == f.name:
                out.append(Diagnostic(
                    "DF005", f"{f.name!r} streams input {k!r} but no "
                    "producer streams it (external or monolithic key); "
                    "the reader falls back to chunking the whole value",
                    function=f.name, key=k,
                    hint="declare it in the producer's stream_outputs"))
                continue
            prod = wf.functions[p]
            if k not in prod.stream_outputs:
                out.append(Diagnostic(
                    "DF005", f"{f.name!r} streams input {k!r} but its "
                    f"producer {p!r} puts it monolithically; no "
                    "pipelining on this edge", function=f.name, key=k,
                    hint=f"add {k!r} to {p!r}.stream_outputs"))
            elif prod.chunk_size != f.chunk_size:
                out.append(Diagnostic(
                    "DF006", f"streamed edge {p!r} -> {f.name!r} on {k!r} "
                    f"disagrees on chunk_size ({prod.chunk_size} vs "
                    f"{f.chunk_size}); chunks arrive producer-sized",
                    function=f.name, key=k,
                    hint="align both chunk_size declarations"))

        # DF004 — producer streams, some consumer reads monolithically.
        for k in f.stream_outputs:
            for c in consumed.get(k, ()):
                cf = wf.functions[c]
                if k not in cf.stream_inputs:
                    out.append(Diagnostic(
                        "DF004", f"{f.name!r} streams output {k!r} but "
                        f"{c!r} consumes it monolithically (waits for "
                        "close; pipelining lost on this edge)",
                        function=c, key=k,
                        hint=f"add {k!r} to {c!r}.stream_inputs"))

    # DF008 also applies to declared external inputs (they become keys).
    for k in wf.external_inputs:
        if any(s in k for s in _RESERVED):
            out.append(Diagnostic(
                "DF008", f"external input {k!r} contains a reserved "
                "separator (':' or '#')", key=k,
                hint="use '.', '-' or '_' inside key names"))

    # DF014 — partially declared externals: the undeclared ones silently
    # became 1 MB defaults, the classic signature of a typo'd input key.
    if wf.declared_external:
        for k in wf.external_inputs:
            if k not in wf.declared_external:
                out.append(Diagnostic(
                    "DF014", f"input {k!r} is not produced by any "
                    "function and not declared in external_inputs; it "
                    "silently defaulted to a 1 MB external",
                    key=k, hint="declare it in external_inputs or fix "
                    "the input key"))

    # DF010 — fn bindings.  With require_fns every output-bearing function
    # needs a callable; otherwise a *mixed* workflow (some bound, some
    # not) is flagged as a likely forgotten binding.
    unbound = [f.name for f in wf.functions.values()
               if f.fn is None and f.outputs]
    bound_any = any(f.fn is not None for f in wf.functions.values())
    if require_fns:
        for name in unbound:
            out.append(Diagnostic(
                "DF010", f"{name!r} has declared outputs but no fn "
                "binding; an engine run would fail mid-flight",
                function=name,
                hint="bind a callable via parse_workflow(doc, fns=...)"))
    elif bound_any and unbound:
        for name in unbound:
            out.append(Diagnostic(
                "DF010", f"{name!r} has no fn binding while other "
                "functions are bound (forgotten binding?)",
                function=name, severity="warning",
                hint="bind a callable or drop the other bindings"))
    return out


# ----------------------------------------------------------------------
# Doc-level passes (raw workflow.yaml documents, pre-construction)
# ----------------------------------------------------------------------

_FOREACH_SUFFIX = re.compile(r"\.\d+$")


def _family(name: str) -> str:
    """Producer family of an expanded function: 'count.3' -> 'count'."""
    return _FOREACH_SUFFIX.sub("", name)


def _doc_passes(doc: Mapping[str, Any]) -> tuple[list[Diagnostic], bool]:
    """Structural checks on the raw document.  Returns (diagnostics,
    constructible) — construction is skipped when a defect
    ``parse_workflow`` would reject was found."""
    out: list[Diagnostic] = []
    expanded: list[tuple[str, dict]] = []
    for fname, spec in (doc.get("functions") or {}).items():
        try:
            expanded.extend(_expand_foreach(fname, spec))
        except (TypeError, ValueError) as exc:
            out.append(Diagnostic(
                "DF000", f"foreach of {fname!r} fails to expand: {exc}",
                function=fname))
            return out, False

    # DF012 — expansion collides with an explicit declaration.
    seen: set[str] = set()
    for fname, _ in expanded:
        if fname in seen:
            out.append(Diagnostic(
                "DF012", f"function {fname!r} declared twice (foreach "
                "expansion collides with an explicit function)",
                function=fname,
                hint="rename the explicit function or shrink the foreach"))
        seen.add(fname)

    # DF011 — duplicate producer across the expanded set.
    producer: dict[str, str] = {}
    for fname, spec in expanded:
        for k in spec.get("outputs") or ():
            if k in producer and producer[k] != fname:
                out.append(Diagnostic(
                    "DF011", f"key {k!r} produced by both "
                    f"{producer[k]!r} and {fname!r} (DStore keys are "
                    "single-producer)", function=fname, key=k,
                    hint="give each producer a distinct output key"))
            else:
                producer[k] = fname

    # DF007 — output_sizes naming non-output keys.
    for fname, spec in expanded:
        outputs = set(spec.get("outputs") or ())
        for k in (spec.get("output_sizes") or {}):
            if k not in outputs:
                out.append(Diagnostic(
                    "DF007", f"{fname!r} sizes unknown key {k!r}; "
                    "simulator estimates would fall back to the 1 MB "
                    "default", function=fname, key=k,
                    hint=f"name one of {sorted(outputs)}"))

    # DF009 — suspicious glob resolutions (an input ending in '*').
    produced = set(producer)
    resolved_inputs: dict[str, list[str]] = {}
    for fname, spec in expanded:
        keys: list[str] = []
        for k in spec.get("inputs") or ():
            if not k.endswith("*"):
                keys.append(k)
                continue
            matches = sorted(p for p in produced if p.startswith(k[:-1]))
            keys.extend(matches)
            own = set(spec.get("outputs") or ())
            if not matches:
                out.append(Diagnostic(
                    "DF009", f"glob {k!r} in {fname!r} matches no "
                    "produced key", function=fname, key=k,
                    severity="error",
                    hint="fix the prefix or drop the glob"))
            elif own & set(matches):
                out.append(Diagnostic(
                    "DF009", f"glob {k!r} in {fname!r} matches its own "
                    f"output(s) {sorted(own & set(matches))}",
                    function=fname, key=k,
                    hint="narrow the glob prefix"))
            else:
                fams = {_family(producer[m]) for m in matches}
                if len(fams) > 1:
                    out.append(Diagnostic(
                        "DF009", f"glob {k!r} in {fname!r} matches keys "
                        f"from {len(fams)} distinct producers "
                        f"({sorted(fams)}) — likely over-matching",
                        function=fname, key=k,
                        hint="lengthen the glob prefix"))
        resolved_inputs[fname] = keys

    # DF013 — cycle over the resolved edge set (construction would raise).
    succ: dict[str, set[str]] = {n: set() for n, _ in expanded}
    indeg = {n: 0 for n, _ in expanded}
    for fname, _ in expanded:
        for k in resolved_inputs.get(fname, ()):
            p = producer.get(k)
            if p is not None and p != fname and fname not in succ[p]:
                succ[p].add(fname)
                indeg[fname] += 1
    ready = [n for n, d in indeg.items() if d == 0]
    done = 0
    while ready:
        n = ready.pop()
        done += 1
        for s in succ[n]:
            indeg[s] -= 1
            if indeg[s] == 0:
                ready.append(s)
    if done != len(indeg):
        cyc = sorted(n for n, d in indeg.items() if d > 0)
        out.append(Diagnostic(
            "DF013", f"dependency cycle through {cyc}",
            hint="break the cycle (keys are immutable; no in-place "
            "updates)"))

    blocking = {"DF007", "DF009", "DF011", "DF012", "DF013", "DF000"}
    constructible = not any(
        d.code in blocking and d.severity == "error" for d in out)
    return out, constructible


def lint_doc(doc: Mapping[str, Any] | str,
             fns: Mapping[str, Callable] | None = None, *,
             require_fns: bool = False) -> list[Diagnostic]:
    """Lint a raw workflow document (dict or YAML text): doc-level passes
    first, then — when the document is constructible — the full
    :func:`lint_workflow` pass over the parsed result."""
    from .dag import parse_workflow

    if isinstance(doc, str):
        import io

        import yaml
        try:
            doc = yaml.safe_load(io.StringIO(doc))
        except yaml.YAMLError as exc:
            return [Diagnostic("DF000", f"YAML does not parse: {exc}")]
    if not isinstance(doc, Mapping) or "functions" not in doc:
        return [Diagnostic(
            "DF000", "document has no 'functions' mapping",
            hint="see dag.py's module docstring for the schema")]

    out, constructible = _doc_passes(doc)
    if not constructible:
        return out
    try:
        wf = parse_workflow(doc, fns)
    except (ValueError, KeyError, TypeError) as exc:
        out.append(Diagnostic(
            "DF000", f"workflow fails to construct: {exc}"))
        return out
    dedup = {(d.code, d.function, d.key) for d in out}
    for d in lint_workflow(wf, require_fns=require_fns):
        if (d.code, d.function, d.key) not in dedup:
            out.append(d)
    return out


def lint(source: Workflow | Mapping[str, Any] | str,
         fns: Mapping[str, Callable] | None = None, *,
         require_fns: bool = False) -> list[Diagnostic]:
    """Lint a Workflow object, a parsed document, or YAML text."""
    if isinstance(source, Workflow):
        return lint_workflow(source, require_fns=require_fns)
    return lint_doc(source, fns, require_fns=require_fns)


def check_workflow(wf: Workflow, *, require_fns: bool = False) -> None:
    """Engine pre-flight: raise :class:`WorkflowLintError` when any
    error-severity diagnostic fires (deadlocks, namespace collisions and
    missing bindings are cheaper to reject here than to debug as a
    wedged Get two layers down)."""
    errors = [d for d in lint_workflow(wf, require_fns=require_fns)
              if d.severity == "error"]
    if errors:
        raise WorkflowLintError(wf.name, errors)
