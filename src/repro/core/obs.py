"""DScope — unified observability: metrics, span tracing, plan-vs-actual.

DFlow's headline claims are all *measurements* (99%-ile latency, bandwidth
utilization, cold-start latency), and real orchestrators are debugged with
trigger/event-level visibility (Triggerflow) and per-request breakdowns
(the empirical serverless-workflow study).  Before DScope this repo's
telemetry was scattered — :class:`~repro.core.serve.ContainerPool` kept its
own lifecycle counters, :class:`~repro.core.router.RoutingTable` /
``TieredTransport`` their hit/miss/``tier_bytes``/``hop_hist``,
:class:`~repro.core.dstore.LocalStore` its byte peaks — and
``ServeReport`` hand-aggregated a subset.  DScope is the single layer the
threaded engine, DServe, the simulator and the sharded store all report
through:

* :class:`MetricsRegistry` — thread-safe counters / gauges / histograms
  with label sets.  Subsystems register *collectors* (pull-style scrape
  callbacks, zero hot-path cost) via their ``register_metrics`` methods;
  hot-path latency histograms (per-Get, per-chunk) are pushed only when a
  registry is *attached* (``attach_metrics``, mirroring the DCheck
  ``attach_tracer`` zero-cost-when-off pattern).
* :class:`Tracer` / :class:`Span` — per-request span trees:
  request → function invocation → container acquire → per-Get/Put →
  per-chunk stream transfer → cross-shard hop.  Ordering comes from a
  logical clock (optionally shared with DCheck's
  :class:`~repro.core.check.TraceRecorder` so spans and invariant events
  interleave consistently); durations come from an injectable clock —
  wall clock in the threaded engine, ``env.now`` in the simulator.
* Exporters — JSON-lines (:func:`write_spans_jsonl` /
  :func:`read_spans_jsonl`, with the plan attribution doc embedded as a
  meta line so a span file is self-contained) and Chrome ``trace_event``
  JSON (:func:`to_chrome_trace`) that opens directly in Perfetto /
  ``chrome://tracing`` as a per-request flamegraph.
* Plan-vs-actual attribution (:func:`attribute`) — joins spans against
  DPlan's ``est``/``eft``/slack/``boot_at`` to report per-function
  critical-path drift, prewarm lead-time accuracy, and eviction-timing
  lag, turning the static plan into a live drift detector.
* The standardized ``BENCH_*.json`` schema (``dflow-bench/v1``):
  :func:`bench_metric` rows (system, metric, value, units, optional
  regression direction) + :func:`compare_docs`, the engine behind
  ``benchmarks/bench_compare.py``'s PR-over-PR regression gate.

CLI: ``python -m repro.obs`` (summarize / attribute / perfetto / diff).
"""

from __future__ import annotations

import json
import math
import threading
import time
from contextlib import contextmanager
from dataclasses import dataclass, field
from typing import Any, Callable, Iterable, Mapping

__all__ = [
    "MetricsRegistry", "Span", "Tracer",
    "write_spans_jsonl", "read_spans_jsonl", "to_chrome_trace",
    "plan_attribution", "attribute",
    "BENCH_SCHEMA", "bench_metric", "bench_doc", "compare_docs",
]


# ----------------------------------------------------------------------
# MetricsRegistry: counters / gauges / histograms with label sets
# ----------------------------------------------------------------------

class _Counter:
    """Monotonic counter.  ``set`` exists for collectors that scrape a
    subsystem's own authoritative count into the registry."""

    __slots__ = ("_lock", "_value")

    def __init__(self) -> None:
        self._lock = threading.Lock()
        self._value = 0.0

    def inc(self, n: float = 1.0) -> None:
        with self._lock:
            self._value += n

    def set(self, v: float) -> None:
        with self._lock:
            self._value = float(v)

    @property
    def value(self) -> float:
        with self._lock:
            return self._value


class _Gauge(_Counter):
    """Point-in-time value; ``add`` for up/down tracking."""

    __slots__ = ()

    def add(self, n: float) -> None:
        self.inc(n)


# Log2 bucket bounds from 1 µs to ~1000 s (histograms estimate tails from
# buckets only when the exact reservoir overflowed).
_BUCKETS = tuple(2.0 ** e for e in range(-20, 11))
_SAMPLE_CAP = 4096


class _Histogram:
    """Thread-safe histogram: count/sum/min/max + log2 buckets, plus an
    exact sample reservoir (first ``_SAMPLE_CAP`` observations) so
    percentiles are exact for typical benchmark-sized runs."""

    __slots__ = ("_lock", "count", "sum", "min", "max", "_buckets",
                 "_samples")

    def __init__(self) -> None:
        self._lock = threading.Lock()
        self.count = 0
        self.sum = 0.0
        self.min = math.inf
        self.max = -math.inf
        self._buckets = [0] * (len(_BUCKETS) + 1)
        self._samples: list[float] = []

    def observe(self, v: float) -> None:
        v = float(v)
        with self._lock:
            self.count += 1
            self.sum += v
            if v < self.min:
                self.min = v
            if v > self.max:
                self.max = v
            lo, hi = 0, len(_BUCKETS)
            while lo < hi:                    # first bucket bound >= v
                mid = (lo + hi) // 2
                if _BUCKETS[mid] < v:
                    lo = mid + 1
                else:
                    hi = mid
            self._buckets[lo] += 1
            if len(self._samples) < _SAMPLE_CAP:
                self._samples.append(v)

    def percentile(self, q: float) -> float:
        with self._lock:
            if not self.count:
                return math.nan
            if len(self._samples) == self.count:
                v = sorted(self._samples)
                pos = (len(v) - 1) * q / 100.0
                lo = int(pos)
                hi = min(lo + 1, len(v) - 1)
                frac = pos - lo
                return v[lo] * (1 - frac) + v[hi] * frac
            # Reservoir overflowed: upper-bound estimate from buckets.
            target = self.count * q / 100.0
            seen = 0
            for i, n in enumerate(self._buckets):
                seen += n
                if seen >= target:
                    return _BUCKETS[min(i, len(_BUCKETS) - 1)]
            return self.max

    def snapshot(self) -> dict:
        with self._lock:
            if not self.count:
                return {"count": 0}
            return {
                "count": self.count,
                "sum": self.sum,
                "min": self.min,
                "max": self.max,
                "mean": self.sum / self.count,
            } | ({} if len(self._samples) != self.count else {})

    def summary(self) -> dict:
        s = self.snapshot()
        if s["count"]:
            s["p50"] = self.percentile(50.0)
            s["p99"] = self.percentile(99.0)
        return s


def _label_key(labels: Mapping[str, Any]) -> tuple:
    return tuple(sorted((k, str(v)) for k, v in labels.items()))


def _render(name: str, labels: tuple) -> str:
    if not labels:
        return name
    inner = ",".join(f"{k}={v}" for k, v in labels)
    return f"{name}{{{inner}}}"


class MetricsRegistry:
    """Thread-safe metric registry with label sets and pull collectors.

    Direct instruments (``counter`` / ``gauge`` / ``histogram``) get-or-
    create a metric keyed by ``(name, labels)``; a name is bound to one
    instrument type.  ``register_collector(fn)`` adds a scrape callback
    run by :meth:`collect` — subsystems keep their own counters and the
    registry reads them on demand, so an idle registry costs nothing on
    the hot path.
    """

    def __init__(self) -> None:
        self._lock = threading.Lock()
        self._metrics: dict[tuple[str, tuple], Any] = {}
        self._types: dict[str, type] = {}
        self._collectors: list[Callable[[], None]] = []

    def _get(self, cls: type, name: str, labels: Mapping[str, Any]):
        key = (name, _label_key(labels))
        with self._lock:
            bound = self._types.setdefault(name, cls)
            if bound is not cls:
                raise ValueError(
                    f"metric {name!r} already registered as "
                    f"{bound.__name__}, not {cls.__name__}")
            m = self._metrics.get(key)
            if m is None:
                m = self._metrics[key] = cls()
            return m

    def counter(self, name: str, **labels: Any) -> _Counter:
        return self._get(_Counter, name, labels)

    def gauge(self, name: str, **labels: Any) -> _Gauge:
        return self._get(_Gauge, name, labels)

    def histogram(self, name: str, **labels: Any) -> _Histogram:
        return self._get(_Histogram, name, labels)

    def register_collector(self, fn: Callable[[], None]) -> None:
        with self._lock:
            self._collectors.append(fn)

    # -- reads -------------------------------------------------------------
    def total(self, name: str) -> float:
        """Sum of a counter/gauge across all label sets (0.0 if absent)."""
        with self._lock:
            items = [(k, m) for k, m in self._metrics.items()
                     if k[0] == name]
        return sum(m.value for _, m in items
                   if isinstance(m, _Counter))

    def label_values(self, name: str, label: str) -> dict[str, float]:
        """``{label value: summed metric value}`` for one label name —
        e.g. ``label_values("dstore_peak_resident_bytes", "node")``."""
        with self._lock:
            items = [(dict(k[1]), m) for k, m in self._metrics.items()
                     if k[0] == name and isinstance(m, _Counter)]
        out: dict[str, float] = {}
        for labels, m in items:
            if label in labels:
                out[labels[label]] = out.get(labels[label], 0.0) + m.value
        return out

    def collect(self) -> dict:
        """Run every collector, then return :meth:`dump`."""
        with self._lock:
            collectors = list(self._collectors)
        for fn in collectors:
            fn()
        return self.dump()

    def dump(self) -> dict:
        """Point-in-time dump: ``{"counters": {...}, "gauges": {...},
        "histograms": {...}}`` with ``name{label=value,...}`` keys."""
        with self._lock:
            items = sorted(self._metrics.items())
        out: dict[str, dict] = {"counters": {}, "gauges": {},
                                "histograms": {}}
        for (name, labels), m in items:
            key = _render(name, labels)
            if isinstance(m, _Histogram):
                out["histograms"][key] = m.summary()
            elif isinstance(m, _Gauge):
                out["gauges"][key] = m.value
            else:
                out["counters"][key] = m.value
        return out

    def __len__(self) -> int:
        with self._lock:
            return len(self._metrics)


# ----------------------------------------------------------------------
# Tracer: per-request span trees
# ----------------------------------------------------------------------

@dataclass
class Span:
    """One timed operation in a request's tree.

    ``seq``/``end_seq`` order spans on the shared logical clock (ties in
    ``start`` are possible under a virtual clock); ``trace`` groups the
    spans of one workflow instance (the ``#``-namespaced instance id)."""

    id: int
    parent: int | None
    trace: str
    name: str
    kind: str         # request | invoke | acquire | get | put | chunk |
    #                   chunk_put | hop | evict
    start: float
    seq: int
    end: float = math.nan
    end_seq: int = 0
    attrs: dict = field(default_factory=dict)

    @property
    def duration(self) -> float:
        return self.end - self.start

    def to_doc(self) -> dict:
        return {"id": self.id, "parent": self.parent, "trace": self.trace,
                "name": self.name, "kind": self.kind, "start": self.start,
                "end": self.end, "seq": self.seq, "end_seq": self.end_seq,
                "attrs": self.attrs}

    @classmethod
    def from_doc(cls, d: Mapping) -> "Span":
        return cls(id=d["id"], parent=d["parent"], trace=d["trace"],
                   name=d["name"], kind=d["kind"], start=d["start"],
                   seq=d["seq"], end=d["end"], end_seq=d.get("end_seq", 0),
                   attrs=dict(d.get("attrs") or {}))


_USE_CURRENT = object()


class Tracer:
    """Span factory with a thread-local active-span context.

    ``start`` defaults a new span's parent to the calling thread's active
    span, so data-plane spans created deep inside :class:`~repro.core.
    dstore.DStore` automatically nest under the function-invocation span
    the engine activated on that thread.  Cross-thread parenting (the
    stream prefetch pump) captures a parent explicitly and re-activates
    it with :meth:`activate`.

    ``clock`` is injectable: ``time.monotonic`` (default) in the threaded
    engine, ``lambda: env.now`` in the simulator (:meth:`set_clock`).
    ``recorder`` shares DCheck's :class:`~repro.core.check.TraceRecorder`
    logical clock so span ``seq`` values interleave consistently with
    invariant-trace events; without one the tracer counts on its own.
    """

    def __init__(self, clock: Callable[[], float] = time.monotonic, *,
                 recorder=None):
        self._clock = clock
        self._recorder = recorder
        self._lock = threading.Lock()
        self._seq = 0
        self._next_id = 0
        self._finished: list[Span] = []
        self._tls = threading.local()

    def set_clock(self, clock: Callable[[], float]) -> None:
        self._clock = clock

    def _tick(self) -> int:
        if self._recorder is not None:
            return self._recorder.tick()
        with self._lock:
            self._seq += 1
            return self._seq

    # -- span lifecycle ----------------------------------------------------
    def start(self, name: str, kind: str = "span", *,
              parent: Any = _USE_CURRENT, trace: str | None = None,
              **attrs: Any) -> Span:
        if parent is _USE_CURRENT:
            parent = self.current()
        with self._lock:
            self._next_id += 1
            sid = self._next_id
        if trace is None:
            trace = parent.trace if parent is not None else ""
        return Span(id=sid, parent=parent.id if parent else None,
                    trace=trace, name=name, kind=kind,
                    start=self._clock(), seq=self._tick(), attrs=attrs)

    def end(self, span: Span | None, **attrs: Any) -> None:
        """Close a span (idempotent; attrs merge in)."""
        if span is None or not math.isnan(span.end):
            return
        span.end = self._clock()
        span.end_seq = self._tick()
        if attrs:
            span.attrs.update(attrs)
        with self._lock:
            self._finished.append(span)

    def event(self, name: str, kind: str = "event", *,
              parent: Any = _USE_CURRENT, trace: str | None = None,
              **attrs: Any) -> Span:
        """Zero-duration span (e.g. an eviction instant)."""
        sp = self.start(name, kind, parent=parent, trace=trace, **attrs)
        sp.end = sp.start
        sp.end_seq = sp.seq
        with self._lock:
            self._finished.append(sp)
        return sp

    # -- thread-local context ----------------------------------------------
    def current(self) -> Span | None:
        stack = getattr(self._tls, "stack", None)
        return stack[-1] if stack else None

    @contextmanager
    def activate(self, span: Span | None):
        """Make ``span`` the calling thread's active span (no end)."""
        stack = getattr(self._tls, "stack", None)
        if stack is None:
            stack = self._tls.stack = []
        stack.append(span)
        try:
            yield span
        finally:
            stack.pop()

    @contextmanager
    def span(self, name: str, kind: str = "span", *,
             parent: Any = _USE_CURRENT, trace: str | None = None,
             **attrs: Any):
        """start + activate + end in one context manager."""
        sp = self.start(name, kind, parent=parent, trace=trace, **attrs)
        try:
            with self.activate(sp):
                yield sp
        finally:
            self.end(sp)

    def annotate(self, **attrs: Any) -> None:
        sp = self.current()
        if sp is not None:
            sp.attrs.update(attrs)

    # -- results -----------------------------------------------------------
    def finished(self) -> list[Span]:
        """Closed spans, ordered by logical start ``seq``.  Spans never
        ended (an in-flight request) are not exported."""
        with self._lock:
            return sorted(self._finished, key=lambda s: s.seq)

    def clear(self) -> None:
        with self._lock:
            self._finished.clear()

    def __len__(self) -> int:
        with self._lock:
            return len(self._finished)


# ----------------------------------------------------------------------
# Exporters
# ----------------------------------------------------------------------

def write_spans_jsonl(spans: Iterable[Span], path: str, *,
                      plan: Mapping | None = None,
                      meta: Mapping | None = None) -> int:
    """One span per line; the first line is a meta record (schema tag,
    optional plan attribution doc) so the file is self-contained for
    :func:`attribute`.  Returns the span count written."""
    head = {"dscope": "spans/v1", "plan": dict(plan) if plan else None}
    if meta:
        head.update(meta)
    n = 0
    with open(path, "w", encoding="utf-8") as fh:
        fh.write(json.dumps(head) + "\n")
        for sp in spans:
            fh.write(json.dumps(sp.to_doc()) + "\n")
            n += 1
    return n


def read_spans_jsonl(path: str) -> tuple[list[Span], dict]:
    """Inverse of :func:`write_spans_jsonl`: ``(spans, meta)``."""
    spans: list[Span] = []
    meta: dict = {}
    with open(path, "r", encoding="utf-8") as fh:
        for line in fh:
            line = line.strip()
            if not line:
                continue
            doc = json.loads(line)
            if "dscope" in doc and "id" not in doc:
                meta = doc
            else:
                spans.append(Span.from_doc(doc))
    return spans, meta


def to_chrome_trace(spans: Iterable[Span]) -> dict:
    """Chrome ``trace_event`` JSON (loads in Perfetto / chrome://tracing).

    pid = request (trace id); tid = the request's direct child subtree the
    span belongs to (each function invocation gets its own lane, so
    same-lane complete events nest by time containment into the expected
    request → invoke → get/put → chunk flamegraph).  Zero-duration spans
    (evictions) become instant events.
    """
    spans = list(spans)
    by_id = {s.id: s for s in spans}
    pids: dict[str, int] = {}
    lane_names: dict[tuple[int, int], str] = {}
    t0 = min((s.start for s in spans), default=0.0)

    def pid_of(trace: str) -> int:
        if trace not in pids:
            pids[trace] = len(pids) + 1
        return pids[trace]

    def lane_of(s: Span) -> int:
        # Walk up to the child-of-request ancestor; requests lane 0.
        cur = s
        while cur.parent is not None:
            parent = by_id.get(cur.parent)
            if parent is None or parent.kind == "request":
                return cur.id
            cur = parent
        return 0

    events: list[dict] = []
    for s in spans:
        pid = pid_of(s.trace or s.name)
        tid = lane_of(s)
        lane_names.setdefault((pid, tid), s.name if tid else "request")
        us = (s.start - t0) * 1e6
        dur = max((s.end - s.start) * 1e6, 0.0)
        args = {"kind": s.kind, "seq": s.seq} | s.attrs
        if dur <= 0.0 and s.kind not in ("request", "invoke"):
            events.append({"name": f"{s.kind}:{s.name}", "cat": s.kind,
                           "ph": "i", "s": "t", "ts": us, "pid": pid,
                           "tid": tid, "args": args})
        else:
            events.append({"name": f"{s.kind}:{s.name}", "cat": s.kind,
                           "ph": "X", "ts": us, "dur": max(dur, 0.01),
                           "pid": pid, "tid": tid, "args": args})
    for trace, pid in pids.items():
        events.append({"name": "process_name", "ph": "M", "pid": pid,
                       "tid": 0, "args": {"name": trace}})
    for (pid, tid), name in lane_names.items():
        events.append({"name": "thread_name", "ph": "M", "pid": pid,
                       "tid": tid, "args": {"name": name}})
    return {"traceEvents": events, "displayTimeUnit": "ms"}


# ----------------------------------------------------------------------
# Plan-vs-actual attribution
# ----------------------------------------------------------------------

def plan_attribution(plan) -> dict:
    """Portable attribution doc from a :class:`~repro.core.plan.
    WorkflowPlan` (duck-typed) — what :func:`write_spans_jsonl` embeds."""
    return {
        "workflow": plan.workflow,
        "critical_path": plan.critical_path,
        "functions": {
            fp.function: {"est": fp.est, "eft": fp.eft, "slack": fp.slack,
                          "boot_at": fp.boot_at,
                          "cold_start": fp.cold_start}
            for fp in plan.functions.values()},
    }


def _strip_ns(name: str, trace: str) -> str:
    prefix = f"{trace}:"
    return name[len(prefix):] if name.startswith(prefix) else name


def attribute(spans: Iterable[Span], plan_doc: Mapping) -> dict:
    """Join per-request spans against a plan attribution doc.

    Per function (aggregated over requests): *start drift* (actual launch
    offset from request start minus the plan's ``est`` — positive = late),
    *finish drift* (vs ``eft``), *acquire wait* (time inside the container
    acquire span), cold/prewarm-hit rates and *prewarm lead* (how far
    ahead of the actual start the plan's ``boot_at`` fired).  Per request:
    latency vs the plan's critical path (*critical-path drift*).  Eviction
    timing: lag between a key's last Get return and its evict event —
    plan-driven eviction should hold this near zero.
    """
    fns: Mapping[str, Mapping] = plan_doc.get("functions", {})
    cp = float(plan_doc.get("critical_path", math.nan))
    by_trace: dict[str, list[Span]] = {}
    for s in spans:
        by_trace.setdefault(s.trace, []).append(s)

    func_rows: dict[str, list[dict]] = {}
    request_rows: list[dict] = []
    evict_lags: list[float] = []
    for trace, ss in sorted(by_trace.items()):
        req = next((s for s in ss if s.kind == "request"), None)
        if req is None or math.isnan(req.end):
            continue
        t0 = req.start
        children: dict[int, list[Span]] = {}
        for s in ss:
            if s.parent is not None:
                children.setdefault(s.parent, []).append(s)
        # First invoke span per function (a straggler duplicate would add
        # a second; the first is the one the plan's timeline predicts).
        invokes: dict[str, Span] = {}
        for s in sorted(ss, key=lambda s: s.seq):
            if s.kind == "invoke" and s.name not in invokes:
                invokes[s.name] = s
        for fname, inv in invokes.items():
            fp = fns.get(fname)
            if fp is None:
                continue
            acq = next((c for c in children.get(inv.id, ())
                        if c.kind == "acquire"), None)
            actual_start = inv.start - t0
            actual_finish = inv.end - t0
            row = {
                "function": fname,
                "actual_start": actual_start,
                "actual_finish": actual_finish,
                "start_drift": actual_start - fp["est"],
                "finish_drift": actual_finish - fp["eft"],
                "slack": fp["slack"],
                "acquire_wait": (acq.duration if acq is not None
                                 else math.nan),
                "cold": bool(acq.attrs.get("cold")) if acq else None,
                "prewarm_lead": actual_start - fp["boot_at"],
            }
            func_rows.setdefault(fname, []).append(row)
        latency = req.end - t0
        request_rows.append({"trace": trace, "latency": latency,
                             "cp_drift": latency - cp})
        # Eviction lag: evict instant minus last Get return of the key.
        last_get: dict[str, float] = {}
        for s in ss:
            if s.kind in ("get", "chunk"):
                k = _strip_ns(s.name, trace)
                last_get[k] = max(last_get.get(k, -math.inf), s.end)
        for s in ss:
            if s.kind == "evict":
                k = _strip_ns(s.name, trace)
                if k in last_get:
                    evict_lags.append(s.end - last_get[k])

    def _agg(vals: list[float]) -> dict:
        vals = [v for v in vals if not math.isnan(v)]
        if not vals:
            return {"n": 0}
        return {"n": len(vals), "mean": sum(vals) / len(vals),
                "max": max(vals), "min": min(vals)}

    functions = []
    for fname in sorted(func_rows):
        rows = func_rows[fname]
        cold_known = [r["cold"] for r in rows if r["cold"] is not None]
        functions.append({
            "function": fname,
            "requests": len(rows),
            "start_drift": _agg([r["start_drift"] for r in rows]),
            "finish_drift": _agg([r["finish_drift"] for r in rows]),
            "acquire_wait": _agg([r["acquire_wait"] for r in rows]),
            "prewarm_lead": _agg([r["prewarm_lead"] for r in rows]),
            "slack": rows[0]["slack"],
            "cold_rate": (sum(cold_known) / len(cold_known)
                          if cold_known else None),
        })
    lat = sorted(r["latency"] for r in request_rows)
    return {
        "workflow": plan_doc.get("workflow", ""),
        "critical_path": cp,
        "requests": len(request_rows),
        "latency": _agg(lat),
        "cp_drift": _agg([r["cp_drift"] for r in request_rows]),
        "functions": functions,
        "eviction_lag": _agg(evict_lags),
        "per_request": request_rows,
    }


# ----------------------------------------------------------------------
# Standardized BENCH_*.json schema + comparison
# ----------------------------------------------------------------------

BENCH_SCHEMA = "dflow-bench/v1"

# Default regression tolerance: a gated metric may move 10% in its bad
# direction before compare_docs fails (the ISSUE's ">10% p99" gate).
DEFAULT_TOLERANCE = 0.10


def bench_metric(system: str, metric: str, value: float, units: str = "",
                 *, direction: str | None = None,
                 tolerance: float | None = None) -> dict:
    """One standardized metric row.  ``direction`` arms the regression
    gate: ``"lower"`` (lower is better) fails when a fresh value exceeds
    the committed one by more than ``tolerance`` (relative);``"higher"``
    fails on the symmetric drop.  ``None`` = report-only (e.g. noisy
    absolute wall-clock latencies on shared CI runners)."""
    if direction not in (None, "lower", "higher"):
        raise ValueError(f"direction must be lower/higher/None, "
                         f"got {direction!r}")
    row = {"system": system, "metric": metric, "value": value,
           "units": units, "direction": direction}
    if tolerance is not None:
        row["tolerance"] = float(tolerance)
    return row


def bench_doc(bench: str, config: Mapping, metrics: list[dict],
              **sections: Any) -> dict:
    """Assemble a ``dflow-bench/v1`` document: schema tag + config + the
    standardized metric list, with legacy readable sections appended."""
    return {"schema": BENCH_SCHEMA, "bench": bench,
            "config": dict(config), "metrics": list(metrics), **sections}


def compare_docs(old: Mapping, new: Mapping, *,
                 default_tolerance: float = DEFAULT_TOLERANCE
                 ) -> tuple[list[dict], list[str]]:
    """Diff two standardized bench docs; returns ``(rows, failures)``.

    Metrics match on ``(system, metric)``.  Gated metrics (direction set
    in the *old*/committed doc) fail when the new value regresses beyond
    the tolerance; ungated metrics are reported only.  A committed metric
    missing from the fresh doc is a failure (silent coverage loss)."""
    new_by_key = {(m["system"], m["metric"]): m
                  for m in new.get("metrics", ())}
    rows: list[dict] = []
    failures: list[str] = []
    for m in old.get("metrics", ()):
        key = (m["system"], m["metric"])
        fresh = new_by_key.get(key)
        if fresh is None:
            failures.append(f"{key[0]}/{key[1]}: missing from fresh run")
            continue
        ov, nv = float(m["value"]), float(fresh["value"])
        direction = m.get("direction")
        tol = float(m.get("tolerance", default_tolerance))
        delta = nv - ov
        rel = delta / abs(ov) if ov else math.inf if delta else 0.0
        regressed = False
        if direction == "lower":
            regressed = nv > ov * (1 + tol) if ov > 0 else nv > ov
        elif direction == "higher":
            regressed = nv < ov * (1 - tol) if ov > 0 else nv < ov
        rows.append({"system": key[0], "metric": key[1], "old": ov,
                     "new": nv, "delta": delta, "rel": rel,
                     "direction": direction, "gated": direction is not None,
                     "regressed": regressed, "units": m.get("units", "")})
        if regressed:
            failures.append(
                f"{key[0]}/{key[1]}: {ov:g} -> {nv:g} "
                f"({rel:+.1%}, direction={direction}, tol={tol:.0%})")
    return rows, failures
