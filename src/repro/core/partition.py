"""Global-Scheduler DAG partitioning (paper §3.2; same GS as FaaSFlow).

The GS splits the workflow DAG into per-node sub-DAGs.  Objectives, in the
order the paper's GS (inherited from FaaSFlow) cares about them:

1. **data locality** — co-locate a function with the producers of its
   largest inputs so intra-node exchange (local store) replaces network
   transfers;
2. **load balance** — spread total execution time so no worker serialises.

We implement a deterministic greedy pass in topological order followed by a
boundary-refinement sweep (move a function to another node if that strictly
reduces cut bytes without violating the balance cap).  The same placement is
fed to *every* system (CFlow/FaaSFlow/.../DFlow) — the paper evaluates all
systems under FaaSFlow's GS, which isolates the invocation-pattern effect.
"""

from __future__ import annotations

from typing import Mapping, Sequence

from .dag import Workflow

__all__ = ["partition_workflow", "cut_bytes", "stage_node"]


def stage_node(wf: Workflow, key: str, placement: Mapping[str, str],
               default: str | None = None) -> str | None:
    """Node where an external input is staged: its *first* consumer's node
    (the trigger payload lands where it is first needed), or ``default``
    when nothing consumes the key.  The single authority for staging-home
    decisions — ``InstanceRun.start``, DPlan's transfer matrix and DShard's
    static routing tables must all agree on it, otherwise the planner's
    locality classification (and the router's 1-hop invariant) would
    diverge from what the runtime actually does."""
    for f in wf.functions.values():
        if key in f.inputs:
            return placement[f.name]
    return default


def _edge_bytes(wf: Workflow) -> dict[tuple[str, str], float]:
    """bytes moved along each DAG edge (producer fn -> consumer fn)."""
    out: dict[tuple[str, str], float] = {}
    for f in wf.functions.values():
        for k in f.inputs:
            p = wf.producer.get(k)
            if p is None or p == f.name:
                continue
            sz = wf.key_bytes(k)
            out[(p, f.name)] = out.get((p, f.name), 0.0) + sz
    return out


def cut_bytes(wf: Workflow, placement: Mapping[str, str]) -> float:
    """Total bytes crossing node boundaries under ``placement``."""
    return sum(sz for (u, v), sz in _edge_bytes(wf).items()
               if placement[u] != placement[v])


def partition_workflow(wf: Workflow, nodes: Sequence[str],
                       balance_slack: float = 1.35,
                       refine_iters: int = 3) -> dict[str, str]:
    """Greedy locality-first partitioning with load-balance cap.

    ``balance_slack``: a node may hold at most ``slack * total/len(nodes)``
    seconds of work; within the cap, placement maximises co-located input
    bytes (ties broken by load, then node order → deterministic).
    """
    if not nodes:
        raise ValueError("no worker nodes")
    edges = _edge_bytes(wf)
    total = max(wf.total_exec_time(), 1e-9)
    # A node loaded up to the DAG's critical path cannot extend the makespan,
    # so the balance cap never forces a sequential chain to split.
    cap = balance_slack * max(total / len(nodes), wf.critical_path_time())
    load: dict[str, float] = {n: 0.0 for n in nodes}
    placement: dict[str, str] = {}

    for fname in wf.topo_order:
        f = wf.functions[fname]
        local_bytes: dict[str, float] = {n: 0.0 for n in nodes}
        for p in wf.predecessors[fname]:
            n = placement[p]
            local_bytes[n] += edges.get((p, fname), 0.0)
        # candidates under the balance cap (always allow the emptiest node).
        order = sorted(
            nodes,
            key=lambda n: (-local_bytes[n], load[n], nodes.index(n)))
        chosen = None
        for n in order:
            if load[n] + f.exec_time <= cap:
                chosen = n
                break
        if chosen is None:
            chosen = min(nodes, key=lambda n: (load[n], nodes.index(n)))
        placement[fname] = chosen
        load[chosen] += f.exec_time

    # Boundary refinement: single-function moves that reduce cut bytes.
    for _ in range(refine_iters):
        improved = False
        for fname in wf.topo_order:
            f = wf.functions[fname]
            here = placement[fname]

            def gain(n: str) -> float:
                g = 0.0
                for p in wf.predecessors[fname]:
                    sz = edges.get((p, fname), 0.0)
                    g += (placement[p] == n) * sz - (placement[p] == here) * sz
                for s in wf.successors[fname]:
                    sz = edges.get((fname, s), 0.0)
                    g += (placement[s] == n) * sz - (placement[s] == here) * sz
                return g

            best_n, best_g = here, 0.0
            for n in nodes:
                if n == here:
                    continue
                if load[n] + f.exec_time > cap:
                    continue
                g = gain(n)
                if g > best_g + 1e-9:
                    best_n, best_g = n, g
            if best_n != here:
                load[here] -= f.exec_time
                load[best_n] += f.exec_time
                placement[fname] = best_n
                improved = True
        if not improved:
            break
    return placement
