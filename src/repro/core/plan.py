"""DPlan — static dataflow planner over the workflow DAG.

Everything the runtime decides heuristically today — when a key may be
reclaimed, when a container must start booting, which edges cross nodes
and what that costs — is statically derivable from the DAG plus a
placement, the same way iRoute derives routing tables and FaaSFlow's GS
derives partitions.  :func:`build_plan` computes a :class:`WorkflowPlan`
IR with four analyses:

* **liveness / eviction** — per key, the producer, the consumer set and
  the topological interval in which the key can be live.  A key is safe
  to evict only once *every* consumer's Get has returned: get order
  inside one consumer is arbitrary (``_fetch_inputs`` issues fetches
  sequentially in input order), so even a consumer that is a DAG
  ancestor of another consumer gives no happens-before between their
  Gets of the *same* key.  The provably-safe earliest-eviction schedule
  is therefore a per-key read countdown (``eviction_reads``): the
  runtime evicts the moment the statically-last read returns.  Keys on
  stream edges (chunked twins, iterator reads that never issue a plain
  Get) and sink keys (collected by ``wait()``) are excluded and left to
  instance-scoped eviction.
* **critical path / slack / prewarm** — the classic earliest/latest
  start DP over ``exec_time`` (identical recurrence to
  :meth:`Workflow.critical_path_time`, so the two agree exactly).  Each
  function's container should start booting at ``est - cold_start``
  (clamped at 0): exactly slack-ahead of its earliest frontier-ready
  time, replacing the fire-at-precursor-launch heuristic which boots
  everything as early as the +2 frontier reaches it.
* **transfer-cost matrix** — bytes per producer→consumer edge via the
  one shared sizing helper (:meth:`Workflow.key_bytes`, also used by
  ``partition._edge_bytes``, so ``cross_node_bytes == cut_bytes`` by
  construction), chunk counts for streamed edges, local/cross
  classification under the placement, plus a deduplicated
  per-(key, node) pull prediction (a second consumer on a node reuses
  the replica — the matrix is the upper bound, the dedup the lower) and
  a peak-resident-bytes-per-node prediction under the canonical
  topological schedule with earliest eviction.
* **stream-overlap feasibility** — DF016/DF017 diagnostics (registered
  in :mod:`repro.core.lint`'s CODES) for declared streams that can
  never actually pipeline.

The plan is machine-checked, not trusted: :class:`repro.core.check.
PlanConformance` replays recorded traces against it and flags any
dynamic event that contradicts a static claim.
"""

from __future__ import annotations

import math
from dataclasses import dataclass, field
from typing import Any, Mapping

from .dag import Workflow
from .lint import Diagnostic
from .partition import stage_node
from .stream import chunk_count

__all__ = ["KeyPlan", "FunctionPlan", "TransferPlan", "WorkflowPlan",
           "build_plan"]


@dataclass(frozen=True)
class KeyPlan:
    """Liveness facts for one data key."""

    key: str
    size: int
    producer: str | None             # None = external workflow input
    consumers: tuple[str, ...]       # functions with this key in inputs
    first_step: int                  # topo index where the key appears
    last_step: int                   # topo index of its last consumer
    sink: bool                       # collected by wait(); never plan-evict
    streamed: bool                   # chunked twin / iterator reads exist
    reads: int                       # plain Gets before eviction is safe

    @property
    def evictable(self) -> bool:
        return not self.sink and not self.streamed and self.reads > 0


@dataclass(frozen=True)
class FunctionPlan:
    """Critical-path facts + prewarm timing for one function."""

    function: str
    node: str | None
    est: float                       # earliest start (exec_time DP)
    eft: float                       # earliest finish = est + exec_time
    lst: float                       # latest start w/o stretching the CP
    slack: float                     # lst - est (0 on the critical path)
    cold_start: float
    boot_at: float                   # max(0, est - cold_start)

    @property
    def critical(self) -> bool:
        return self.slack <= 1e-12

    @property
    def boot_cost(self) -> float:
        """Container-seconds a slack-timed prewarm spends ahead of the
        function's earliest start (``est - boot_at`` = ``min(cold_start,
        est)``) — the price DScale's :class:`~repro.core.scale.
        PrewarmBudget` debits per boot."""
        return max(0.0, self.est - self.boot_at)


@dataclass(frozen=True)
class TransferPlan:
    """One matrix cell: bytes along a producer→consumer edge."""

    producer: str | None             # None = external input staging
    consumer: str
    key: str
    bytes: int
    chunks: int                      # 1 for monolithic edges
    chunk_bytes: int                 # bytes per chunk (last may be short)
    src: str | None                  # producing / staging node
    dst: str | None                  # consuming node
    local: bool | None               # None when no placement was given


@dataclass
class WorkflowPlan:
    """The static plan IR for one workflow (+ optional placement)."""

    workflow: str
    critical_path: float
    keys: dict[str, KeyPlan]
    functions: dict[str, FunctionPlan]
    transfers: tuple[TransferPlan, ...]
    placement: dict[str, str] | None = None
    peak_resident: dict[str, int] = field(default_factory=dict)
    diagnostics: tuple[Diagnostic, ...] = ()

    # -- eviction ---------------------------------------------------------
    @property
    def eviction_reads(self) -> dict[str, int]:
        """key -> number of plain Gets after which eviction is safe."""
        return {k: kp.reads for k, kp in self.keys.items() if kp.evictable}

    def eviction_order(self) -> list[str]:
        """Evictable keys by earliest safe eviction step (topo index)."""
        ev = [(kp.last_step, k) for k, kp in self.keys.items()
              if kp.evictable]
        return [k for _, k in sorted(ev)]

    # -- prewarm ----------------------------------------------------------
    @property
    def prewarm_schedule(self) -> tuple[tuple[str, float, float], ...]:
        """(function, boot_at, cold_start) sorted by boot time."""
        return tuple(sorted(
            ((fp.function, fp.boot_at, fp.cold_start)
             for fp in self.functions.values()),
            key=lambda e: (e[1], e[0])))

    # -- transfer matrix --------------------------------------------------
    def key_size(self, key: str) -> int | None:
        kp = self.keys.get(key)
        return None if kp is None else kp.size

    @property
    def cross_node_bytes(self) -> float:
        """Per-edge cross-node bytes between functions — by construction
        equal to ``partition.cut_bytes`` under the same placement."""
        return float(sum(t.bytes for t in self.transfers
                         if t.local is False and t.producer is not None))

    def predicted_pull_bytes(self, *, include_external: bool = True) -> int:
        """Deduplicated cross-node pull prediction: one receiver-driven
        transfer per (key, consumer node) — a second consumer on the same
        node hits the replica registered by the first."""
        pulled: set[tuple[str, str]] = set()
        total = 0
        for t in self.transfers:
            if t.local is not False:
                continue
            if t.producer is None and not include_external:
                continue
            if (t.key, t.dst) in pulled:
                continue
            pulled.add((t.key, t.dst))
            total += t.bytes
        return total

    # -- consistency ------------------------------------------------------
    def self_check(self) -> list[str]:
        """Internal invariants every well-formed plan satisfies; used by
        the CLI and CI so builtin/example plans are machine-checked even
        when no executable trace exists."""
        problems: list[str] = []
        for fp in self.functions.values():
            if fp.slack < -1e-9:
                problems.append(f"{fp.function}: negative slack {fp.slack}")
            if fp.eft - 1e-9 > self.critical_path:
                problems.append(
                    f"{fp.function}: eft {fp.eft} beyond critical path")
            if fp.boot_at - 1e-9 > max(fp.est, 0.0):
                problems.append(
                    f"{fp.function}: boot_at {fp.boot_at} after est {fp.est}")
        for k, kp in self.keys.items():
            if kp.reads != len(set(kp.consumers)):
                problems.append(f"{k}: reads != distinct consumers")
            if kp.sink and kp.evictable:
                problems.append(f"{k}: sink marked evictable")
            if kp.last_step < kp.first_step and kp.consumers:
                problems.append(f"{k}: last step precedes first")
        for t in self.transfers:
            if t.bytes < 0 or t.chunks < 1:
                problems.append(f"{t.key}: malformed transfer cell")
        return problems

    # -- serialization ----------------------------------------------------
    def to_doc(self) -> dict[str, Any]:
        return {
            "workflow": self.workflow,
            "critical_path_s": self.critical_path,
            "placement": self.placement,
            "functions": [vars(fp) | {"critical": fp.critical}
                          for fp in self.functions.values()],
            "keys": [vars(kp) | {"evictable": kp.evictable}
                     for kp in self.keys.values()],
            "transfers": [vars(t) for t in self.transfers],
            "eviction_order": self.eviction_order(),
            "prewarm_schedule": [
                {"function": f, "boot_at": b, "cold_start": c}
                for f, b, c in self.prewarm_schedule],
            "cross_node_bytes": self.cross_node_bytes,
            "predicted_pull_bytes": self.predicted_pull_bytes(),
            "peak_resident_bytes": self.peak_resident,
            "diagnostics": [vars(d) for d in self.diagnostics],
        }


# ----------------------------------------------------------------------
# Builder
# ----------------------------------------------------------------------

def _liveness(wf: Workflow, step: Mapping[str, int]) -> dict[str, KeyPlan]:
    consumers: dict[str, list[str]] = {}
    for f in wf.functions.values():
        for k in set(f.inputs):
            consumers.setdefault(k, []).append(f.name)
    streamed: set[str] = set()
    for f in wf.functions.values():
        streamed.update(f.stream_outputs)
        streamed.update(f.stream_inputs)

    out: dict[str, KeyPlan] = {}
    n_steps = len(wf.topo_order)
    for key in (*wf.producer, *wf.external_inputs):
        prod = wf.producer.get(key)
        cons = tuple(sorted(consumers.get(key, ()),
                            key=lambda c: step[c]))
        first = step[prod] if prod is not None else -1
        last = max((step[c] for c in cons), default=n_steps - 1)
        sink = not cons
        out[key] = KeyPlan(
            key=key, size=wf.key_bytes(key), producer=prod,
            consumers=cons, first_step=first,
            last_step=last if not sink else n_steps - 1,
            sink=sink, streamed=key in streamed,
            reads=len(cons))
    return out


def _schedule(wf: Workflow,
              placement: Mapping[str, str] | None
              ) -> tuple[dict[str, FunctionPlan], float]:
    # Earliest start/finish: the exact recurrence of
    # Workflow.critical_path_time(), so equality is bit-for-bit.
    eft: dict[str, float] = {}
    est: dict[str, float] = {}
    for n in wf.topo_order:
        base = max((eft[p] for p in wf.predecessors[n]), default=0.0)
        est[n] = base
        eft[n] = base + wf.functions[n].exec_time
    cp = max(eft.values()) if eft else 0.0
    # Latest start: backward pass pinned to the critical-path makespan.
    lst: dict[str, float] = {}
    for n in reversed(wf.topo_order):
        lft = min((lst[s] for s in wf.successors[n]), default=cp)
        lst[n] = lft - wf.functions[n].exec_time
    out: dict[str, FunctionPlan] = {}
    for n in wf.topo_order:
        f = wf.functions[n]
        out[n] = FunctionPlan(
            function=n,
            node=None if placement is None else placement[n],
            est=est[n], eft=eft[n], lst=lst[n],
            slack=max(0.0, lst[n] - est[n]),
            cold_start=f.cold_start,
            boot_at=max(0.0, est[n] - f.cold_start))
    return out, cp


def _transfers(wf: Workflow, keys: Mapping[str, KeyPlan],
               placement: Mapping[str, str] | None
               ) -> tuple[TransferPlan, ...]:
    # External inputs are staged on the node of each key's *first*
    # consumer (partition.stage_node — the same authority InstanceRun and
    # DShard's routing tables use); other consumers pull.
    staged: dict[str, str] = {}
    if placement is not None:
        for k in wf.external_inputs:
            n = stage_node(wf, k, placement)
            if n is not None:
                staged[k] = n
    out: list[TransferPlan] = []
    for f in wf.functions.values():
        for k in sorted(set(f.inputs)):
            kp = keys[k]
            prod = kp.producer
            if prod == f.name:
                continue                       # dropped edge (DF003 lints)
            size = kp.size
            chunk = wf.functions[prod].chunk_size if prod is not None \
                else f.chunk_size
            chunks = chunk_count(size, chunk) if kp.streamed else 1
            src = dst = local = None
            if placement is not None:
                src = placement[prod] if prod is not None \
                    else staged.get(k)
                dst = placement[f.name]
                local = src == dst
            out.append(TransferPlan(
                producer=prod, consumer=f.name, key=k, bytes=size,
                chunks=chunks,
                chunk_bytes=min(size, chunk) if kp.streamed else size,
                src=src, dst=dst, local=local))
    out.sort(key=lambda t: (t.consumer, t.key))
    return tuple(out)


def _peak_resident(wf: Workflow, keys: Mapping[str, KeyPlan],
                   placement: Mapping[str, str] | None) -> dict[str, int]:
    """Peak resident bytes per node under the canonical topological
    schedule with earliest eviction.  A prediction, not a bound: a
    concurrent schedule can reorder steps, but the canonical walk is
    what the eviction schedule itself is derived from, so it is the
    number plan-driven serving converges to per instance."""
    node_of = (lambda fn: placement[fn]) if placement is not None \
        else (lambda fn: "cluster")
    step = {fn: i for i, fn in enumerate(wf.topo_order)}
    # (step, node, delta) events; externals land before step 0.
    events: list[tuple[int, str, int]] = []
    for k, kp in keys.items():
        holders: set[str] = set()
        if kp.producer is not None:
            home = node_of(kp.producer)
            events.append((kp.first_step, home, kp.size))
            holders.add(home)
        elif kp.consumers:
            home = node_of(kp.consumers[0])
            events.append((-1, home, kp.size))
            holders.add(home)
        for c in kp.consumers:
            n = node_of(c)
            if n not in holders:               # replica pulled at read time
                events.append((step[c], n, kp.size))
                holders.add(n)
        if kp.evictable:
            for n in holders:
                events.append((kp.last_step, n, -kp.size))
    # Within a step, additions land before eviction releases: the last
    # reader's Get returns (bytes resident) before the evict fires.
    events.sort(key=lambda e: (e[0], e[2] < 0))
    resident: dict[str, int] = {}
    peak: dict[str, int] = {}
    for _, node, delta in events:
        resident[node] = resident.get(node, 0) + delta
        peak[node] = max(peak.get(node, 0), resident[node])
    return peak


def _stream_diagnostics(wf: Workflow,
                        keys: Mapping[str, KeyPlan]) -> list[Diagnostic]:
    out: list[Diagnostic] = []
    for f in wf.functions.values():
        for k in f.stream_outputs:
            # DF017 — a stream that fits one chunk degenerates to a
            # monolithic put: nothing to overlap.
            if chunk_count(wf.key_bytes(k), f.chunk_size) <= 1:
                out.append(Diagnostic(
                    "DF017", f"stream {k!r} of {f.name!r} fits a single "
                    f"chunk ({wf.key_bytes(k)} B <= chunk_size "
                    f"{f.chunk_size}); the pipeline degenerates to a "
                    "monolithic transfer", function=f.name, key=k,
                    hint="shrink chunk_size or drop the stream "
                    "declaration"))
        for k in f.stream_inputs:
            p = wf.producer.get(k)
            if p is None or p == f.name:
                continue                       # DF005 territory (lint)
            prod = wf.functions[p]
            if k not in prod.stream_outputs:
                continue
            # DF016a — the consumer also waits on a *later-emitted* plain
            # output of the same producer: _emit_outputs publishes in
            # outputs order after draining earlier stream generators, so
            # that Get returns only once the stream is fully produced.
            for k2 in f.inputs:
                if (k2 in prod.outputs and k2 not in prod.stream_outputs
                        and k2 not in f.stream_inputs
                        and prod.outputs.index(k2) > prod.outputs.index(k)):
                    out.append(Diagnostic(
                        "DF016", f"{f.name!r} streams {k!r} from {p!r} "
                        f"but also waits for {k2!r}, which {p!r} emits "
                        f"only after draining the stream — the edge can "
                        "never pipeline", function=f.name, key=k,
                        hint=f"reorder {p!r}.outputs so {k2!r} precedes "
                        f"{k!r}, or stream {k2!r} too"))
            # DF016b — the consumer waits on an output of another
            # consumer of the same stream: that producer finishes only
            # after the stream closes, so the overlap window is empty.
            for k2 in f.inputs:
                p2 = wf.producer.get(k2)
                if (p2 is not None and p2 != p and p2 != f.name
                        and k in wf.functions[p2].inputs
                        and k2 not in f.stream_inputs):
                    out.append(Diagnostic(
                        "DF016", f"{f.name!r} streams {k!r} but also "
                        f"waits for {k2!r} from {p2!r}, itself a "
                        f"consumer of {k!r} — {k2!r} exists only after "
                        "the stream closed, so the edge can never "
                        "pipeline", function=f.name, key=k,
                        hint=f"drop the stream declaration on {k!r} or "
                        f"restructure the diamond through {p2!r}"))
    return out


def build_plan(wf: Workflow,
               placement: Mapping[str, str] | None = None, *,
               nodes: list[str] | None = None) -> WorkflowPlan:
    """Compute the :class:`WorkflowPlan` for ``wf``.

    ``placement`` maps function -> node (e.g. from
    :func:`~repro.core.partition.partition_workflow`).  When omitted but
    ``nodes`` is given, the partitioner runs here; with neither, the plan
    is placement-agnostic (transfer locality and per-node peaks unknown).
    """
    if placement is None and nodes:
        from .partition import partition_workflow

        placement = partition_workflow(wf, nodes)
    if placement is not None:
        missing = set(wf.functions) - set(placement)
        if missing:
            raise ValueError(f"placement misses functions {sorted(missing)}")
        placement = dict(placement)
    step = {fn: i for i, fn in enumerate(wf.topo_order)}
    keys = _liveness(wf, step)
    functions, cp = _schedule(wf, placement)
    transfers = _transfers(wf, keys, placement)
    peak = _peak_resident(wf, keys, placement)
    diags = _stream_diagnostics(wf, keys)
    plan = WorkflowPlan(
        workflow=wf.name, critical_path=cp, keys=keys,
        functions=functions, transfers=transfers, placement=placement,
        peak_resident=peak, diagnostics=tuple(diags))
    assert math.isclose(cp, wf.critical_path_time(), rel_tol=0.0,
                        abs_tol=0.0) or cp == wf.critical_path_time()
    return plan
