"""DShard — sharded multi-node DStore with local routing tables.

The single-process :class:`~repro.core.dstore.DStore` keeps ONE directory
for the whole cluster: every Get that misses locally consults that central
directory and then pulls — effectively a 2-hop exchange (consumer →
directory → producer), and at serving scale the directory is the metadata
hotspot.  DShard restructures the data plane the way iRoute does (local
routing controllers + a coordinator syncing routing tables):

* **one directory shard per node** — a key's metadata lives on the shard of
  its *home* node, which is the node the GS partitioner placed its producer
  on (externals: where the input is staged, ``partition.stage_node``);
* **a per-node routing table** (:class:`RoutingTable`) — consumers resolve
  key → home locally, no central lookup on the hot path;
* **a lightweight coordinator** (:class:`Coordinator`) — the authority the
  tables sync from.  Instance registration installs the static routes
  derived from placement (or, better, from DPlan's transfer matrix);
  dynamic writes of unplanned keys register their home lazily.

The result is the universal **1-hop transfer**: a consumer's Get contacts
exactly one shard — the producing node's — and pulls from a replica it
names.  A 2-hop resolution can only happen through a *stale* table
(misroute: the contacted shard is alive but not the home); it is counted,
recorded in the trace (``hops=2``) and flagged by the trace checker's
``routing`` invariant.

Transport tiers (priced distinctly by :class:`TieredTransport` and the
simulator's ``ShardedDStorePlane``):

* ``ipc``  — same-container handoff: the key's home *is* the consumer's
  node and the bytes are already local (e.g. the trigger payload);
* ``mem``  — same-node memoryview: bytes local from an earlier pull, or
  pulled from a replica on the consumer's own node;
* ``net``  — cross-node network pull (the only tier that pays bandwidth).

:class:`ShardedDStore` subclasses ``DStore`` so the engine, DStream, DPlan
eviction and DCheck tracing all run unchanged on top of it — the 200-seed
differential corpus is byte-exact against the single-store baseline.
"""

from __future__ import annotations

import threading
import time
from typing import TYPE_CHECKING, Iterable, Mapping

from .dstore import (DStore, DataDirectoryService, GetTimeout,
                     ImmutabilityError, Transport, _sizeof, _trace_of)
from .check import content_digest
from .partition import stage_node
from .stream import base_key, chunk_key

if TYPE_CHECKING:  # pragma: no cover - typing only
    from .dag import Workflow
    from .plan import WorkflowPlan

__all__ = ["ShardedDStore", "RoutingTable", "Coordinator", "TieredTransport",
           "static_routes", "routes_from_plan",
           "TIER_IPC", "TIER_MEM", "TIER_NET"]

# Transport tiers, cheapest first.
TIER_IPC = "ipc"    # same-container: key homed here and bytes already local
TIER_MEM = "mem"    # same-node memoryview: local bytes, remote home
TIER_NET = "net"    # cross-node network pull

# A Get blocked at a home shard re-checks the coordinator's authoritative
# route at this period, so a failure re-home (or a stale-table fix) moves
# the consumer to the new home instead of wedging on a dead shard's CV.
_ROUTE_POLL = 0.05

_MISSING = object()


class TieredTransport(Transport):
    """Transport that prices the three DShard tiers distinctly.

    The base-class counters (``bytes_moved``/``transfers``) keep their
    single-store meaning — cross-node traffic only — so reports stay
    comparable; per-tier traffic lands in ``tier_bytes``/``tier_transfers``.
    """

    def __init__(self, bandwidth: float | None = None, latency: float = 0.0,
                 *, mem_bandwidth: float | None = None,
                 mem_latency: float = 0.0):
        super().__init__(bandwidth, latency)
        self.mem_bandwidth = mem_bandwidth
        self.mem_latency = mem_latency
        self.tier_bytes = {TIER_IPC: 0, TIER_MEM: 0, TIER_NET: 0}
        self.tier_transfers = {TIER_IPC: 0, TIER_MEM: 0, TIER_NET: 0}

    def move(self, size: int, tier: str = TIER_NET) -> None:
        if tier == TIER_NET:
            super().move(size)
        elif tier == TIER_MEM:
            if self.mem_latency:
                time.sleep(self.mem_latency)
            if self.mem_bandwidth:
                time.sleep(size / self.mem_bandwidth)
        with self._lock:
            self.tier_bytes[tier] += size
            self.tier_transfers[tier] += 1


class RoutingTable:
    """One node's local key → home-shard map (synced from the coordinator).

    Chunk keys route through their stream's base key, so a single installed
    route covers a whole stream.  ``lookup`` counts hits/misses; ``peek``
    is the non-counting variant used for tier classification on local hits.
    """

    def __init__(self, node: str):
        self.node = node
        self._lock = threading.Lock()
        self._routes: dict[str, str] = {}
        self.version = -1
        self.hits = 0
        self.misses = 0
        self.refreshes = 0

    def install(self, routes: Mapping[str, str], version: int) -> None:
        with self._lock:
            self._routes = dict(routes)
            self.version = version
            self.refreshes += 1

    def lookup(self, key: str) -> str | None:
        with self._lock:
            home = self._routes.get(key)
            if home is None:
                b = base_key(key)
                if b != key:
                    home = self._routes.get(b)
            if home is None:
                self.misses += 1
            else:
                self.hits += 1
            return home

    def peek(self, key: str) -> str | None:
        with self._lock:
            home = self._routes.get(key)
            if home is None:
                home = self._routes.get(base_key(key))
            return home

    def __len__(self) -> int:
        with self._lock:
            return len(self._routes)


class Coordinator:
    """Routing authority the per-node tables sync from.

    Holds the versioned key → home map plus the failed-node set.  Route
    changes (install / re-home) bump the version and wake ``wait_route``
    blockers — consumers of a key no plan knows about yet block *here*, not
    on a guessed shard, so even dynamically-registered keys resolve 1-hop.
    """

    def __init__(self, nodes: Iterable[str]):
        self.nodes = list(nodes)
        self._lock = threading.Lock()
        self._cv = threading.Condition(self._lock)
        self._routes: dict[str, str] = {}
        self._version = 0
        self._failed: set[str] = set()
        self.syncs = 0

    @property
    def version(self) -> int:
        with self._lock:
            return self._version

    def install(self, routes: Mapping[str, str]) -> None:
        with self._cv:
            self._routes.update(routes)
            self._version += 1
            self._cv.notify_all()

    def remove_prefix(self, prefix: str) -> None:
        with self._cv:
            stale = [k for k in self._routes if k.startswith(prefix)]
            for k in stale:
                del self._routes[k]
            if stale:
                self._version += 1

    def route_of(self, key: str) -> str | None:
        with self._lock:
            home = self._routes.get(key)
            if home is None:
                home = self._routes.get(base_key(key))
            return home

    def rehome(self, key: str, node: str) -> None:
        with self._cv:
            self._routes[key] = node
            self._version += 1
            self._cv.notify_all()

    def sync(self, table: RoutingTable) -> None:
        """Refresh one node's table (the lightweight coordinator sync)."""
        with self._lock:
            snapshot = dict(self._routes)
            version = self._version
            self.syncs += 1
        table.install(snapshot, version)

    def mark_failed(self, node: str) -> None:
        with self._cv:
            self._failed.add(node)
            self._version += 1
            self._cv.notify_all()

    def mark_alive(self, node: str) -> None:
        with self._lock:
            self._failed.discard(node)

    def is_failed(self, node: str) -> bool:
        with self._lock:
            return node in self._failed

    def wait_route(self, key: str, deadline: float | None) -> str:
        """Block until ``key`` has a home (a Put registered it)."""
        with self._cv:
            while True:
                home = self._routes.get(key)
                if home is None:
                    home = self._routes.get(base_key(key))
                if home is not None:
                    return home
                remaining = None
                if deadline is not None:
                    remaining = deadline - time.monotonic()
                    if remaining <= 0:
                        raise GetTimeout(f"Get({key!r}) timed out")
                self._cv.wait(remaining)


def static_routes(wf: "Workflow", placement: Mapping[str, str],
                  nodes: list[str] | None = None) -> dict[str, str]:
    """Raw-key routing table from the GS partitioner's placement: a
    function's outputs are homed on its node; external inputs where they
    are staged (first consumer's node — the same authority the engine and
    DPlan use, so the table matches what the runtime actually does)."""
    routes: dict[str, str] = {}
    default = nodes[0] if nodes else next(iter(placement.values()), None)
    for f in wf.functions.values():
        for k in f.outputs:
            routes[k] = placement[f.name]
    for k in wf.external_inputs:
        home = stage_node(wf, k, placement, default)
        if home is not None:
            routes[k] = home
    return routes


def routes_from_plan(plan: "WorkflowPlan") -> dict[str, str]:
    """Raw-key routes from DPlan's IR — the preferred source: the plan's
    transfer matrix already names every key's producing node (externals:
    the ``src`` of their staged transfer)."""
    routes: dict[str, str] = {}
    placement = plan.placement or {}
    for k, kp in plan.keys.items():
        if kp.producer is not None and kp.producer in placement:
            routes[k] = placement[kp.producer]
    for t in plan.transfers:
        if t.producer is None and t.src:
            routes[t.key] = t.src
    return routes


class _ShardView:
    """Read-only aggregate facade over the per-node shards, bound to
    ``ShardedDStore.directory`` so diagnostics written against the
    single-store API (``directory.keys()`` / ``directory.peek()``) keep
    working.  Mutations go through the store's overridden methods."""

    def __init__(self, owner: "ShardedDStore"):
        self._owner = owner

    def keys(self) -> list[str]:
        out: list[str] = []
        for shard in self._owner.shards.values():
            out.extend(shard.keys())
        return sorted(set(out))

    def peek(self, key: str):
        shard = self._owner.shard_of(key)
        if shard is not None:
            m = shard.peek(key)
            if m is not None:
                return m
        for shard in self._owner.shards.values():
            m = shard.peek(key)
            if m is not None:
                return m
        return None


class ShardedDStore(DStore):
    """Per-node directory shards + local routing tables (drop-in DStore).

    Gets resolve against the consumer node's :class:`RoutingTable` and
    contact exactly the home shard; hop counts and transport tiers are
    tracked per store (``hop_hist`` / ``tier_gets``) and emitted as
    ``route`` trace events carrying ``src``/``tier``/``hops`` for the
    checker's 1-hop routing invariant.
    """

    def __init__(self, nodes: list[str], transport: Transport | None = None,
                 *, coordinator: Coordinator | None = None):
        # Base init wires streams/stores/transport and — under the test
        # harness — auto-attaches the DCheck tracer (conftest patches
        # DStore.__init__, which this super() call resolves to).
        super().__init__(nodes, transport)
        self.node_list = list(nodes)
        self.shards: dict[str, DataDirectoryService] = {
            n: DataDirectoryService() for n in nodes}
        self.tables: dict[str, RoutingTable] = {
            n: RoutingTable(n) for n in nodes}
        self.coordinator = coordinator or Coordinator(nodes)
        # The base class's single directory is replaced by a read-only
        # union view; every method that mutated it is overridden below.
        self.directory = _ShardView(self)
        # DPlan-advisory per-node capacity (presize_from_plan).
        self.capacity_bytes: dict[str, int] = {n: 0 for n in nodes}
        self._stats_lock = threading.Lock()
        self.hop_hist: dict[int, int] = {0: 0, 1: 0, 2: 0}
        self.tier_gets = {TIER_IPC: 0, TIER_MEM: 0, TIER_NET: 0}

    # -- routing-table plumbing -------------------------------------------
    def shard_of(self, key: str) -> DataDirectoryService | None:
        home = self.coordinator.route_of(key)
        return self.shards.get(home) if home is not None else None

    def register_instance(self, prefix: str, wf: "Workflow",
                          placement: Mapping[str, str], *,
                          plan: "WorkflowPlan | None" = None) -> None:
        """Install one instance's static routes with the coordinator (the
        engine calls this before staging inputs).  Tables are NOT eagerly
        pushed — each node picks the routes up on its first sync, which is
        the stale-table refresh path working as designed."""
        routes = static_routes(wf, placement, nodes=self.node_list)
        if plan is not None and plan.placement:
            routes.update(routes_from_plan(plan))
            self.presize_from_plan(plan)
        self.coordinator.install({prefix + k: n for k, n in routes.items()})

    def register_metrics(self, registry) -> None:
        """Base collectors (resident/peak/transport) plus the sharded
        routing counters: hop histogram, per-tier Get counts and traffic,
        routing-table hit/miss/refresh and coordinator syncs."""
        super().register_metrics(registry)

        def _scrape() -> None:
            with self._stats_lock:
                hops = dict(self.hop_hist)
                tiers = dict(self.tier_gets)
            for h, n in hops.items():
                registry.counter("routing_gets", hops=h).set(n)
            for tier, n in tiers.items():
                registry.counter("tier_gets", tier=tier).set(n)
            t = self.transport
            if isinstance(t, TieredTransport):
                for tier, n in t.tier_bytes.items():
                    registry.counter("tier_bytes", tier=tier).set(n)
                for tier, n in t.tier_transfers.items():
                    registry.counter("tier_transfers", tier=tier).set(n)
            for node, tb in self.tables.items():
                registry.counter("routing_table_hits",
                                 node=node).set(tb.hits)
                registry.counter("routing_table_misses",
                                 node=node).set(tb.misses)
                registry.counter("routing_table_refreshes",
                                 node=node).set(tb.refreshes)
            registry.counter("coordinator_syncs").set(
                self.coordinator.syncs)
            # Per-node byte budgets (presized from DPlan's peak_resident):
            # DScale's autoscaler reads these against resident bytes to
            # hold scale-up on memory-bound nodes.
            for node, cap in self.capacity_bytes.items():
                registry.gauge("capacity_bytes", node=node).set(cap)
        registry.register_collector(_scrape)

    def presize_from_plan(self, plan: "WorkflowPlan") -> None:
        """Advisory per-node capacity from DPlan's peak-resident
        prediction (max over instances sharing the store)."""
        for node, peak in plan.peak_resident.items():
            if node in self.capacity_bytes:
                self.capacity_bytes[node] = max(
                    self.capacity_bytes[node], int(peak))

    def _home_for_put(self, node: str, key: str) -> str:
        home = self.coordinator.route_of(key)
        if home is None:
            # Unplanned key: the writer's node becomes its home (dynamic
            # registration; wakes wait_route blockers).
            self.coordinator.rehome(base_key(key), node)
            return node
        if home != node and self.coordinator.is_failed(home):
            # The home shard's node died: recovery re-homes the key to the
            # writer so the re-published record is reachable.
            self.coordinator.rehome(base_key(key), node)
            return node
        return home

    # -- Table 1 core API, sharded ----------------------------------------
    # _put/_put_chunk/_get are the inner methods: the base class's public
    # put/put_chunk/get wrappers add the DScope span/metric hooks once, so
    # sharded stores are instrumented identically to the single store.
    def _put(self, node: str, key: str, value) -> None:
        home = self._home_for_put(node, key)
        shard = self.shards[home]
        store = self.stores[node]
        digest = content_digest(value)
        tracer = self._tracer
        with self._write_lock:
            meta = shard.peek(key)
            if meta is not None:
                if (digest is not None and meta.digest is not None
                        and meta.digest != digest):
                    raise ImmutabilityError(
                        f"put({key!r}) from {node!r} diverges from the "
                        f"first writer's content: DStore data is immutable")
                if store.has(key):
                    return          # duplicate write: first-writer-wins
            if tracer is not None:
                tracer.record("put", key, node, size=_sizeof(value),
                              digest=digest, src=home)
            store.write(key, value)
            shard.publish(key, _sizeof(value), node, digest=digest)
            self._note_peak()
        self.streams.notify_plain(key)

    def _put_chunk(self, node: str, key: str, idx: int,
                   chunk: bytes) -> None:
        home = self._home_for_put(node, key)
        ck = chunk_key(key, idx)
        digest = content_digest(chunk)
        with self._write_lock:
            if self._tracer is not None:
                self._tracer.record("put_chunk", key, node, idx=idx,
                                    size=len(chunk), digest=digest, src=home)
                self._tracer.record("put", ck, node, size=len(chunk),
                                    digest=digest, src=home)
            self.stores[node].write(ck, chunk)
            self.shards[home].publish(ck, len(chunk), node, digest=digest)
            self._note_peak()
        self.streams.publish_chunk(key, idx, len(chunk))

    def _get(self, node: str, key: str, timeout: float | None = None):
        store = self.stores[node]
        table = self.tables[node]
        deadline = None if timeout is None else time.monotonic() + timeout
        wrong = 0       # alive-but-wrong shard contacts (stale table)
        home: str | None = None
        while True:
            if store.has(key):
                self._note_local_hit(node, key)
                return store.read(key)
            if home is None:
                home = table.lookup(key)
                if home is None:
                    # Table miss → one coordinator sync (the refresh path;
                    # a *legal* resolution, still 1 hop to the data).
                    self.coordinator.sync(table)
                    home = table.lookup(key)
            if home is None:
                # Key not registered anywhere yet: block at the
                # coordinator until a Put dynamically homes it.
                home = self.coordinator.wait_route(key, deadline)
            remaining = None
            if deadline is not None:
                remaining = deadline - time.monotonic()
                if remaining <= 0:
                    raise GetTimeout(f"Get({key!r}) timed out")
            wait_s = _ROUTE_POLL if remaining is None \
                else min(_ROUTE_POLL, remaining)
            try:
                meta = self.shards[home].wait(key, wait_s)
            except GetTimeout:
                # Still blocked at `home`: re-check the authoritative
                # route — it moves on failure re-home or if our table was
                # stale all along.
                auth = self.coordinator.route_of(key)
                if auth is not None and auth != home:
                    if not self.coordinator.is_failed(home):
                        wrong += 1      # genuine misroute: extra shard hop
                    self.coordinator.sync(table)
                    home = auth
                continue
            value = self._pull(node, key, meta, home, hops=1 + wrong)
            if value is not _MISSING:
                return value

    def _note_local_hit(self, node: str, key: str) -> None:
        # ipc: the key is homed here (trigger payload / own output);
        # mem: local replica of a remotely-homed key (earlier pull).  The
        # coordinator fallback is stats-only classification, not routing —
        # a never-synced table would otherwise misfile ipc hits as mem.
        home = self.tables[node].peek(key)
        if home is None:
            home = self.coordinator.route_of(key)
        tier = TIER_IPC if home == node else TIER_MEM
        with self._stats_lock:
            self.hop_hist[0] = self.hop_hist.get(0, 0) + 1
            self.tier_gets[tier] += 1

    def _pull(self, node: str, key: str, meta, home: str, *, hops: int):
        shard = self.shards[home]
        store = self.stores[node]
        try:
            src = shard.choose_replica(key)
        except KeyError:
            return _MISSING            # record vanished while unlocked
        try:
            value = self.stores[src].read(key)
        except KeyError:
            shard.release_replica(key, src)
            shard.drop_replica(key, src)    # phantom replica
            return _MISSING
        tier = TIER_MEM if src == node else TIER_NET
        spans = self._spans
        sp = spans.start(key, "hop", src=home, tier=tier, hops=hops,
                         size=meta.size) if spans is not None else None
        try:
            self._move(meta.size, tier)     # receiver-driven pull
        finally:
            if sp is not None:
                spans.end(sp)
            shard.release_replica(key, src)
        with self._write_lock:
            if self._tracer is not None:
                self._tracer.record("replica", key, node, size=meta.size,
                                    digest=meta.digest, src=home)
                self._tracer.record("route", key, node, size=meta.size,
                                    src=home, tier=tier, hops=hops)
            store.write(key, value)
            shard.publish(key, meta.size, node, digest=meta.digest)
            self._note_peak()
        with self._stats_lock:
            self.hop_hist[hops] = self.hop_hist.get(hops, 0) + 1
            self.tier_gets[tier] += 1
        return value

    def _move(self, size: int, tier: str) -> None:
        if isinstance(self.transport, TieredTransport):
            self.transport.move(size, tier)
        elif tier == TIER_NET:
            # Plain transport keeps its single-store meaning: cross-node
            # traffic only (same-node pulls are memoryview handoffs).
            self.transport.move(size)

    # -- eviction, sharded -------------------------------------------------
    def evict_key(self, key: str) -> None:
        with self._write_lock:
            existed = any(sh.peek(key) is not None
                          for sh in self.shards.values())
            if self._tracer is not None and existed:
                self._tracer.record("evict", key)
            for store in self.stores.values():
                store.drop_key(key)
            for shard in self.shards.values():
                shard.drop([key])
        if existed and self._spans is not None:
            self._spans.event(key, "evict", parent=None,
                              trace=_trace_of(key))
        # Routes are left installed: keys are immutable, so a stale route
        # for an evicted key can only lead to a clean block, never stale
        # bytes.

    def evict_instance(self, prefix: str) -> None:
        swept: list[str] = []
        with self._write_lock:
            if self._tracer is not None or self._spans is not None:
                for shard in self.shards.values():
                    for k in shard.keys():
                        if k.startswith(prefix):
                            if self._tracer is not None:
                                self._tracer.record("evict", k)
                            swept.append(k)
            for store in self.stores.values():
                store.drop_prefix(prefix)
            for shard in self.shards.values():
                shard.drop_prefix(prefix)
        self.streams.evict_prefix(prefix)
        if self._spans is not None:
            for k in swept:
                self._spans.event(k, "evict", parent=None,
                                  trace=_trace_of(k))
        self.coordinator.remove_prefix(prefix)
        if self._plan_reads:
            with self._plan_lock:
                for k in [k for k in self._plan_reads
                          if k.startswith(prefix)]:
                    del self._plan_reads[k]

    # -- fault handling, sharded -------------------------------------------
    def fail_node(self, node: str) -> list[str]:
        """Node loss under sharding: the node's bytes AND its directory
        shard die together.  Shard records with replicas surviving on
        other nodes migrate to a survivor's shard (the coordinator
        re-homes them — bounded work, no directory-wide scan); the rest
        are lost and must be recomputed."""
        self.streams.fail_owner(node)
        with self._write_lock:
            tracer = self._tracer
            if tracer is not None:
                tracer.record("fail_node", node=node)
            self.stores[node].drop_all()
            self.coordinator.mark_failed(node)
            lost: list[str] = []
            # Replicas hosted on the dead node vanish from every *other*
            # shard (each shard walks only its own records).
            for n, shard in self.shards.items():
                if n != node:
                    lost.extend(shard.drop_node(node))
            # Migrate the dead shard's surviving records.
            dead = self.shards[node]
            for k in dead.keys():
                m = dead.peek(k)
                if m is None:
                    continue
                survivors = sorted(
                    n for n in m.locations
                    if n != node and self.stores[n].has(k))
                if not survivors:
                    lost.append(k)
                    continue
                new_home = survivors[0]
                for n in survivors:
                    if tracer is not None:
                        tracer.record("publish", k, n, size=m.size,
                                      digest=m.digest, src=new_home)
                    self.shards[new_home].publish(k, m.size, n,
                                                  digest=m.digest)
                self.coordinator.rehome(k, new_home)
            # Fresh shard object: the node itself comes back (recovery may
            # re-place functions on it) with an empty directory.
            self.shards[node] = DataDirectoryService()
            self.coordinator.mark_alive(node)
            lost = sorted(set(lost))
            if tracer is not None:
                for k in lost:
                    tracer.record("drop", k, node)
            return lost
