"""DScale — autoscaling, admission control, and SLO-aware prewarm budgets.

DServe (PR 2) gave the serving layer explicit container pools, but left
three resource decisions unmade: pool capacity never moves, admission is
unbounded, and §3.2 prewarm is free.  DScale closes the loop:

* :class:`PoolAutoscaler` — an arrival-rate-estimating control loop over
  :class:`~repro.core.obs.MetricsRegistry` *rates* (arrival counters,
  ``serve_latency_seconds`` percentiles, ``containers_live``, DShard's
  per-node ``capacity_bytes`` / ``dstore_resident_bytes`` and per-tier
  ``tier_bytes``) — never private subsystem counters.  Each ``step(now)``
  derives a Little's-law target per (node, image) pool
  (``ceil(rate × service_time × headroom)``), applies it through a
  callback (:meth:`~repro.core.serve.ContainerService.set_target` in the
  threaded engine, the sim pool adapter under a virtual clock), and
  publishes every decision back as registry events
  (``autoscale_decisions_total`` / ``pool_target``) *and* tracer span
  instants (``kind="scale"``).  Clock-agnostic like ``ContainerPool``:
  callers supply ``now``.
* :class:`PrewarmBudget` — a token bucket of *container-seconds* that
  prices §3.2 prewarm instead of leaving it free.
  :func:`allocate_prewarms` spends it along DPlan's slack ranking:
  ``FunctionPlan.boot_at`` already prices each boot
  (``boot_cost = est − boot_at``), and slack ranks which boots are
  droppable — critical-path (slack 0) boots are granted first, so a
  tightening budget drops the highest-slack prewarms and the
  lowest-slack ones last (optimizing p99 per container-second).
* :func:`diurnal_arrivals` / :func:`bursty_arrivals` — deterministic
  inhomogeneous-Poisson arrival generators (Lewis thinning over the same
  seeded LCG as :func:`~repro.core.serve.poisson_arrivals`) for the
  trace shapes Triggerflow-style orchestration must survive.

Admission control itself (bounded FIFO queue + shedding) lives in
:class:`~repro.core.serve.DServe` (``max_inflight`` / ``queue_depth``);
this module supplies the policy objects it composes with.
"""

from __future__ import annotations

import math
import threading
from dataclasses import dataclass
from typing import Any, Callable, Iterator, Mapping, Sequence

__all__ = [
    "AutoscalerConfig", "PoolAutoscaler", "PoolSpec", "ScaleDecision",
    "RateEstimator", "PrewarmBudget", "PrewarmGrant", "allocate_prewarms",
    "diurnal_arrivals", "bursty_arrivals",
]


# ----------------------------------------------------------------------
# Arrival generators (deterministic; no global RNG)
# ----------------------------------------------------------------------

def _lcg(seed: int) -> Iterator[float]:
    """The project's seeded LCG as a (0, 1) uniform stream — same
    constants as :func:`~repro.core.serve.poisson_arrivals`."""
    s = (seed * 2654435761 + 0x9E3779B9) & 0xFFFFFFFF
    while True:
        s = (1103515245 * s + 12345) & 0x7FFFFFFF
        yield (s + 1) / (0x7FFFFFFF + 2)


def _thinned_arrivals(n: int, rate_fn: Callable[[float], float],
                      rate_max: float, seed: int) -> list[float]:
    """Lewis thinning: candidate arrivals at ``rate_max``, accepted with
    probability ``rate_fn(t) / rate_max`` — an exact inhomogeneous
    Poisson process, deterministic per seed."""
    u = _lcg(seed)
    t, out = 0.0, []
    while len(out) < n:
        t += -math.log(next(u)) / rate_max
        if next(u) * rate_max <= rate_fn(t):
            out.append(t)
    return out


def diurnal_arrivals(n: int, *, base_rate: float, peak_rate: float,
                     period: float = 60.0, seed: int = 0) -> list[float]:
    """Diurnal (sinusoidal) arrivals: the rate swings from ``base_rate``
    (t=0 is the trough) up to ``peak_rate`` and back once per ``period``
    seconds — a compressed day/night load curve."""
    if base_rate <= 0 or peak_rate < base_rate or period <= 0:
        raise ValueError("need 0 < base_rate <= peak_rate and period > 0")

    def rate(t: float) -> float:
        swing = 0.5 * (1.0 - math.cos(2.0 * math.pi * t / period))
        return base_rate + (peak_rate - base_rate) * swing

    return _thinned_arrivals(n, rate, peak_rate, seed)


def bursty_arrivals(n: int, *, base_rate: float, burst_rate: float,
                    burst_every: float, burst_len: float,
                    seed: int = 0) -> list[float]:
    """Bursty arrivals: ``burst_rate`` for the first ``burst_len`` seconds
    of every ``burst_every``-second cycle (bursts start at t=0), trickling
    at ``base_rate`` in between — the on/off trace shape that punishes
    fixed pools (idle burn) and pure keep-alive (cold re-boots)."""
    if base_rate <= 0 or burst_rate < base_rate:
        raise ValueError("need 0 < base_rate <= burst_rate")
    if not 0 < burst_len < burst_every:
        raise ValueError("need 0 < burst_len < burst_every")

    def rate(t: float) -> float:
        return burst_rate if (t % burst_every) < burst_len else base_rate

    return _thinned_arrivals(n, rate, burst_rate, seed)


# ----------------------------------------------------------------------
# Prewarm budget (container-seconds, allocated by DPlan slack)
# ----------------------------------------------------------------------

@dataclass
class PrewarmGrant:
    """One admitted prewarm: ``cost`` container-seconds were debited for
    booting ``function`` ahead of need.  ``settle`` at fire time (a
    revoked grant must not boot), ``cancel`` refunds an unfired grant."""

    function: str
    cost: float
    slack: float
    fired: bool = False
    revoked: bool = False
    refunded: bool = False


class PrewarmBudget:
    """Token bucket of prewarm container-seconds (clock-agnostic).

    Prewarm is free in the §3.2 heuristic; a real cluster pays for every
    second a container idles ahead of its function.  The bucket starts at
    ``capacity_s`` and refills at ``refill_per_s`` (0 = one-shot budget);
    time is whatever clock the caller runs on (wall or virtual) —
    ``available``/``request`` take ``now`` and refill lazily.

    Grants are revocable until they fire: :meth:`reclaim` revokes pending
    grants **highest slack first** (slack ranks droppability — DPlan's
    critical-path boots go last), and a scheduler arming prewarm timers
    must :meth:`settle` each grant at fire time and skip the boot when it
    returns False.
    """

    def __init__(self, capacity_s: float, *, refill_per_s: float = 0.0):
        if capacity_s < 0 or refill_per_s < 0:
            raise ValueError("capacity_s and refill_per_s must be >= 0")
        self.capacity = float(capacity_s)
        self.refill_per_s = float(refill_per_s)
        self._tokens = float(capacity_s)
        self._last: float | None = None
        self._pending: list[PrewarmGrant] = []
        self._lock = threading.Lock()
        self.granted = 0
        self.denied = 0
        self.revoked = 0
        self.spent_s = 0.0

    def _refill(self, now: float) -> None:
        if self._last is not None and now > self._last \
                and self.refill_per_s > 0:
            self._tokens = min(self.capacity, self._tokens +
                               (now - self._last) * self.refill_per_s)
        self._last = now if self._last is None else max(self._last, now)

    def available(self, now: float) -> float:
        with self._lock:
            self._refill(now)
            return self._tokens

    def request(self, function: str, cost: float, *, slack: float = 0.0,
                now: float = 0.0) -> PrewarmGrant | None:
        """Debit ``cost`` container-seconds for one prewarm; None = the
        budget is exhausted and the boot must be dropped."""
        cost = max(0.0, float(cost))
        with self._lock:
            self._refill(now)
            if cost > self._tokens + 1e-12:
                self.denied += 1
                return None
            self._tokens -= cost
            self.spent_s += cost
            grant = PrewarmGrant(function=function, cost=cost, slack=slack)
            self._pending.append(grant)
            self.granted += 1
            return grant

    def settle(self, grant: PrewarmGrant) -> bool:
        """Consume the grant at fire time; False = it was revoked (the
        timer must not boot)."""
        with self._lock:
            if grant in self._pending:
                self._pending.remove(grant)
            if grant.revoked:
                return False
            grant.fired = True
            return True

    def cancel(self, grant: PrewarmGrant) -> None:
        """Refund an unfired grant (instance finished / was evicted
        before its timer fired).  Also revokes it, so a timer racing the
        cancellation sees ``settle`` fail and never boots."""
        with self._lock:
            if grant.fired or grant.revoked or grant.refunded:
                return
            grant.refunded = True
            grant.revoked = True
            if grant in self._pending:
                self._pending.remove(grant)
            self._tokens = min(self.capacity, self._tokens + grant.cost)
            self.spent_s -= grant.cost

    def refund(self, grant: PrewarmGrant) -> None:
        """Refund a settled grant whose boot turned out to be a no-op
        (an idle/booting container already existed)."""
        with self._lock:
            if grant.refunded:
                return
            grant.refunded = True
            self._tokens = min(self.capacity, self._tokens + grant.cost)
            self.spent_s -= grant.cost

    def reclaim(self, seconds: float, now: float) -> list[PrewarmGrant]:
        """Revoke pending (unfired) grants until at least ``seconds``
        container-seconds are recovered — highest slack first, so
        critical-path boots survive the squeeze."""
        out: list[PrewarmGrant] = []
        with self._lock:
            self._refill(now)
            reclaimed = 0.0
            for grant in sorted(self._pending, key=lambda g: -g.slack):
                if reclaimed >= seconds:
                    break
                grant.revoked = True
                self._pending.remove(grant)
                self._tokens = min(self.capacity,
                                   self._tokens + grant.cost)
                self.spent_s -= grant.cost
                reclaimed += grant.cost
                self.revoked += 1
                out.append(grant)
        return out


def allocate_prewarms(plan, budget: PrewarmBudget | None,
                      now: float = 0.0) -> list[tuple]:
    """Spend a prewarm budget along DPlan's slack ranking.

    Grants are requested **lowest slack first** (critical-path boots are
    the ones a p99-per-container-second optimizer can least afford to
    drop), each priced at :attr:`~repro.core.plan.FunctionPlan.boot_cost`
    — the container-seconds the boot spends ahead of the function's
    earliest start.  Denied entries are dropped; the survivors come back
    in boot order as ``(function, boot_at, cold_start, grant)`` rows
    (``grant`` is None when no budget applies).
    """
    entries = sorted(
        plan.prewarm_schedule,
        key=lambda e: (plan.functions[e[0]].slack, e[1], e[0]))
    out = []
    for fname, boot_at, cold in entries:
        fp = plan.functions[fname]
        if budget is None:
            out.append((fname, boot_at, cold, None))
            continue
        grant = budget.request(fname, fp.boot_cost, slack=fp.slack,
                               now=now)
        if grant is not None:
            out.append((fname, boot_at, cold, grant))
    out.sort(key=lambda e: (e[1], e[0]))
    return out


# ----------------------------------------------------------------------
# Arrival-rate estimation + pool autoscaler
# ----------------------------------------------------------------------

class RateEstimator:
    """Windowed rate from samples of a monotonic counter: ``observe(now,
    total)`` then ``rate()`` = counter delta / time span over the last
    ``window`` seconds.  Clock-agnostic and cheap (a short deque)."""

    def __init__(self, window: float = 1.0):
        if window <= 0:
            raise ValueError("window must be > 0")
        self.window = float(window)
        self._samples: list[tuple[float, float]] = []

    def observe(self, now: float, total: float) -> None:
        self._samples.append((now, total))
        cutoff = now - self.window
        # Keep one sample at/just before the cutoff so the span covers
        # the full window once enough history exists.
        while len(self._samples) > 2 and self._samples[1][0] <= cutoff:
            self._samples.pop(0)

    def rate(self) -> float:
        if len(self._samples) < 2:
            return 0.0
        (t0, c0), (t1, c1) = self._samples[0], self._samples[-1]
        span = t1 - t0
        if span <= 0:
            return 0.0
        # A short history still divides by the full window: two samples
        # 50 ms apart do not evidence a 20x sustained rate.
        return max(0.0, c1 - c0) / max(span, self.window)


@dataclass(frozen=True)
class PoolSpec:
    """One scalable pool: the (node, image) identity plus what the
    autoscaler needs to size it (mean service time, boot cost)."""

    node: str
    image: str
    service_time: float
    cold_start: float = 0.5


@dataclass(frozen=True)
class AutoscalerConfig:
    interval: float = 0.1            # control-loop period (threaded mode)
    window: float = 1.0              # rate-estimation window (seconds)
    headroom: float = 1.5            # target = ceil(rate*service*headroom)
    min_pool: int = 0
    max_pool: int = 64
    scale_down_delay: float = 0.5    # sustain low demand before shrinking
    slo_p99: float | None = None     # latency SLO: p99 above it bumps +1
    mem_pressure: float = 0.9        # resident/capacity gate for scale-up


@dataclass(frozen=True)
class ScaleDecision:
    at: float
    node: str
    image: str
    previous: int | None
    target: int
    rate: float
    reason: str


class PoolAutoscaler:
    """Arrival-rate-estimating autoscaler over registry rates.

    Sensors (all read from the :class:`~repro.core.obs.MetricsRegistry`;
    the autoscaler owns no private counters):

    * ``serve_arrivals_total``  — workload demand (rate estimation).
    * ``serve_latency_seconds`` — p99 vs the optional SLO (pressure bump).
    * ``containers_live``       — published per (node, image) by the pool
      collector; decisions are diffed against it for observability.
    * ``capacity_bytes`` / ``dstore_resident_bytes`` (DShard, per node) —
      a memory-bound node (utilization > ``mem_pressure``) refuses
      scale-up: more containers would worsen the pressure.
    * ``tier_bytes`` (DShard, per tier) — the network-bound share is
      attached to each decision so operators can tell *why* a node
      saturated.

    Actuation goes through ``apply(node, image, target, cold_start)``
    (``ContainerService.set_target`` threaded, the sim adapter under a
    virtual clock), and every decision is published twice: registry
    events (``autoscale_decisions_total{direction=...}`` counters +
    ``pool_target`` gauges) and tracer span instants (``kind="scale"``).
    """

    def __init__(self, registry, pools: Sequence[PoolSpec], *,
                 cfg: AutoscalerConfig | None = None,
                 apply: Callable[..., Any] | None = None,
                 spans=None,
                 arrivals_metric: str = "serve_arrivals_total",
                 arrivals_labels: Mapping[str, Any] | None = None):
        self.registry = registry
        self.pools = list(pools)
        self.cfg = cfg or AutoscalerConfig()
        self.apply = apply
        self.spans = spans
        self.arrivals_metric = arrivals_metric
        self.arrivals_labels = dict(arrivals_labels or {})
        self._rate = RateEstimator(self.cfg.window)
        self._targets: dict[tuple[str, str], int] = {}
        self._low_since: dict[tuple[str, str], float] = {}
        self.decisions: list[ScaleDecision] = []
        self._lock = threading.Lock()

    # -- sensors -----------------------------------------------------------
    def _arrivals_total(self) -> float:
        reg = self.registry
        if self.arrivals_labels:
            return reg.counter(self.arrivals_metric,
                               **self.arrivals_labels).value
        return reg.total(self.arrivals_metric)

    def _p99(self) -> float:
        h = self.registry.histogram("serve_latency_seconds",
                                    **self.arrivals_labels)
        return h.percentile(99.0) if h.count else math.nan

    def _node_mem_utilization(self) -> dict[str, float]:
        reg = self.registry
        cap = reg.label_values("capacity_bytes", "node")
        res = reg.label_values("dstore_resident_bytes", "node")
        return {n: res.get(n, 0.0) / c for n, c in cap.items() if c > 0}

    def _net_share(self) -> float:
        tiers = self.registry.label_values("tier_bytes", "tier")
        total = sum(tiers.values())
        return tiers.get("net", 0.0) / total if total > 0 else 0.0

    # -- control loop ------------------------------------------------------
    def step(self, now: float) -> list[ScaleDecision]:
        """One control iteration at ``now`` (clock-agnostic): refresh the
        pull collectors, estimate the arrival rate, and re-target every
        pool.  Returns the decisions taken this step."""
        cfg = self.cfg
        with self._lock:
            self.registry.collect()
            self._rate.observe(now, self._arrivals_total())
            rate = self._rate.rate()
            p99 = self._p99()
            slo_bump = 1 if (cfg.slo_p99 is not None
                             and not math.isnan(p99)
                             and p99 > cfg.slo_p99) else 0
            mem_util = self._node_mem_utilization()
            net_share = self._net_share()
            out: list[ScaleDecision] = []
            for spec in self.pools:
                key = (spec.node, spec.image)
                desired = 0
                if rate > 0:
                    desired = math.ceil(
                        rate * max(spec.service_time, 0.0) * cfg.headroom
                        - 1e-9) + slo_bump
                desired = max(cfg.min_pool, min(cfg.max_pool, desired))
                current = self._targets.get(key)
                reason = "rate"
                if current is not None and desired > current \
                        and mem_util.get(spec.node, 0.0) > cfg.mem_pressure:
                    # Memory-bound node: adding containers would deepen
                    # the pressure; hold (a held pool produces no decision,
                    # so the hold itself is published as a counter).
                    self.registry.counter(
                        "autoscale_mem_holds_total", node=spec.node,
                        image=spec.image).inc()
                    desired = current
                if current is not None and desired < current:
                    # Hysteresis: only shrink after sustained low demand.
                    since = self._low_since.setdefault(key, now)
                    if now - since < cfg.scale_down_delay:
                        continue
                    reason = "idle"
                else:
                    self._low_since.pop(key, None)
                if desired == current:
                    continue
                if current is None and desired == 0:
                    # No rate evidence yet: pinning a fresh pool to zero
                    # would evict idles before any demand was seen.
                    continue
                self._low_since.pop(key, None)
                self._targets[key] = desired
                if self.apply is not None:
                    self.apply(spec.node, spec.image, desired,
                               spec.cold_start)
                d = ScaleDecision(at=now, node=spec.node, image=spec.image,
                                  previous=current, target=desired,
                                  rate=rate, reason=reason)
                out.append(d)
                self.decisions.append(d)
                self._publish(d, net_share)
            self.registry.counter("autoscale_steps_total").inc()
        return out

    def _publish(self, d: ScaleDecision, net_share: float) -> None:
        reg = self.registry
        direction = "up" if d.previous is None or d.target > d.previous \
            else "down"
        reg.counter("autoscale_decisions_total", node=d.node,
                    image=d.image, direction=direction).inc()
        reg.gauge("pool_target", node=d.node, image=d.image).set(d.target)
        live = reg.gauge("containers_live", node=d.node,
                         image=d.image).value
        if self.spans is not None:
            self.spans.event(
                d.image, kind="scale", parent=None, trace="autoscaler",
                node=d.node, direction=direction, target=d.target,
                previous=d.previous, rate=round(d.rate, 3),
                reason=d.reason, containers_live=live,
                net_share=round(net_share, 3))

    def target(self, node: str, image: str) -> int | None:
        with self._lock:
            return self._targets.get((node, image))
