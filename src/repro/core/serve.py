"""DServe — concurrent multi-instance serving with explicit container pools.

The paper's headline wins are tail latency under load and a 5.6x cold-start
reduction (§5.4), but a single-instance engine cannot exhibit either: both
require many workflow instances in flight sharing one cluster's containers
and one DStore.  This module adds the serving substrate:

* :class:`ContainerPool` — an explicit, clock-agnostic container lifecycle
  model for one (node, function-image) pair: cold boot, warm reuse,
  keep-alive TTL eviction, and *dataflow-triggered prewarm* (paper §3.2: a
  function's container starts booting when its **precursor launches**, not
  when its inputs arrive, so boot time overlaps precursor execution).  The
  model is pure state + timestamps — every method takes ``now`` and returns
  delays — so the *same* lifecycle (and the same metrics: cold starts,
  warm/prewarm hits, evictions, container-seconds) backs both the threaded
  engine (wall clock) and the discrete-event simulator (virtual clock, via
  :class:`repro.core.simcluster._ContainerPool`).
* :class:`ContainerService` — thread-safe wall-clock adapter used by
  :class:`~repro.core.dscheduler.DFlowEngine`: per-(node, image) pools plus
  a bounded per-node execution-slot semaphore (per-node concurrency cap).
* :func:`poisson_arrivals` / :func:`trace_arrivals` — open-loop arrival
  processes (deterministic LCG exponential gaps; no global RNG).
* :class:`DServe` — the serving layer: drives N concurrent workflow
  instances through one shared engine + DStore with per-instance key
  namespacing (``"<wf>#<i>:<key>"``), instance-scoped eviction on
  completion, optional node-failure injection with per-instance incremental
  recovery, and a :class:`ServeReport` aggregating p50/p95/p99 latency,
  cold-start counts, and container-seconds.
"""

from __future__ import annotations

import math
import threading
import time
from contextlib import contextmanager
from dataclasses import dataclass, field
from typing import Any, Callable, Iterable, Mapping, Sequence

from .obs import MetricsRegistry

__all__ = [
    "ContainerPool", "ContainerService", "DServe", "Lease", "ServeReport",
    "InstanceStat", "percentile", "poisson_arrivals", "trace_arrivals",
]

# The metrics a ServeReport is built from; DServe.run snapshots their
# registry totals before/after so the report covers one run even though
# the service (and its warm containers) outlives runs.
_SERVE_BASE_METRICS = (
    "container_cold_starts", "container_prewarm_boots",
    "container_warm_hits", "container_prewarm_hits",
    "container_evictions", "container_seconds",
    "serve_queued_total", "serve_shed_total",
)


# ----------------------------------------------------------------------
# Container lifecycle model (pure; shared by engine and simulator)
# ----------------------------------------------------------------------

@dataclass
class _Container:
    boot_at: float                   # when the boot started
    ready_at: float                  # when the boot completes (<= now: ready)
    busy: bool                       # leased to a running function
    idle_since: float                # last release time (TTL anchor)


@dataclass
class Lease:
    """Handle for one leased container.

    ``release(lease, now)`` must return the *same* container the acquire
    marked busy — with mixed warm hits and prewarm hits a first-busy
    release can mark a still-booting container idle with the wrong
    ``idle_since``, skewing MRU reuse, TTL eviction, and
    container-seconds.  The token pins the container identity.
    """

    container: _Container
    delay: float                     # boot delay the caller must wait out
    cold: bool                       # paid a full request-path cold boot
    released: bool = False


class ContainerPool:
    """Lifecycle of containers for one (node, function-image) pair.

    Clock-agnostic: callers supply ``now`` (wall clock in the threaded
    engine, ``env.now`` in the simulator) and receive *delays*.  Metrics:

    * ``cold_starts``    — boots paid on the request path (a function had to
      start a container and wait out the full ``cold_start``).
    * ``prewarm_boots``  — boots started ahead of need (off the request
      path); ``boots = cold_starts + prewarm_boots``.
    * ``warm_hits``      — acquires served instantly by an idle container.
    * ``prewarm_hits``   — acquires that joined a container still booting
      (they wait only the residual boot time — the §3.2 overlap).
    * ``evictions`` / ``container_seconds`` — keep-alive TTL reclaim and the
      aggregate container occupancy (the cost axis of a serving system).
    """

    def __init__(self, image: str = "", *, cold_start: float = 0.5,
                 keepalive: float = 600.0):
        if cold_start < 0 or keepalive <= 0:
            raise ValueError("cold_start must be >= 0 and keepalive > 0")
        self.image = image
        self.cold_start = float(cold_start)
        self.keepalive = float(keepalive)
        self._containers: list[_Container] = []
        self.cold_starts = 0
        self.prewarm_boots = 0
        self.warm_hits = 0
        self.prewarm_hits = 0
        self.evictions = 0
        self._finalized_seconds = 0.0
        # DScale autoscaler target: None = TTL-only (classic keep-alive).
        # When set it pins the pool from both sides: sweep() reclaims
        # idle containers beyond it *before* their TTL expires (the
        # container-seconds win) but never TTL-evicts below it, and
        # set_target() boots up to it ahead of demand.
        self.target: int | None = None

    # -- derived state -----------------------------------------------------
    @property
    def boots(self) -> int:
        return self.cold_starts + self.prewarm_boots

    def idle_count(self, now: float) -> int:
        """Containers ready and idle at ``now`` (classic "warm count")."""
        return sum(1 for c in self._containers
                   if not c.busy and c.ready_at <= now)

    def available(self, now: float) -> int:
        """Idle containers including ones still booting (joinable)."""
        del now
        return sum(1 for c in self._containers if not c.busy)

    def live(self) -> int:
        return len(self._containers)

    def container_seconds(self, now: float) -> float:
        """Aggregate occupancy: evicted containers' lifetimes plus the age
        of every container still alive at ``now``."""
        return self._finalized_seconds + sum(
            max(now, c.boot_at) - c.boot_at for c in self._containers)

    # -- lifecycle ---------------------------------------------------------
    def sweep(self, now: float, *, enforce_target: bool = True) -> int:
        """Evict idle containers whose keep-alive TTL expired, then — when
        an autoscaler :attr:`target` is set — reclaim idle containers
        beyond the target immediately (LRU first, busy never).  The
        target is a two-sided pin: TTL expiry never shrinks the pool
        below it either (the autoscaler's floor outranks keep-alive, or
        a lull longer than the TTL would silently drain a pool the
        control loop believes is provisioned).  Returns how many were
        evicted (the simulator releases capacity per eviction)."""
        evicted = 0
        floor = self.target if self.target is not None else 0
        expired = sorted(
            (c for c in self._containers
             if not c.busy
             and max(c.idle_since, c.ready_at) + self.keepalive <= now),
            key=lambda c: c.idle_since)
        for c in expired:
            if len(self._containers) <= floor:
                break
            expires = max(c.idle_since, c.ready_at) + self.keepalive
            self._containers.remove(c)
            self._finalized_seconds += expires - c.boot_at
            self.evictions += 1
            evicted += 1
        if enforce_target and self.target is not None:
            idle = sorted((c for c in self._containers if not c.busy),
                          key=lambda c: c.idle_since)
            for c in idle:
                if len(self._containers) <= self.target:
                    break
                self._containers.remove(c)
                self._finalized_seconds += max(now, c.boot_at) - c.boot_at
                self.evictions += 1
                evicted += 1
        return evicted

    def try_acquire_warm(self, now: float) -> Lease | None:
        """Lease an existing container: delay 0.0 for a ready idle one,
        the residual boot delay for one still booting, None if a cold boot
        is required.  Marks the chosen container busy and returns the
        :class:`Lease` token identifying it (pass it back to
        :meth:`release`)."""
        # TTL-expired containers must not be reused, but an over-target
        # pool still prefers serving the request in hand over evicting —
        # it shrinks on the next release/set_target sweep instead.
        self.sweep(now, enforce_target=False)
        ready = [c for c in self._containers
                 if not c.busy and c.ready_at <= now]
        if ready:
            # MRU reuse keeps the rest of the fleet evictable by TTL.
            c = max(ready, key=lambda c: c.idle_since)
            c.busy = True
            self.warm_hits += 1
            return Lease(container=c, delay=0.0, cold=False)
        booting = [c for c in self._containers if not c.busy]
        if booting:
            c = min(booting, key=lambda c: c.ready_at)
            c.busy = True
            self.prewarm_hits += 1
            return Lease(container=c, delay=c.ready_at - now, cold=False)
        return None

    def acquire(self, now: float) -> Lease:
        """Lease a container; the returned token carries the delay until
        it is ready and whether a request-path cold boot was paid."""
        lease = self.try_acquire_warm(now)
        if lease is not None:
            return lease
        c = _Container(boot_at=now, ready_at=now + self.cold_start,
                       busy=True, idle_since=now)
        self._containers.append(c)
        self.cold_starts += 1
        return Lease(container=c, delay=self.cold_start, cold=True)

    def release(self, lease: Lease, now: float) -> None:
        """Return the leased container to the idle (warm) set.  Tolerates
        the container having been retired underneath the lease (pool
        shutdown / node failure) — its seconds were finalized then."""
        if lease.released:
            raise RuntimeError(
                f"pool {self.image!r}: lease released twice")
        lease.released = True
        c = lease.container
        if c not in self._containers:
            return                     # retired by shutdown()/node failure
        if not c.busy:
            raise RuntimeError(f"pool {self.image!r}: lease not busy")
        c.busy = False
        c.idle_since = max(now, c.ready_at)
        self.sweep(now)

    def set_target(self, target: int | None, now: float) -> tuple[int, int]:
        """Autoscaler hook: pin the pool's live-container target.  Boots
        up to the target immediately (counted as prewarm boots — they are
        proactive boots ahead of demand) and reclaims idle containers
        beyond it ahead of their TTL.  Returns ``(booted, evicted)``."""
        self.target = None if target is None else max(0, int(target))
        booted = 0
        while self.target is not None and self.live() < self.target:
            self._containers.append(
                _Container(boot_at=now, ready_at=now + self.cold_start,
                           busy=False, idle_since=now + self.cold_start))
            self.prewarm_boots += 1
            booted += 1
        evicted = self.sweep(now)
        return booted, evicted

    def prewarm(self, now: float) -> float:
        """Start booting one container ahead of need (paper §3.2 prewarm
        trigger: called when the function's *precursor launches*).  No-op if
        an idle or booting container already exists.  Returns the delay
        until an idle container will be ready."""
        self.sweep(now, enforce_target=False)
        idle = [c for c in self._containers if not c.busy]
        if idle:
            return max(0.0, min(c.ready_at for c in idle) - now)
        self._containers.append(
            _Container(boot_at=now, ready_at=now + self.cold_start,
                       busy=False, idle_since=now + self.cold_start))
        self.prewarm_boots += 1
        return self.cold_start

    def shutdown(self, now: float) -> float:
        """Retire every container; returns total container-seconds."""
        for c in self._containers:
            self._finalized_seconds += max(now, c.boot_at) - c.boot_at
        self._containers = []
        return self._finalized_seconds


# ----------------------------------------------------------------------
# Threaded adapter (wall clock) used by DFlowEngine / DServe
# ----------------------------------------------------------------------

class ContainerService:
    """Wall-clock container service: per-(node, image) pools + per-node
    bounded execution slots.

    ``acquire`` blocks the calling function thread for the boot delay (cold
    or residual prewarm); booting needs no background thread because
    readiness is purely a timestamp in the shared lifecycle model.
    ``slot(node)`` bounds how many functions *execute* concurrently per
    node (the cores cap); container acquisition is deliberately outside the
    slot so launched-but-blocked dataflow functions cannot deadlock the
    executing ones.
    """

    def __init__(self, nodes: Sequence[str], *, keepalive: float = 600.0,
                 max_per_node: int = 8, cold_start: float | None = None,
                 clock: Callable[[], float] = time.monotonic,
                 sleep: Callable[[float], None] = time.sleep):
        self.nodes = list(nodes)
        self.keepalive = float(keepalive)
        self.cold_start_override = cold_start
        self._clock = clock
        self._sleep = sleep
        self._lock = threading.Lock()
        self._pools: dict[tuple[str, str], ContainerPool] = {}
        self._slots = {n: threading.Semaphore(int(max_per_node))
                       for n in self.nodes}
        # Lifecycle guards for DScale: prewarms (including ones armed on
        # threading.Timers by the scheduler) must become no-ops once the
        # service shut down or the node died.
        self.closed = False
        self._failed_nodes: set[str] = set()
        # DCheck hook: container lifecycle events land in the same trace
        # as data-plane events, so PlanConformance can judge whether a
        # cold boot was avoidable (an unleased container existed).
        self._tracer = None

    def attach_tracer(self, tracer) -> None:
        self._tracer = tracer

    def register_metrics(self, registry) -> None:
        """DScope pull collector: per-(node, image) lifecycle counters
        scraped at ``registry.collect()`` time — zero hot-path cost."""
        def _scrape() -> None:
            with self._lock:
                now = self._clock()
                rows = [(node, image, p.cold_starts, p.prewarm_boots,
                         p.warm_hits, p.prewarm_hits, p.evictions,
                         p.container_seconds(now), p.live())
                        for (node, image), p in self._pools.items()]
            for (node, image, cold, boots, warm, pwh, ev, secs,
                 live) in rows:
                labels = dict(node=node, image=image)
                registry.counter("container_cold_starts",
                                 **labels).set(cold)
                registry.counter("container_prewarm_boots",
                                 **labels).set(boots)
                registry.counter("container_warm_hits", **labels).set(warm)
                registry.counter("container_prewarm_hits",
                                 **labels).set(pwh)
                registry.counter("container_evictions", **labels).set(ev)
                registry.gauge("container_seconds", **labels).set(secs)
                registry.gauge("containers_live", **labels).set(live)
        registry.register_collector(_scrape)

    def _pool_events(self, p: ContainerPool, pre: tuple[int, int, int, int],
                     node: str, image: str, *, cold: bool | None = None,
                     released: bool = False) -> None:
        # Called with self._lock held, right after a pool transition;
        # translates counter deltas into trace events (key = image).
        tr = self._tracer
        warm0, pw0, ev0, pb0 = pre
        if released:
            tr.record("container_release", image, node)
        for _ in range(p.evictions - ev0):
            tr.record("container_evict", image, node)
        for _ in range(p.prewarm_boots - pb0):
            tr.record("prewarm_boot", image, node)
        if cold is True:
            tr.record("cold_boot", image, node)
        elif cold is False:
            if p.warm_hits > warm0:
                tr.record("warm_hit", image, node)
            elif p.prewarm_hits > pw0:
                tr.record("prewarm_hit", image, node)

    def pool(self, node: str, image: str,
             cold_start: float = 0.5) -> ContainerPool:
        if self.cold_start_override is not None:
            cold_start = self.cold_start_override
        p = self._pools.get((node, image))
        if p is None:
            p = self._pools[(node, image)] = ContainerPool(
                image, cold_start=cold_start, keepalive=self.keepalive)
        return p

    def acquire(self, node: str, image: str,
                cold_start: float = 0.5) -> Lease:
        """Lease a container, sleeping out its boot delay; the returned
        :class:`Lease` records whether a full cold start was paid and must
        be handed back to :meth:`release`."""
        with self._lock:
            p = self.pool(node, image, cold_start)
            pre = (p.warm_hits, p.prewarm_hits, p.evictions, p.prewarm_boots)
            lease = p.acquire(self._clock())
            if self._tracer is not None:
                self._pool_events(p, pre, node, image, cold=lease.cold)
        if lease.delay > 0:
            self._sleep(lease.delay)
        return lease

    def release(self, node: str, image: str, lease: Lease) -> None:
        with self._lock:
            p = self._pools.get((node, image))
            if p is None:
                # Node failed / service shut down underneath the lease;
                # its container-seconds were finalized then.
                lease.released = True
                return
            pre = (p.warm_hits, p.prewarm_hits, p.evictions, p.prewarm_boots)
            p.release(lease, self._clock())
            if self._tracer is not None:
                self._pool_events(p, pre, node, image, released=True)

    def prewarm(self, node: str, image: str,
                cold_start: float = 0.5) -> bool:
        """Dataflow-triggered prewarm (§3.2): begin booting the function's
        container the moment its precursor launches.  Returns immediately
        — readiness is a timestamp, not a thread — with whether a boot
        actually started (False: an idle/booting container already
        existed, or the service/node is gone, so a prewarm budget should
        be refunded)."""
        with self._lock:
            if self.closed or node in self._failed_nodes:
                return False
            p = self.pool(node, image, cold_start)
            pre = (p.warm_hits, p.prewarm_hits, p.evictions, p.prewarm_boots)
            p.prewarm(self._clock())
            booted = p.prewarm_boots > pre[3]
            if self._tracer is not None:
                self._pool_events(p, pre, node, image)
        return booted

    def set_target(self, node: str, image: str, target: int | None,
                   cold_start: float = 0.5) -> tuple[int, int]:
        """DScale autoscaler hook: pin one pool's live-container target
        (boot up to it, reclaim idle beyond it ahead of TTL)."""
        with self._lock:
            if self.closed or node in self._failed_nodes:
                return (0, 0)
            p = self.pool(node, image, cold_start)
            pre = (p.warm_hits, p.prewarm_hits, p.evictions, p.prewarm_boots)
            out = p.set_target(target, self._clock())
            if self._tracer is not None:
                self._pool_events(p, pre, node, image)
        return out

    def fail_node(self, node: str) -> None:
        """Node death: retire the node's pools (finalizing their
        container-seconds); later prewarms/scale decisions for it no-op
        and in-flight releases become tolerated no-ops."""
        with self._lock:
            self._failed_nodes.add(node)
            now = self._clock()
            for (n, image), p in list(self._pools.items()):
                if n == node:
                    p.shutdown(now)

    def shutdown(self) -> float:
        """Retire every pool; returns total container-seconds.  Armed
        prewarm timers that fire afterwards are no-ops."""
        with self._lock:
            self.closed = True
            now = self._clock()
            return sum(p.shutdown(now) for p in self._pools.values())

    @contextmanager
    def slot(self, node: str):
        """Bounded per-node execution slot (acquired only for fn runtime)."""
        self._slots[node].acquire()
        try:
            yield
        finally:
            self._slots[node].release()

    # -- aggregate metrics -------------------------------------------------
    def _total(self, attr: str) -> int:
        with self._lock:
            return sum(getattr(p, attr) for p in self._pools.values())

    @property
    def cold_starts(self) -> int:
        return self._total("cold_starts")

    @property
    def prewarm_boots(self) -> int:
        return self._total("prewarm_boots")

    @property
    def warm_hits(self) -> int:
        return self._total("warm_hits")

    @property
    def prewarm_hits(self) -> int:
        return self._total("prewarm_hits")

    @property
    def evictions(self) -> int:
        return self._total("evictions")

    def container_seconds(self) -> float:
        with self._lock:
            now = self._clock()
            return sum(p.container_seconds(now)
                       for p in self._pools.values())


# ----------------------------------------------------------------------
# Open-loop arrival processes
# ----------------------------------------------------------------------

def poisson_arrivals(rate_per_s: float, n: int,
                     seed: int = 0) -> list[float]:
    """Deterministic Poisson process: ``n`` arrival times (seconds from
    t=0) with exponential inter-arrival gaps of mean ``1/rate`` drawn from
    a seeded LCG (no global RNG — every experiment is reproducible)."""
    if rate_per_s <= 0:
        raise ValueError("rate_per_s must be positive")
    s = (seed * 2654435761 + 0x9E3779B9) & 0xFFFFFFFF
    t, out = 0.0, []
    for _ in range(n):
        s = (1103515245 * s + 12345) & 0x7FFFFFFF
        u = (s + 1) / (0x7FFFFFFF + 2)          # u in (0, 1)
        t += -math.log(u) / rate_per_s
        out.append(t)
    return out


def trace_arrivals(times: Iterable[float]) -> list[float]:
    """Trace-driven arrivals: validate + sort a recorded timestamp list.

    NaN/inf are rejected, not just negatives: NaN sorts unpredictably
    (it silently corrupts the schedule ordering) and inf wedges the
    open-loop arrival sleep forever.
    """
    out = []
    for t in times:
        f = float(t)
        if not math.isfinite(f):
            raise ValueError(f"trace timestamps must be finite, got {f!r}")
        if f < 0:
            raise ValueError("trace timestamps must be >= 0")
        out.append(f)
    out.sort()
    return out


# ----------------------------------------------------------------------
# Serving layer
# ----------------------------------------------------------------------

@dataclass
class InstanceStat:
    instance: str
    arrival: float                   # seconds from serve start
    latency: float = math.nan        # end-to-end (admission -> all done)
    ok: bool = False
    error: str = ""
    reexecuted: int = 0
    outputs: dict = field(default_factory=dict)   # sink outputs (response)
    queue_wait: float = 0.0          # admission-queue wait (DScale)
    shed: bool = False               # rejected: queue full (backpressure)


def percentile(values: list[float], q: float) -> float:
    """Linear-interpolated percentile (q in [0,100]).  The project's one
    implementation — ``repro.core.experiments`` re-exports it."""
    if not 0.0 <= q <= 100.0:       # also rejects NaN (comparison False)
        raise ValueError(f"percentile q must be in [0, 100], got {q!r}")
    if not values:
        return math.nan
    v = sorted(values)
    pos = (len(v) - 1) * q / 100.0
    lo = int(math.floor(pos))
    hi = min(lo + 1, len(v) - 1)
    frac = pos - lo
    return v[lo] * (1 - frac) + v[hi] * frac


@dataclass
class ServeReport:
    """Aggregate of one open-loop serving run (consumed by
    ``benchmarks/serve_load.py`` and ``benchmarks/fig12_coldstart.py``)."""

    workflow: str
    pattern: str
    stats: list[InstanceStat] = field(default_factory=list)
    wall_time: float = 0.0
    max_concurrency: int = 0
    cold_starts: int = 0             # request-path cold boots
    prewarm_boots: int = 0
    warm_hits: int = 0
    prewarm_hits: int = 0
    evictions: int = 0
    container_seconds: float = 0.0
    # Max over per-node DStore high-water marks: a node provisions for its
    # OWN peak, and under DShard the stores really are per-node shards —
    # summing them (the old definition) overstated the capacity a node
    # needs and was incomparable to DPlan's per-node peak_resident.
    peak_resident_bytes: int = 0
    peak_resident_per_node: dict = field(default_factory=dict)
    # DScale admission control (derived from registry deltas like the
    # container counters above).
    queued: int = 0                  # requests that waited in admission
    shed: int = 0                    # requests rejected (queue full)

    @property
    def latencies(self) -> list[float]:
        return [s.latency for s in self.stats if s.ok]

    @property
    def failures(self) -> int:
        return sum(1 for s in self.stats if not s.ok and not s.shed)

    @property
    def queue_waits(self) -> list[float]:
        return [s.queue_wait for s in self.stats if s.queue_wait > 0]

    @property
    def queue_wait_p95(self) -> float:
        return percentile(self.queue_waits, 95.0) if self.queue_waits \
            else 0.0

    @property
    def p50(self) -> float:
        return percentile(self.latencies, 50.0)

    @property
    def p95(self) -> float:
        return percentile(self.latencies, 95.0)

    @property
    def p99(self) -> float:
        return percentile(self.latencies, 99.0)

    def row(self) -> dict:
        return {
            "workflow": self.workflow, "pattern": self.pattern,
            "n": len(self.stats), "failures": self.failures,
            "p50_s": round(self.p50, 4), "p95_s": round(self.p95, 4),
            "p99_s": round(self.p99, 4),
            "max_concurrency": self.max_concurrency,
            "cold_starts": self.cold_starts,
            "prewarm_boots": self.prewarm_boots,
            "warm_hits": self.warm_hits,
            "prewarm_hits": self.prewarm_hits,
            "container_seconds": round(self.container_seconds, 3),
            "peak_resident_bytes": self.peak_resident_bytes,
            "queued": self.queued, "shed": self.shed,
            "queue_wait_p95_s": round(self.queue_wait_p95, 4),
        }


class DServe:
    """Open-loop serving of one workflow: N concurrent instances through a
    shared :class:`~repro.core.dscheduler.DFlowEngine`, one shared DStore
    (per-instance key namespacing), and one :class:`ContainerService`.

    ``prewarm`` toggles the §3.2 dataflow-triggered prewarm of successor
    containers at precursor launch.  It is strictly a dataflow-pattern
    mechanism — the engine ignores it under ``pattern="controlflow"``,
    whose baseline semantics boot a container only when a function becomes
    ready (the §5.5 ablation).

    ``plan`` switches instances to DPlan-driven execution: ``True`` builds
    a :func:`repro.core.plan.build_plan` from this serve's placement; a
    prebuilt :class:`~repro.core.plan.WorkflowPlan` is used as-is.  Keys
    are then evicted the moment their statically-last read returns
    (instead of at instance completion) and container boots follow the
    slack schedule instead of the precursor-launch heuristic.

    ``sharded`` serves over a :class:`~repro.core.router.ShardedDStore`
    (DShard): per-node directory shards, local routing tables and 1-hop
    transfers — byte-identical results, no central metadata hotspot.

    DScope (obs.py): every DServe owns a :class:`MetricsRegistry` wired
    with pull collectors (containers, store, routing) — ``ServeReport``
    is built from it, and ``self.metrics.collect()`` dumps every counter
    from one source.  Passing your own ``metrics`` registry additionally
    enables the push-side hot-path histograms (per-Get / per-chunk
    latency); passing a ``spans`` :class:`~repro.core.obs.Tracer` records
    per-request span trees (request → invoke → acquire → Get/Put → chunk
    → hop).  Both default to off-path: a plain DServe pays nothing.
    """

    def __init__(self, wf, *, n_nodes: int = 2, pattern: str = "dataflow",
                 prewarm: bool | None = None, keepalive: float = 600.0,
                 max_per_node: int = 8, cold_start: float | None = None,
                 transport=None, get_timeout: float = 30.0,
                 evict_on_complete: bool = True, tracer=None,
                 lint: bool = True, plan=None, sharded: bool = False,
                 metrics=None, spans=None, max_inflight: int | None = None,
                 queue_depth: int | None = None, autoscale=None,
                 prewarm_budget=None):
        from .dscheduler import DFlowEngine
        from .dstore import DStore
        from .router import ShardedDStore

        if lint:
            # Lint once at serve-construction time (the request path
            # builds InstanceRuns directly and must stay lean).
            from .lint import check_workflow

            check_workflow(wf, require_fns=True)
        self.wf = wf
        self.pattern = pattern
        if prewarm is None:
            prewarm = pattern == "dataflow"
        self.containers = ContainerService(
            [f"node{i}" for i in range(n_nodes)], keepalive=keepalive,
            max_per_node=max_per_node, cold_start=cold_start)
        self.engine = DFlowEngine(n_nodes=n_nodes, pattern=pattern,
                                  transport=transport,
                                  get_timeout=get_timeout,
                                  containers=self.containers,
                                  prewarm=prewarm)
        self.sharded = sharded
        store_cls = ShardedDStore if sharded else DStore
        self.store = store_cls(self.engine.nodes, self.engine.transport)
        if tracer is not None:
            self.store.attach_tracer(tracer)
            self.containers.attach_tracer(tracer)
        # DScope wiring: pull collectors always (they cost nothing until
        # collect()); the hot-path push hooks only when the caller brought
        # a registry of their own.
        self.spans = spans
        self.metrics = metrics if metrics is not None else MetricsRegistry()
        self.containers.register_metrics(self.metrics)
        if metrics is not None:
            self.store.attach_metrics(self.metrics)
        else:
            self.store.register_metrics(self.metrics)
        if spans is not None:
            self.store.attach_spans(spans)
        self.placement = self.engine.gs.assign(wf)
        if plan is True:
            from .plan import build_plan

            plan = build_plan(wf, self.placement)
        self.plan = plan if plan is not False else None
        self.evict_on_complete = evict_on_complete
        self._lock = threading.Lock()
        self._active: dict[str, Any] = {}      # instance -> InstanceRun
        self.max_concurrency = 0
        # -- DScale (scale.py) ------------------------------------------
        # Admission control: at most max_inflight instances run at once;
        # excess arrivals wait in a bounded FIFO (queue_depth; None =
        # unbounded) and overflow is shed.  None/None = classic unbounded
        # admission (behavior unchanged).
        if max_inflight is not None and max_inflight < 1:
            raise ValueError("max_inflight must be >= 1")
        if queue_depth is not None and queue_depth < 0:
            raise ValueError("queue_depth must be >= 0")
        self.max_inflight = max_inflight
        self.queue_depth = queue_depth
        from .scale import (AutoscalerConfig, PoolAutoscaler, PoolSpec,
                            PrewarmBudget)

        if isinstance(prewarm_budget, (int, float)):
            prewarm_budget = PrewarmBudget(float(prewarm_budget))
        self.prewarm_budget = prewarm_budget
        self.autoscaler = None
        if autoscale:
            cfg = autoscale if isinstance(autoscale, AutoscalerConfig) \
                else AutoscalerConfig()
            specs = [PoolSpec(node=self.placement[f],
                              image=f"{wf.name}/{f}",
                              service_time=fn.exec_time,
                              cold_start=fn.cold_start)
                     for f, fn in wf.functions.items()]
            self.autoscaler = PoolAutoscaler(
                self.metrics, specs, cfg=cfg, spans=self.spans,
                apply=self.containers.set_target,
                arrivals_labels=dict(workflow=wf.name, pattern=pattern))

    # ------------------------------------------------------------------
    def fail_node(self, node: str) -> list[str]:
        """Kill a node: every active instance incrementally recovers the
        functions whose outputs it lost (its own namespace only)."""
        lost = self.store.fail_node(node)
        with self._lock:
            active = list(self._active.values())
        for run in active:
            run.recover(lost)
        return lost

    # ------------------------------------------------------------------
    def run(self, arrivals: Sequence[float],
            inputs: Mapping[str, Any] | Callable[[int], Mapping[str, Any]]
            | None = None, *,
            fail_node_at: tuple[float, str] | None = None) -> ServeReport:
        """Drive one open-loop run: instance ``i`` starts at
        ``arrivals[i]`` seconds (wall clock) after the run begins.

        ``inputs`` may be a static mapping (shared by every instance) or a
        callable ``i -> mapping`` for per-instance payloads.
        ``fail_node_at=(t, node)`` kills ``node`` ``t`` seconds into the
        run (per-instance incremental recovery keeps instances alive).
        """
        arrivals = sorted(float(a) for a in arrivals)
        report = ServeReport(workflow=self.wf.name, pattern=self.pattern)
        stats = [InstanceStat(instance=f"{self.wf.name}#{i}", arrival=a)
                 for i, a in enumerate(arrivals)]
        report.stats = stats
        # Snapshot the registry so the report covers THIS run only (the
        # service — and its warm containers — outlives runs).  One source:
        # the same collectors back the registry dump and this report.
        reg = self.metrics
        reg.collect()
        base = {name: reg.total(name) for name in _SERVE_BASE_METRICS}
        self.max_concurrency = 0             # per-run high-water mark
        self.store.reset_peak()              # per-run resident high-water
        t0 = time.monotonic()
        threads: list[threading.Thread] = []

        killer = None
        if fail_node_at is not None:
            t_fail, node = fail_node_at

            def kill():
                delay = t_fail - (time.monotonic() - t0)
                if delay > 0:
                    time.sleep(delay)
                self.fail_node(node)
            killer = threading.Thread(target=kill, daemon=True,
                                      name="dserve-failure")
            killer.start()

        labels = dict(workflow=self.wf.name, pattern=self.pattern)
        # Admission state (DScale): bounded concurrency + FIFO overflow
        # queue.  All transitions happen under self._lock; `outstanding`
        # counts stats not yet resolved (finished or shed) so the waiter
        # below survives launches that happen from finish threads.
        from collections import deque
        admission_queue: deque = deque()
        inflight = [0]
        outstanding = [len(stats)]
        all_done = threading.Event()
        if not stats:
            all_done.set()

        def resolve_one() -> None:
            with self._lock:
                outstanding[0] -= 1
                if outstanding[0] <= 0:
                    all_done.set()

        def finish(stat: InstanceStat, run) -> None:
            try:
                rep = run.wait()
                stat.latency = rep.wall_time + stat.queue_wait
                stat.reexecuted = len(rep.reexecuted)
                stat.outputs = rep.outputs
                stat.ok = True
            except BaseException as exc:        # noqa: BLE001 - recorded
                stat.error = f"{type(exc).__name__}: {exc}"
            finally:
                with self._lock:
                    self._active.pop(stat.instance, None)
                if self.evict_on_complete:
                    self.store.evict_instance(f"{stat.instance}:")
                resolve_one()
                self._admit_next(admission_queue, inflight, launch, reg,
                                 labels)

        from .dscheduler import InstanceRun

        def launch(i: int, stat: InstanceStat) -> None:
            payload = inputs(i) if callable(inputs) else inputs
            run = InstanceRun(self.engine, self.wf, payload,
                              store=self.store, instance=stat.instance,
                              placement=self.placement, plan=self.plan,
                              spans=self.spans,
                              budget=self.prewarm_budget)
            # Register BEFORE starting: a node failure racing the start
            # must already see this instance to hand it its lost keys.
            with self._lock:
                self._active[stat.instance] = run
                self.max_concurrency = max(self.max_concurrency,
                                           len(self._active))
            run.start()
            th = threading.Thread(target=finish, args=(stat, run),
                                  daemon=True,
                                  name=f"dserve-{stat.instance}")
            th.start()
            threads.append(th)

        scaler_stop = self._start_autoscaler(t0)
        try:
            for i, stat in enumerate(stats):
                delay = stat.arrival - (time.monotonic() - t0)
                if delay > 0:
                    time.sleep(delay)
                reg.counter("serve_arrivals_total", **labels).inc()
                with self._lock:
                    if self.max_inflight is None \
                            or inflight[0] < self.max_inflight:
                        inflight[0] += 1
                        admit = "run"
                    elif self.queue_depth is None \
                            or len(admission_queue) < self.queue_depth:
                        admission_queue.append(
                            (i, stat, time.monotonic()))
                        admit = "queue"
                    else:
                        admit = "shed"
                if admit == "run":
                    launch(i, stat)
                elif admit == "queue":
                    reg.counter("serve_queued_total", **labels).inc()
                else:
                    stat.shed = True
                    stat.error = "shed: admission queue full"
                    reg.counter("serve_shed_total", **labels).inc()
                    resolve_one()

            all_done.wait(self.engine.get_timeout * 2)
            for th in list(threads):
                th.join(self.engine.get_timeout * 2)
        finally:
            if scaler_stop is not None:
                scaler_stop.set()
        if killer is not None:
            killer.join(1.0)
        report.wall_time = time.monotonic() - t0
        report.max_concurrency = self.max_concurrency
        reg.collect()

        def _delta(name: str) -> float:
            return reg.total(name) - base[name]

        report.cold_starts = int(_delta("container_cold_starts"))
        report.prewarm_boots = int(_delta("container_prewarm_boots"))
        report.warm_hits = int(_delta("container_warm_hits"))
        report.prewarm_hits = int(_delta("container_prewarm_hits"))
        report.evictions = int(_delta("container_evictions"))
        report.container_seconds = _delta("container_seconds")
        report.queued = int(_delta("serve_queued_total"))
        report.shed = int(_delta("serve_shed_total"))
        per_node = {n: int(v) for n, v in reg.label_values(
            "dstore_peak_resident_bytes", "node").items()}
        report.peak_resident_per_node = per_node
        report.peak_resident_bytes = max(per_node.values(), default=0)
        self._publish_run_metrics(report)
        return report

    # ------------------------------------------------------------------
    def _admit_next(self, queue, inflight, launch, reg, labels) -> None:
        """A finished instance hands its admission slot to the oldest
        queued arrival (FIFO); with an empty queue the slot is freed."""
        with self._lock:
            if not queue:
                inflight[0] -= 1
                return
            i, stat, enq = queue.popleft()
        wait = time.monotonic() - enq
        stat.queue_wait = wait
        reg.histogram("serve_queue_wait_seconds", **labels).observe(wait)
        launch(i, stat)

    def _start_autoscaler(self, t0: float):
        """Run the DScale control loop for the duration of one run: every
        ``cfg.interval`` seconds the autoscaler reads registry rates and
        re-targets the container pools.  Returns the stop event (None when
        autoscaling is off)."""
        del t0  # the autoscaler shares the service's monotonic clock
        if self.autoscaler is None:
            return None
        stop = threading.Event()
        interval = self.autoscaler.cfg.interval

        def loop() -> None:
            while not stop.wait(interval):
                self.autoscaler.step(time.monotonic())

        threading.Thread(target=loop, daemon=True,
                         name="dscale-autoscaler").start()
        return stop

    def _publish_run_metrics(self, report: ServeReport) -> None:
        """Run-level serving metrics into the registry (latency histogram,
        request/failure totals, concurrency) so autoscaling and bench
        emitters can read rates and tails from the same source."""
        reg = self.metrics
        labels = dict(workflow=report.workflow, pattern=report.pattern)
        h = reg.histogram("serve_latency_seconds", **labels)
        for lat in report.latencies:
            h.observe(lat)
        reg.counter("serve_requests_total", **labels).inc(len(report.stats))
        reg.counter("serve_failures_total", **labels).inc(report.failures)
        reg.gauge("serve_max_concurrency",
                  **labels).set(report.max_concurrency)
        if report.latencies:
            reg.gauge("serve_p50_seconds", **labels).set(report.p50)
            reg.gauge("serve_p95_seconds", **labels).set(report.p95)
            reg.gauge("serve_p99_seconds", **labels).set(report.p99)
