"""Deterministic discrete-event simulation kernel.

A minimal SimPy-like engine: processes are Python generators that ``yield``
:class:`Event` objects and are resumed when the event triggers.  Determinism
is total — the event heap is ordered by (time, sequence) and no wall-clock or
RNG state is consulted — so every paper-figure experiment is exactly
reproducible.

Also provides the two resource models the cluster simulation needs:

* :class:`Resource` — counted semaphore (CPU cores, container slots).
* :class:`Network`  — node-uplink/downlink constrained flows with **max-min
  fair sharing**, the standard fluid model for TCP-like bandwidth division.
  This is what lets the simulator reproduce DFlow's receiver-driven
  bandwidth-utilisation results: when CFlow funnels every transfer through
  the master node, the master's links saturate and per-flow rates collapse;
  DFlow's node-to-node pulls spread across all links.
"""

from __future__ import annotations

import heapq
import math
from typing import Any, Callable, Generator, Iterable

__all__ = ["Env", "Event", "Process", "Resource", "Network", "all_of"]


class Event:
    """One-shot event; processes wait on it, ``trigger`` resumes them."""

    __slots__ = ("env", "triggered", "value", "_waiters")

    def __init__(self, env: "Env"):
        self.env = env
        self.triggered = False
        self.value: Any = None
        self._waiters: list[Callable[[Any], None]] = []

    def trigger(self, value: Any = None) -> None:
        if self.triggered:
            raise RuntimeError("event triggered twice")
        self.triggered = True
        self.value = value
        waiters, self._waiters = self._waiters, []
        for cb in waiters:
            self.env._immediate(cb, value)

    def add_waiter(self, cb: Callable[[Any], None]) -> None:
        if self.triggered:
            self.env._immediate(cb, self.value)
        else:
            self._waiters.append(cb)


class Process(Event):
    """A running generator; is itself an Event that triggers on return."""

    __slots__ = ("gen",)

    def __init__(self, env: "Env", gen: Generator):
        super().__init__(env)
        self.gen = gen
        env._immediate(self._step, None)

    def _step(self, value: Any) -> None:
        try:
            ev = self.gen.send(value)
        except StopIteration as stop:
            self.trigger(stop.value)
            return
        if not isinstance(ev, Event):
            raise TypeError(f"process yielded non-Event {ev!r}")
        ev.add_waiter(self._step)


class Env:
    """Event loop with a virtual clock."""

    def __init__(self) -> None:
        self.now = 0.0
        self._heap: list[tuple[float, int, Callable, Any]] = []
        self._seq = 0

    # -- scheduling -----------------------------------------------------
    def _at(self, t: float, cb: Callable, value: Any = None) -> None:
        self._seq += 1
        heapq.heappush(self._heap, (t, self._seq, cb, value))

    def _immediate(self, cb: Callable, value: Any = None) -> None:
        self._at(self.now, cb, value)

    def timeout(self, delay: float, value: Any = None) -> Event:
        if delay < 0:
            raise ValueError(f"negative delay {delay}")
        ev = Event(self)
        self._at(self.now + delay, ev.trigger, value)
        return ev

    def event(self) -> Event:
        return Event(self)

    def process(self, gen: Generator) -> Process:
        return Process(self, gen)

    # -- run ------------------------------------------------------------
    def run(self, until: float | None = None) -> None:
        while self._heap:
            t, _, cb, value = self._heap[0]
            if until is not None and t > until:
                self.now = until
                return
            heapq.heappop(self._heap)
            self.now = t
            cb(value)
        if until is not None:
            self.now = until


def all_of(env: Env, events: Iterable[Event]) -> Event:
    """Event that triggers when every input event has triggered."""
    events = list(events)
    done = env.event()
    remaining = len(events)
    if remaining == 0:
        env._immediate(done.trigger, [])
        return done
    values: list[Any] = [None] * remaining

    def mk(i: int):
        def cb(v: Any) -> None:
            nonlocal remaining
            values[i] = v
            remaining -= 1
            if remaining == 0:
                done.trigger(values)
        return cb

    for i, ev in enumerate(events):
        ev.add_waiter(mk(i))
    return done


class Resource:
    """Counted resource (e.g. CPU cores).  FIFO grant order."""

    def __init__(self, env: Env, capacity: int):
        self.env = env
        self.capacity = capacity
        self.in_use = 0
        self._queue: list[Event] = []

    def acquire(self) -> Event:
        ev = self.env.event()
        if self.in_use < self.capacity:
            self.in_use += 1
            self.env._immediate(ev.trigger, None)
        else:
            self._queue.append(ev)
        return ev

    def release(self) -> None:
        if self._queue:
            ev = self._queue.pop(0)
            self.env._immediate(ev.trigger, None)
        else:
            self.in_use -= 1
            if self.in_use < 0:
                raise RuntimeError("release without acquire")

    @property
    def queued(self) -> int:
        return len(self._queue)


class _Flow:
    __slots__ = ("src", "dst", "size", "remaining", "rate", "done", "tag")

    def __init__(self, src: str, dst: str, size: float, done: Event, tag: str):
        self.src = src
        self.dst = dst
        self.size = float(size)
        self.remaining = float(size)
        self.rate = 0.0
        self.done = done
        self.tag = tag


class Network:
    """Max-min fair fluid network over per-node uplink/downlink capacities.

    ``transfer(src, dst, size)`` returns an Event triggered when the last
    byte arrives.  All concurrent flows continuously share bandwidth under
    max-min fairness (waterfilling over the 2·N link capacities); rates are
    re-solved whenever a flow starts or finishes.  A transfer log
    ``(src, dst, bytes, t_start, t_end, tag)`` feeds the bandwidth-
    utilisation metric (paper Fig. 9/10 discussion).
    """

    def __init__(self, env: Env, uplink: dict[str, float],
                 downlink: dict[str, float], latency: float = 0.0005):
        self.env = env
        self.uplink = dict(uplink)
        self.downlink = dict(downlink)
        self.latency = latency
        self._flows: list[_Flow] = []
        self._last_update = 0.0
        self._timer_version = 0
        self.log: list[tuple[str, str, float, float, float, str]] = []
        self._starts: dict[int, float] = {}
        # Union of intervals with >=1 active flow: the denominator of the
        # achieved-bandwidth metric (bytes moved / time spent moving them).
        self.busy_time = 0.0
        self._busy_since: float | None = None

    # -- public ----------------------------------------------------------
    def transfer(self, src: str, dst: str, size: float, tag: str = "") -> Event:
        done = self.env.event()
        if src == dst or size <= 0:
            self.env._immediate(done.trigger, None)
            return done
        flow = _Flow(src, dst, size, done, tag)
        # Wire latency before the flow joins the fluid model.
        def start(_):
            self._advance()
            if not self._flows:
                self._busy_since = self.env.now
            self._flows.append(flow)
            self._starts[id(flow)] = self.env.now
            self._resolve()
        self.env._at(self.env.now + self.latency, start)
        return done

    def active_bytes_per_sec(self) -> float:
        return sum(f.rate for f in self._flows)

    # -- fluid model -------------------------------------------------------
    def _advance(self) -> None:
        """Account progress of all flows since the last rate change."""
        dt = self.env.now - self._last_update
        if dt > 0:
            for f in self._flows:
                f.remaining -= f.rate * dt
        self._last_update = self.env.now

    def _resolve(self) -> None:
        """Recompute max-min fair rates and reschedule next completion."""
        flows = self._flows
        if not flows:
            self._timer_version += 1
            return
        # Waterfilling: resources are ("up", node) and ("down", node).
        cap: dict[tuple[str, str], float] = {}
        members: dict[tuple[str, str], list[_Flow]] = {}
        for f in flows:
            up, down = ("up", f.src), ("down", f.dst)
            cap.setdefault(up, self.uplink.get(f.src, math.inf))
            cap.setdefault(down, self.downlink.get(f.dst, math.inf))
            members.setdefault(up, []).append(f)
            members.setdefault(down, []).append(f)
        fixed: dict[int, float] = {}
        live = {r for r in cap}
        while len(fixed) < len(flows) and live:
            best_r, best_share = None, math.inf
            for r in live:
                unfixed = [f for f in members[r] if id(f) not in fixed]
                if not unfixed:
                    continue
                share = cap[r] / len(unfixed)
                if share < best_share:
                    best_share, best_r = share, r
            if best_r is None:
                break
            for f in members[best_r]:
                if id(f) not in fixed:
                    fixed[id(f)] = best_share
                    for r2 in (("up", f.src), ("down", f.dst)):
                        if r2 != best_r:
                            cap[r2] -= best_share
            live.discard(best_r)
        for f in flows:
            f.rate = fixed.get(id(f), math.inf)
        # Next completion.
        self._timer_version += 1
        version = self._timer_version
        t_next = min((f.remaining / f.rate if f.rate > 0 else math.inf)
                     for f in flows)
        if math.isinf(t_next):
            raise RuntimeError("flow with zero rate and no completion")
        target = self.env.now + max(t_next, 0.0)
        if target <= self.env.now:          # guarantee clock progress
            target = math.nextafter(self.env.now, math.inf)
        self.env._at(target, lambda _: self._on_timer(version))

    def _on_timer(self, version: int) -> None:
        if version != self._timer_version:
            return  # stale timer; rates changed since
        self._advance()
        still: list[_Flow] = []
        for f in self._flows:
            # Completion tolerance must scale with the rate: a sub-byte
            # remainder whose drain time is below the float64 ULP of `now`
            # would otherwise stall the clock (resolve→timer at +0 forever).
            eps = 1e-6 + f.rate * 1e-9
            if f.remaining <= eps:
                t0 = self._starts.pop(id(f))
                self.log.append((f.src, f.dst, f.size, t0, self.env.now, f.tag))
                f.done.trigger(None)
            else:
                still.append(f)
        self._flows = still
        if not still and self._busy_since is not None:
            self.busy_time += self.env.now - self._busy_since
            self._busy_since = None
        self._resolve()
