"""Simulated data planes: DStore and the baseline stores (paper §3.3, §5).

Every plane implements the same three-call protocol, namespaced per workflow
instance so concurrent invocations never collide (DStore data is immutable —
"updated data must be stored with a new unique identifier", §3.3):

* ``seed(node, key, size)``      — stage an external workflow input.
* ``put(node, key, size, consumers)``  — producer stores one output.
  Returns an Event for *producer-side completion* (when the producer's
  container may be released).
* ``get(node, key)``             — consumer obtains the bytes into its
  container on ``node``.  Returns an Event triggered when the copy is done.

Planes:

* :class:`DStorePlane`   — the paper's DStore: per-node local stores, a
  metadata-only data directory service with **auto blocking/waking-up**,
  **receiver-driven** node-to-node transfers, and **least-access-frequency
  replica selection**.  ``put`` is local (the producer frees its container
  immediately, §3.4) and the metadata publish is asynchronous.
* :class:`CentralPlane`  — CFlow: every byte goes through a store on the
  master (CouchDB by default) — both puts and gets traverse the master's
  links, which is exactly the contention bottleneck the paper measures.
* :class:`HybridPlane`   — FaaSFlow / FaaSFlowRedis / KNIX: local Redis for
  intra-node exchange + a central store (CouchDB or Redis) on the master for
  inter-node exchange.
* :class:`ShardedDStorePlane` — DStore + **DShard** (beyond-paper,
  router.py): per-node directory shards + local routing tables — Gets
  resolve 1-hop at the producing node's shard, and same-container (ipc) /
  same-node (mem) / cross-node (net) transport tiers are priced
  distinctly.
* :class:`StreamingDStorePlane` — DStore + **DStream** (beyond-paper):
  producers publish fixed-size chunks *while executing* and consumers pull
  chunk-by-chunk, so inter-node transfer overlaps output production.
  Extra protocol: ``put_stream(..., produce_time)`` / ``get_stream``.
"""

from __future__ import annotations

import math
from dataclasses import dataclass, field
from typing import Iterable

from .router import TIER_IPC, TIER_MEM, TIER_NET
from .sim import Env, Event, all_of
from .simcluster import MASTER, Cluster, SimConfig

__all__ = ["DStorePlane", "ShardedDStorePlane", "StreamingDStorePlane",
           "CentralPlane", "HybridPlane", "DataMeta"]


@dataclass
class DataMeta:
    """Directory-service record (paper §3.3.1)."""

    key: str
    size: float
    locations: dict[str, int] = field(default_factory=dict)  # node -> access freq

    def best_location(self) -> str:
        # Receiver-driven replica choice: lowest access frequency (§3.3.1).
        return min(self.locations.items(), key=lambda kv: (kv[1], kv[0]))[0]


def _strip_sim_ns(key: str) -> str:
    """Simulator key "<wf>#<i>:<key>" -> raw workflow key."""
    return key.split(":", 1)[1] if ":" in key else key


class DStorePlane:
    """The paper's DStore over the simulated cluster."""

    name = "dstore"

    def __init__(self, env: Env, cluster: Cluster):
        self.env = env
        self.cluster = cluster
        self.cfg = cluster.cfg
        self.meta: dict[str, DataMeta] = {}
        self._waiters: dict[str, list[Event]] = {}
        self.local: dict[str, set[str]] = {n: set() for n in cluster.nodes}
        self.sizes: dict[str, float] = {}   # producer-side truth (local hits
        # may race the async 150us metadata publish; the local store knows
        # its own object sizes without consulting the directory)
        self.fetched_bytes = 0.0
        # DPlan transfer pricing: when a WorkflowPlan is attached, every
        # put/seed prices its key from the static transfer matrix instead
        # of the dynamic caller-supplied size.  ``key_of`` maps simulator
        # keys ("<wf>#<i>:<key>") back to plan keys.
        self.plan = None
        self.key_of = _strip_sim_ns
        self.plan_priced = 0            # puts priced from the plan matrix

    def _planned_size(self, key: str, size: float) -> float:
        if self.plan is None:
            return size
        ps = self.plan.key_size(self.key_of(key))
        if ps is None:
            return size
        self.plan_priced += 1
        return float(ps)

    # -- helpers ---------------------------------------------------------
    def _publish(self, key: str, size: float, node: str) -> None:
        """Async metadata write (≈150 us) then wake blocked consumers."""
        def write(_):
            m = self.meta.get(key)
            if m is None:
                m = self.meta[key] = DataMeta(key, size)
            m.locations.setdefault(node, 0)
            for ev in self._waiters.pop(key, []):
                ev.trigger(m)
        self.env._at(self.env.now + self.cfg.meta_write, write)

    def seed(self, node: str, key: str, size: float) -> None:
        size = self._planned_size(key, size)
        self.local[node].add(key)
        self.sizes[key] = size
        m = self.meta.setdefault(key, DataMeta(key, size))
        m.locations.setdefault(node, 0)

    # -- producer ----------------------------------------------------------
    def put(self, node: str, key: str, size: float,
            consumers: Iterable[str] = (),
            ref_node: str | None = None) -> Event:
        done = self.env.event()
        size = self._planned_size(key, size)
        self.sizes[key] = size

        def copied(_):
            self.local[node].add(key)
            self._publish(key, size, node)   # async: does not block producer
            done.trigger(None)
        self.cluster.local_copy(size).add_waiter(copied)
        return done

    # -- consumer ----------------------------------------------------------
    def get(self, node: str, key: str) -> Event:
        return self.env.process(self._get(node, key))

    def _get(self, node: str, key: str):
        cfg = self.cfg
        # 1. local-store hit: just copy into the container (paper step 5B/6C).
        if key in self.local[node]:
            size = self.sizes[key]
            yield self.cluster.local_copy(size)
            return size
        # 2. query directory service on the master (round trip + service).
        yield self.env.timeout(cfg.msg_latency + cfg.meta_query)
        m = self.meta.get(key)
        if m is None:
            # 3. auto-block until the producer publishes (paper §3.3.2).
            ev = self.env.event()
            self._waiters.setdefault(key, []).append(ev)
            m = yield ev
        if key not in self.local[node]:
            # 4. receiver-driven pull from least-loaded replica (§3.3.4).
            src = m.best_location()
            m.locations[src] += 1
            yield self.cluster.network.transfer(src, node, m.size,
                                                tag=f"dstore:{key}")
            m.locations[src] -= 1
            self.fetched_bytes += m.size
            self.local[node].add(key)
            m.locations.setdefault(node, 0)   # new replica registered
        # 5. local store -> container copy.
        yield self.cluster.local_copy(m.size)
        return m.size


class ShardedDStorePlane(DStorePlane):
    """DStore + DShard (router.py): per-node directory shards behind local
    routing tables, with the three transport tiers priced distinctly.

    Differences from the base plane, mirroring the threaded
    :class:`~repro.core.router.ShardedDStore`:

    * a Get that misses locally pays a node-local ``route_lookup`` (no
      master round trip) and contacts the key's home shard directly —
      one one-way message + the shard's directory service time (1 hop);
      only an *unrouted* key falls back to the master directory bounce
      (2 hops, counted in ``hop_hist``);
    * local hits are tiered: a key homed on the consumer's own node (its
      trigger payload or own output) is an ``ipc`` handoff; a local
      replica of a remotely-homed key is a ``mem`` memoryview handoff
      (``mem_op`` + size/``mem_bw``) — both cheaper than the base plane's
      uniform gRPC ``local_op``/``local_bw`` copy;
    * the final store→container copy after a network pull also rides the
      ``mem`` tier (the pull landed the bytes in this node's shard).

    Routes are installed by ``SimSystem`` from the same
    :func:`~repro.core.router.static_routes` the threaded store uses.
    """

    name = "dstore-shard"

    def __init__(self, env: Env, cluster: Cluster):
        super().__init__(env, cluster)
        self.routes: dict[str, str] = {}       # raw key -> home node
        self.seeded: dict[str, str] = {}       # sim key -> staging node
        self.hop_hist: dict[int, int] = {0: 0, 1: 0, 2: 0}
        self.tier_gets = {TIER_IPC: 0, TIER_MEM: 0, TIER_NET: 0}
        self.tier_bytes = {TIER_IPC: 0.0, TIER_MEM: 0.0, TIER_NET: 0.0}

    def install_routes(self, routes: dict[str, str]) -> None:
        self.routes.update(routes)

    def route_of(self, key: str) -> str | None:
        return self.routes.get(self.key_of(key))

    def seed(self, node: str, key: str, size: float) -> None:
        super().seed(node, key, size)
        self.seeded.setdefault(key, node)

    def put(self, node: str, key: str, size: float,
            consumers: Iterable[str] = (),
            ref_node: str | None = None) -> Event:
        # Dynamic registration: un-routed keys home on their writer.
        self.routes.setdefault(self.key_of(key), node)
        return super().put(node, key, size, consumers, ref_node)

    def _tiered(self, tier: str, size: float) -> None:
        self.tier_gets[tier] += 1
        self.tier_bytes[tier] += size

    def _get(self, node: str, key: str):
        cfg = self.cfg
        if key in self.local[node]:
            size = self.sizes[key]
            if self.seeded.get(key) == node or self.route_of(key) == node:
                # Same-container: the payload is already inside (ipc).
                yield self.env.timeout(cfg.ipc_latency)
                self._tiered(TIER_IPC, size)
            else:
                # Same-node replica: memoryview handoff, no gRPC copy.
                yield self.env.timeout(cfg.mem_op + size / cfg.mem_bw)
                self._tiered(TIER_MEM, size)
            self.hop_hist[0] += 1
            return size
        # Node-local routing table (no master round trip).
        yield self.env.timeout(cfg.route_lookup)
        home = self.route_of(key)
        if home is None:
            # Unrouted key: master directory bounce — 2 hops, the exact
            # resolution the trace checker flags on the threaded path.
            yield self.env.timeout(cfg.msg_latency + cfg.meta_query)
            hops = 2
        else:
            # Direct request to the home shard: one-way message (none if
            # the home is this node) + its directory service time.
            extra = 0.0 if home == node else cfg.msg_latency / 2
            yield self.env.timeout(extra + cfg.meta_query)
            hops = 1
        m = self.meta.get(key)
        if m is None:
            ev = self.env.event()
            self._waiters.setdefault(key, []).append(ev)
            m = yield ev
        if key not in self.local[node]:
            src = m.best_location()
            m.locations[src] += 1
            yield self.cluster.network.transfer(src, node, m.size,
                                                tag=f"dshard:{key}")
            m.locations[src] -= 1
            self.fetched_bytes += m.size
            self.local[node].add(key)
            m.locations.setdefault(node, 0)
            self._tiered(TIER_NET, m.size)
        else:
            self._tiered(TIER_MEM, m.size)
        self.hop_hist[hops] = self.hop_hist.get(hops, 0) + 1
        # Shard store -> container over the mem tier (bytes are node-local
        # now; no gRPC re-serialisation).
        yield self.env.timeout(cfg.mem_op + m.size / cfg.mem_bw)
        return m.size


@dataclass
class _SimStream:
    """Stream-directory record: per-chunk metadata lives in per-chunk
    :class:`DataMeta` entries; this holds the stream-level shape."""

    key: str
    size: float
    n_chunks: int
    chunk: float                     # uniform chunk size (= size / n_chunks)


class StreamingDStorePlane(DStorePlane):
    """DStore + DStream: chunked pipelined exchange (beyond-paper).

    ``put_stream`` is called when the producer *starts* executing: it
    registers the stream in the directory (waking consumers blocked on the
    stream announcement) and then publishes fixed-size chunks paced
    uniformly across the producer's execution time — each chunk gets its
    own :class:`DataMeta` record via the normal async publish, so the
    §3.3.2 auto blocking/waking and §3.3.1/§3.3.4 receiver-driven
    least-access-frequency pulls all apply per chunk.  ``get_stream``
    pulls chunk *i* while chunk *i+1* is still being produced, which is
    where the tail-latency and bandwidth-utilisation headroom over
    monolithic DFlow comes from.
    """

    name = "dstore-stream"

    def __init__(self, env: Env, cluster: Cluster,
                 chunk_size: float | None = None):
        super().__init__(env, cluster)
        self.chunk_size = (cluster.cfg.stream_chunk if chunk_size is None
                           else float(chunk_size))
        self.stream_meta: dict[str, _SimStream] = {}
        self._stream_waiters: dict[str, list[Event]] = {}

    @staticmethod
    def _chunk_key(key: str, i: int) -> str:
        return f"{key}\x1ec{i}"

    # -- producer ----------------------------------------------------------
    def put_stream(self, node: str, key: str, size: float,
                   consumers: Iterable[str] = (),
                   ref_node: str | None = None,
                   produce_time: float = 0.0) -> Event:
        """Announce the stream now; emit chunks across ``produce_time``.
        The returned event is producer-side completion (last chunk copied
        into the local store)."""
        size = self._planned_size(key, size)
        n = max(1, math.ceil(size / self.chunk_size))
        sm = _SimStream(key, size, n, size / n)
        self.sizes[key] = size
        self.stream_meta[key] = sm
        for ev in self._stream_waiters.pop(key, []):
            ev.trigger(sm)
        return self.env.process(self._produce(node, key, sm, produce_time))

    def _produce(self, node: str, key: str, sm: _SimStream,
                 produce_time: float):
        pace = produce_time / sm.n_chunks
        for i in range(sm.n_chunks):
            if pace:
                yield self.env.timeout(pace)
            # container -> local store copy, then async per-chunk publish.
            yield self.cluster.local_copy(sm.chunk)
            ck = self._chunk_key(key, i)
            self.sizes[ck] = sm.chunk
            self._publish(ck, sm.chunk, node)
        self.local[node].add(key)        # whole value now locally resident

    # -- consumer ----------------------------------------------------------
    def get_stream(self, node: str, key: str) -> Event:
        return self.env.process(self._get_stream(node, key))

    def _get_stream(self, node: str, key: str):
        cfg = self.cfg
        if key in self.local[node]:
            size = self.sizes[key]
            yield self.cluster.local_copy(size)
            return size
        yield self.env.timeout(cfg.msg_latency + cfg.meta_query)
        sm = self.stream_meta.get(key)
        if sm is None and (key in self.meta or key in self.sizes):
            # Seeded external input / monolithic Put: plain DStore path.
            size = yield self.env.process(self._get(node, key))
            return size
        if sm is None:
            # Auto-block until the producer announces the stream.
            ev = self.env.event()
            self._stream_waiters.setdefault(key, []).append(ev)
            sm = yield ev
        got = 0.0
        for i in range(sm.n_chunks):
            ck = self._chunk_key(key, i)
            m = self.meta.get(ck)
            if m is None:
                # Auto-block per chunk (§3.3.2 at chunk granularity).
                ev = self.env.event()
                self._waiters.setdefault(ck, []).append(ev)
                m = yield ev
            if node not in m.locations:
                # Receiver-driven chunk pull, least-access-frequency replica.
                src = m.best_location()
                m.locations[src] += 1
                yield self.cluster.network.transfer(src, node, m.size,
                                                    tag=f"dstream:{key}:{i}")
                m.locations[src] -= 1
                self.fetched_bytes += m.size
                m.locations.setdefault(node, 0)
            got += m.size
        self.local[node].add(key)
        yield self.cluster.local_copy(got)   # local store -> container
        return got


class CentralPlane:
    """All data through one store on the master node (CFlow's CouchDB)."""

    def __init__(self, env: Env, cluster: Cluster,
                 op_overhead: float | None = None,
                 bw_eff: float | None = None, name: str = "couch",
                 hub: str = MASTER):
        cfg = cluster.cfg
        self.env = env
        self.cluster = cluster
        self.cfg = cfg
        self.op = cfg.couch_op if op_overhead is None else op_overhead
        self.bw_eff = cfg.couch_bw_eff if bw_eff is None else bw_eff
        self.name = name
        self.hub = hub
        self.sizes: dict[str, float] = {}
        self.seeded: set[str] = set()

    def seed(self, node: str, key: str, size: float) -> None:
        # External inputs arrive with the trigger payload — no store hop.
        self.sizes[key] = size
        self.seeded.add(key)

    def put(self, node: str, key: str, size: float,
            consumers: Iterable[str] = (),
            ref_node: str | None = None) -> Event:
        self.sizes[key] = size
        return self.env.process(self._put(node, key, size))

    def _put(self, node: str, key: str, size: float):
        yield self.env.timeout(self.op)
        yield self.cluster.network.transfer(node, self.hub, size / self.bw_eff,
                                            tag=f"{self.name}:put:{key}")

    def get(self, node: str, key: str) -> Event:
        return self.env.process(self._get(node, key))

    def _get(self, node: str, key: str):
        size = self.sizes[key]
        if key in self.seeded:
            yield self.cluster.local_copy(size)
            return size
        yield self.env.timeout(self.op)
        yield self.cluster.network.transfer(self.hub, node, size / self.bw_eff,
                                            tag=f"{self.name}:get:{key}")
        yield self.cluster.local_copy(size)
        return size


class HybridPlane:
    """Local Redis per node + central store for inter-node (FaaSFlow family).

    ``central='couch'`` → FaaSFlow;  ``central='redis'`` → FaaSFlowRedis/KNIX.
    The producer uploads to the central store *only* when at least one
    consumer lives on another node (FaaSFlow's GS decides storage type per
    function; our partitioner gives the plane the consumer placement).
    """

    def __init__(self, env: Env, cluster: Cluster, central: str = "couch",
                 hub: str = MASTER, db_exclusive: bool = False):
        cfg = cluster.cfg
        self.env = env
        self.cluster = cluster
        self.cfg = cfg
        self.hub = hub
        # KNIX semantics: a DB-type output is written ONLY to the remote
        # Redis ("KNIX will utilize the remote Redis to store the function's
        # output", §5) — consumers then fetch it over the network even if
        # they run on the producer's node.
        self.db_exclusive = db_exclusive
        self.name = f"hybrid-{central}"
        if central == "couch":
            self.op, self.bw_eff = cfg.couch_op, cfg.couch_bw_eff
        elif central == "redis":
            self.op, self.bw_eff = cfg.redis_op, cfg.redis_bw_eff
        else:
            raise ValueError(central)
        self.sizes: dict[str, float] = {}
        self.local: dict[str, set[str]] = {n: set() for n in cluster.nodes}

    def seed(self, node: str, key: str, size: float) -> None:
        self.sizes[key] = size
        self.local[node].add(key)

    def put(self, node: str, key: str, size: float,
            consumers: Iterable[str] = (),
            ref_node: str | None = None) -> Event:
        # Storage-type decision (MEM vs DB) is made against the GS's
        # reference placement: ``ref_node`` is the producer's reference
        # node, ``consumers`` the consumers' reference nodes.
        self.sizes[key] = size
        base = ref_node if ref_node is not None else node
        remote = any(c != base for c in consumers)
        return self.env.process(self._put(node, key, size, remote))

    def _put(self, node: str, key: str, size: float, remote: bool):
        if remote and self.db_exclusive:
            # DB storage type: output lives only in the hub Redis.
            yield self.env.timeout(self.op)
            yield self.cluster.network.transfer(
                node, self.hub, size / self.bw_eff, tag=f"{self.name}:put:{key}")
            return
        yield self.cluster.local_copy(size)          # local redis write
        self.local[node].add(key)
        if remote:                                   # upload for remote readers
            yield self.env.timeout(self.op)
            yield self.cluster.network.transfer(
                node, self.hub, size / self.bw_eff, tag=f"{self.name}:put:{key}")

    def get(self, node: str, key: str) -> Event:
        return self.env.process(self._get(node, key))

    def _get(self, node: str, key: str):
        size = self.sizes[key]
        if key in self.local[node]:
            yield self.cluster.local_copy(size)
            return size
        yield self.env.timeout(self.op)
        yield self.cluster.network.transfer(self.hub, node, size / self.bw_eff,
                                            tag=f"{self.name}:get:{key}")
        yield self.cluster.local_copy(size)
        return size
