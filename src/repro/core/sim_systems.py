"""Simulated serverless workflow systems (paper §2.2, §3, §5).

Implemented systems — all share the same GS placement (FaaSFlow's GS, as in
the paper's evaluation) so the differences isolate (a) the invocation
pattern and (b) the data plane:

================  ==========================  ============================
system            invocation pattern          data plane
================  ==========================  ============================
``cflow``         controlflow, centralized    CentralPlane (CouchDB@master)
``faasflow``      controlflow, decentralized  HybridPlane (local Redis + CouchDB)
``faasflowredis`` controlflow, decentralized  HybridPlane (local Redis + Redis)
``knix``          controlflow, decentralized  HybridPlane (Redis) + 1-container
                                              sandbox per node (process pool)
``faasflow+dstore`` controlflow, decentralized DStorePlane   (paper §5.5)
``dflow``         **dataflow (Algorithm 1)**  DStorePlane
``dflow-stream``  **dataflow (Algorithm 1)**  StreamingDStorePlane (DStream:
                                              chunked pipelined exchange)
``dflow-shard``   **dataflow (Algorithm 1)**  ShardedDStorePlane (DShard:
                                              per-node shards, local routing
                                              tables, 1-hop + tiered
                                              transports)
================  ==========================  ============================

The dataflow local scheduler implements the paper's Algorithm 1 exactly:
on a workflow trigger every DLS launches its *entry points and their direct
successors*; whenever any function completes, each DLS launches the
*successors of that function's successors* (the +2 frontier).  A launched
function acquires its container immediately (cold start overlaps precursor
execution) and spawns one fine-grained fetch per input, each of which may
auto-block inside the DStore directory until the producer publishes.
"""

from __future__ import annotations

import itertools
from dataclasses import dataclass, field

from .dag import Workflow
from .partition import partition_workflow
from .sim import Env, Event, all_of
from .sim_dataplane import (CentralPlane, DStorePlane, HybridPlane,
                            ShardedDStorePlane, StreamingDStorePlane)
from .simcluster import MASTER, Cluster, SimConfig

__all__ = ["make_system", "SimSystem", "InstanceResult", "SYSTEMS"]

SYSTEMS = ("cflow", "faasflow", "faasflowredis", "knix",
           "faasflow+dstore", "dflow", "dflow-stream", "dflow-shard")


@dataclass
class InstanceResult:
    inst: int
    arrival: float
    finish: float = float("inf")
    done: Event | None = None
    cancelled: bool = False
    completed: dict[str, float] = field(default_factory=dict)
    span: object = None              # DScope request span (obs.py), or None

    @property
    def latency(self) -> float:
        return self.finish - self.arrival


class SimSystem:
    """One deployed workflow on one simulated cluster."""

    def __init__(self, env: Env, cluster: Cluster, wf: Workflow, *,
                 pattern: str, plane, prewarm: bool, sandbox: bool,
                 central_sched: bool, name: str,
                 single_node: str | None = None, streaming: bool = False,
                 spans=None, budget=None):
        self.env = env
        # DScale prewarm budget (scale.py PrewarmBudget) on the virtual
        # clock: every speculative container boot must be granted
        # container-seconds first; denied boots are dropped (the §3.2
        # heuristic is free only when no budget is installed).
        self.budget = budget
        # DScope span tracer (obs.py) on the VIRTUAL clock — the driver
        # (run_open_loop) rebinds tracer.clock to env.now.  Spans use
        # explicit parents, never thread-local context: simulated
        # coroutines interleave on one thread, so an implicit "current
        # span" would attribute one instance's ops to another.
        self.spans = spans
        self.cluster = cluster
        self.cfg = cluster.cfg
        self.wf = wf
        self.pattern = pattern              # "controlflow" | "dataflow"
        self.plane = plane
        self.prewarm = prewarm
        self.sandbox = sandbox              # KNIX: process-in-container
        self.central_sched = central_sched  # CFlow: master drives invocation
        self.streaming = streaming          # DStream chunked exchange
        self.name = name
        if single_node is not None:
            # KNIX deployment (paper §5.1): the whole workflow runs on one
            # node; the "remote" Redis lives on another worker.  Storage
            # types (MEM vs DB) are still decided by FaaSFlow's GS over the
            # full worker set (§5: "We employ the GS from FaaSFlow to
            # determine the storage type for each function").
            self.placement = {fn: single_node for fn in wf.functions}
            self.storage_ref = partition_workflow(wf, cluster.workers())
        else:
            self.placement = partition_workflow(wf, cluster.workers())
            self.storage_ref = self.placement
        self._counter = itertools.count()
        self.results: list[InstanceResult] = []
        self._sandbox_booted: dict[str, Event] = {}  # node -> boot done
        # DPlan: DStore-backed planes price transfers from the static
        # matrix (plan.key_size) instead of trusting per-call sizes — the
        # two agree by construction (one sizing helper, Workflow.key_bytes),
        # so this pins the simulator to the analyzer's cost model.
        if isinstance(plane, DStorePlane):
            from .plan import build_plan

            plane.plan = build_plan(wf, self.placement)
        if isinstance(plane, ShardedDStorePlane):
            # DShard: the plane's routing table comes from the same
            # static_routes the threaded ShardedDStore installs (raw keys;
            # the plane's key_of strips the instance namespace).
            from .router import static_routes

            plane.install_routes(
                static_routes(wf, self.placement, cluster.workers()))

    # ------------------------------------------------------------------
    def image(self, fname: str) -> str:
        if self.sandbox:
            return f"sandbox:{self.wf.name}"
        return f"{self.wf.name}/{fname}"

    def consumers_of(self, key: str) -> list[str]:
        """Consumer placements per the storage-type reference partition."""
        out = []
        for f in self.wf.functions.values():
            if key in f.inputs:
                out.append(self.storage_ref[f.name])
        return out

    def key(self, inst: int, k: str) -> str:
        return f"{self.wf.name}#{inst}:{k}"

    # ------------------------------------------------------------------
    def invoke(self) -> InstanceResult:
        inst = next(self._counter)
        res = InstanceResult(inst=inst, arrival=self.env.now,
                             done=self.env.event())
        self.results.append(res)
        if self.spans is not None:
            trace = f"{self.wf.name}#{inst}"
            res.span = self.spans.start(trace, "request", parent=None,
                                        trace=trace, workflow=self.wf.name,
                                        system=self.name)
        # Stage external inputs in the local stores of their first consumers
        # (the trigger payload arrives with the invocation).
        for k, sz in self.wf.external_inputs.items():
            for f in self.wf.functions.values():
                if k in f.inputs:
                    self.plane.seed(self.placement[f.name],
                                    self.key(inst, k), sz)
        # Paper's 60 s experiment timeout: a timed-out invocation stops
        # generating new work (its latency is clamped to the timeout by the
        # metric collector, exactly as the paper records it).
        def expire(_):
            if not res.done.triggered:
                res.cancelled = True
                if self.spans is not None:
                    self.spans.end(res.span, cancelled=True)
                res.done.trigger(res)
        self.env._at(self.env.now + self.cfg.timeout + 1e-6, expire)
        if self.pattern == "dataflow":
            self.env.process(self._invoke_dataflow(res))
        elif self.central_sched:
            self.env.process(self._invoke_central(res))
        else:
            self.env.process(self._invoke_decentralized(res))
        return res

    # -- shared function body -------------------------------------------
    def _acquire_container(self, node: str, fname: str):
        """yields startup delay handling sandbox (KNIX) vs per-fn container.

        Pool-backed: the per-(node, image) pool delegates to the shared
        container lifecycle model (:class:`repro.core.serve.ContainerPool`
        via :class:`repro.core.simcluster._ContainerPool`) — warm reuse,
        joining an in-flight prewarm boot, keep-alive TTL eviction, and the
        cold-start metrics all come from the same code the threaded
        serving layer uses."""
        n = self.cluster.nodes[node]
        if self.sandbox:
            boot = self._sandbox_booted.get(node)
            if boot is None:
                pool = n.pool(self.image(fname))
                boot = self._sandbox_booted[node] = pool.prewarm()
            yield boot                       # first caller pays cold boot
            yield self.env.timeout(self.cfg.knix_process_start)
            return None
        pool = n.pool(self.image(fname))
        lease = yield pool.acquire()
        return lease

    def _run_function(self, res: InstanceResult, fname: str,
                      on_complete) -> None:
        if res.cancelled:
            return
        self.env.process(self._function_body(res, fname, on_complete))

    def _function_body(self, res: InstanceResult, fname: str, on_complete):
        f = self.wf.functions[fname]
        node = self.placement[fname]
        n = self.cluster.nodes[node]
        sp = None
        if self.spans is not None and res.span is not None:
            sp = self.spans.start(fname, "invoke", parent=res.span,
                                  node=node)
            acq = self.spans.start(fname, "acquire", parent=sp, node=node)
        lease = yield self.env.process(self._acquire_container(node, fname))
        if sp is not None:
            self.spans.end(acq)
        if res.cancelled:
            if lease is not None:
                lease.release()
            if sp is not None:
                self.spans.end(sp, cancelled=True)
            return
        # Fetch every input (parallel / fine-grained; DStore gets may block).
        # DStream: chunk-granular gets pull chunk i while the producer is
        # still emitting chunk i+1, so transfer overlaps production.
        if self.streaming:
            gets = [self.plane.get_stream(node, self.key(res.inst, k))
                    for k in f.inputs]
        else:
            gets = [self.plane.get(node, self.key(res.inst, k))
                    for k in f.inputs]
        if gets:
            yield all_of(self.env, gets)
        # Execute on one core.
        yield n.cores.acquire()
        if res.cancelled:
            n.cores.release()
            if lease is not None:
                lease.release()
            if sp is not None:
                self.spans.end(sp, cancelled=True)
            return
        if self.streaming:
            # Announce outputs now; chunks publish paced across execution.
            puts = [self.plane.put_stream(node, self.key(res.inst, k),
                                          f.size_of(k),
                                          consumers=self.consumers_of(k),
                                          ref_node=self.storage_ref[fname],
                                          produce_time=f.exec_time)
                    for k in f.outputs]
            yield self.env.timeout(f.exec_time)
            n.cores.release()
        else:
            yield self.env.timeout(f.exec_time)
            n.cores.release()
            # Store outputs.
            puts = [self.plane.put(node, self.key(res.inst, k), f.size_of(k),
                                   consumers=self.consumers_of(k),
                                   ref_node=self.storage_ref[fname])
                    for k in f.outputs]
        if puts:
            yield all_of(self.env, puts)
        if lease is not None:
            lease.release()
        res.completed[fname] = self.env.now
        if sp is not None:
            self.spans.end(sp)
        on_complete(fname)

    def _finish_if_done(self, res: InstanceResult) -> None:
        if len(res.completed) == len(self.wf.functions):
            # exit-function completion notification to the master.
            def fin(_):
                if not res.done.triggered:
                    res.finish = self.env.now
                    if self.spans is not None:
                        self.spans.end(res.span, ok=True)
                    res.done.trigger(res)
            self.cluster.message("worker", MASTER).add_waiter(fin)

    # -- controlflow, centralized (CFlow) --------------------------------
    def _invoke_central(self, res: InstanceResult):
        wf = self.wf
        pending = {fn: len(wf.predecessors[fn]) for fn in wf.functions}
        launched: set[str] = set()

        def master_on_complete(fname: str):
            # completion message worker -> master, then master invokes
            # newly-ready successors (master -> worker messages).
            def at_master(_):
                self._finish_if_done(res)
                for s in wf.successors[fname]:
                    pending[s] -= 1
                    if pending[s] == 0 and s not in launched:
                        launched.add(s)
                        dst = self.placement[s]

                        def mk(sname):
                            return lambda _: self._run_function(
                                res, sname, master_on_complete)
                        self.cluster.message(MASTER, dst).add_waiter(mk(s))
            self.cluster.message(self.placement[fname],
                                 MASTER).add_waiter(at_master)

        for e in wf.entry_points:
            launched.add(e)
            dst = self.placement[e]

            def mk(ename):
                return lambda _: self._run_function(
                    res, ename, master_on_complete)
            self.cluster.message(MASTER, dst).add_waiter(mk(e))
        return
        yield  # pragma: no cover  (generator form for env.process)

    # -- controlflow, decentralized (FaaSFlow family) ---------------------
    def _invoke_decentralized(self, res: InstanceResult):
        wf = self.wf
        pending = {fn: len(wf.predecessors[fn]) for fn in wf.functions}
        launched: set[str] = set()
        aware: set[str] = set()   # nodes that have heard of this instance

        def node_aware(node: str):
            """First contact with a node: its local scheduler learns of the
            instance and prewarms its sub-DAG's containers.  Non-entry nodes
            only become aware when the first cross-node message arrives —
            unlike DFlow's t=0 broadcast (this is the cold-start gap the
            paper measures in §5.4)."""
            if node in aware:
                return
            aware.add(node)
            if self.prewarm and not self.sandbox:
                for fn2 in wf.functions:
                    if self.placement[fn2] != node:
                        continue
                    pool = self.cluster.nodes[node].pool(self.image(fn2))
                    if pool.available == 0:   # nothing idle NOR booting
                        # DScale: a budget prices the speculative boot at
                        # cold_start container-seconds (virtual clock);
                        # denial drops it — the request path then pays
                        # the cold start instead.
                        if self.budget is not None:
                            grant = self.budget.request(
                                fn2, self.cfg.cold_start, slack=0.0,
                                now=self.env.now)
                            if grant is None \
                                    or not self.budget.settle(grant):
                                continue
                        pool.prewarm()

        def local_on_complete(fname: str):
            self._finish_if_done(res)
            for s in wf.successors[fname]:
                dst = self.placement[s]

                def mk(sname, dnode):
                    def arrived(_):
                        node_aware(dnode)
                        pending[sname] -= 1
                        if pending[sname] == 0 and sname not in launched:
                            launched.add(sname)
                            self._run_function(res, sname, local_on_complete)
                    return arrived
                # notify the scheduler of the successor's node (free if local)
                self.cluster.message(self.placement[fname], dst).add_waiter(
                    mk(s, dst))

        # The trigger reaches only the nodes hosting entry functions.
        entry_nodes = sorted({self.placement[e] for e in wf.entry_points})
        for nd in entry_nodes:
            def mk_node(node):
                def arrived(_):
                    node_aware(node)
                    for e in wf.entry_points:
                        if self.placement[e] == node and e not in launched:
                            launched.add(e)
                            self._run_function(res, e, local_on_complete)
                return arrived
            self.cluster.message(MASTER, nd).add_waiter(mk_node(nd))
        return
        yield  # pragma: no cover

    # -- dataflow (DFlow, Algorithm 1) ------------------------------------
    def _invoke_dataflow(self, res: InstanceResult):
        wf = self.wf
        launched: set[str] = set()

        def launch(fname: str):
            if fname in launched:
                return
            launched.add(fname)
            self._run_function(res, fname, on_complete)

        def on_complete(fname: str):
            self._finish_if_done(res)
            # Algorithm 1 lines 8-15: launch successors-of-successors of the
            # finished function, notifying the DLS of each hosting node.
            targets: dict[str, list[str]] = {}
            for s in wf.successors[fname]:
                for t in wf.successors[s]:
                    if t not in launched:
                        targets.setdefault(self.placement[t], []).append(t)
            src = self.placement[fname]
            for dst, fns in sorted(targets.items()):
                def mk(fns2):
                    return lambda _: [launch(t) for t in fns2]
                self.cluster.message(src, dst).add_waiter(mk(fns))

        # Trigger broadcast: each DLS launches its local share of the
        # initial frontier = entry points + their direct successors
        # (Algorithm 1 lines 1-7).
        frontier: list[str] = []
        for e in wf.entry_points:
            frontier.append(e)
            frontier.extend(wf.successors[e])
        by_node: dict[str, list[str]] = {}
        for fn in dict.fromkeys(frontier):          # dedup, keep order
            by_node.setdefault(self.placement[fn], []).append(fn)
        for nd, fns in sorted(by_node.items()):
            def mk_node(fns2):
                return lambda _: [launch(fn) for fn in fns2]
            self.cluster.message(MASTER, nd).add_waiter(mk_node(fns))
        return
        yield  # pragma: no cover


# ----------------------------------------------------------------------
def make_system(name: str, env: Env, cluster: Cluster,
                wf: Workflow, *, spans=None, budget=None) -> SimSystem:
    """Factory mapping paper system names to configurations.

    ``budget`` (a :class:`repro.core.scale.PrewarmBudget`) prices every
    speculative container boot in container-seconds; None keeps the
    classic free-prewarm behavior."""
    system = _make_system(name, env, cluster, wf, spans=spans)
    system.budget = budget
    return system


def _make_system(name: str, env: Env, cluster: Cluster,
                 wf: Workflow, *, spans=None) -> SimSystem:
    if name == "cflow":
        return SimSystem(env, cluster, wf, pattern="controlflow",
                         plane=CentralPlane(env, cluster), prewarm=False,
                         sandbox=False, central_sched=True, name=name, spans=spans)
    if name == "faasflow":
        return SimSystem(env, cluster, wf, pattern="controlflow",
                         plane=HybridPlane(env, cluster, central="couch"),
                         prewarm=True, sandbox=False, central_sched=False,
                         name=name, spans=spans)
    if name == "faasflowredis":
        return SimSystem(env, cluster, wf, pattern="controlflow",
                         plane=HybridPlane(env, cluster, central="redis"),
                         prewarm=True, sandbox=False, central_sched=False,
                         name=name, spans=spans)
    if name == "knix":
        # Paper §5.1: "we deploy the remote Redis on Node 1 and install KNIX
        # on Node 2" — single-worker sandbox, hub Redis on another worker.
        return SimSystem(env, cluster, wf, pattern="controlflow",
                         plane=HybridPlane(env, cluster, central="redis",
                                           hub="node1", db_exclusive=True),
                         prewarm=False, sandbox=True, central_sched=False,
                         name=name, single_node="node2", spans=spans)
    if name == "faasflow+dstore":
        return SimSystem(env, cluster, wf, pattern="controlflow",
                         plane=DStorePlane(env, cluster), prewarm=True,
                         sandbox=False, central_sched=False, name=name,
                         spans=spans)
    if name == "dflow":
        return SimSystem(env, cluster, wf, pattern="dataflow",
                         plane=DStorePlane(env, cluster), prewarm=False,
                         sandbox=False, central_sched=False, name=name,
                         spans=spans)
    if name == "dflow-stream":
        # DFlow + DStream: Algorithm 1 invocation with chunked pipelined
        # data exchange (transfer overlaps production; beyond-paper).
        return SimSystem(env, cluster, wf, pattern="dataflow",
                         plane=StreamingDStorePlane(env, cluster),
                         prewarm=False, sandbox=False, central_sched=False,
                         name=name, streaming=True, spans=spans)
    if name == "dflow-shard":
        # DFlow + DShard: Algorithm 1 invocation over per-node DStore
        # shards with local routing tables — 1-hop transfers and tiered
        # ipc/mem/net transports (beyond-paper; see core/router.py).
        return SimSystem(env, cluster, wf, pattern="dataflow",
                         plane=ShardedDStorePlane(env, cluster),
                         prewarm=False, sandbox=False, central_sched=False,
                         name=name, spans=spans)
    raise ValueError(f"unknown system {name!r}; choose from {SYSTEMS}")
