"""Simulated FaaS cluster: nodes, cores, container pools, messaging.

Models the paper's testbed (§5.1): 8 × ecs.g7.2xlarge (8 vCPU, 32 GB),
functions run in 1-core/256 MB containers, link bandwidth shaped with
wondershaper to 25–100 MB/s.  All constants live in :class:`SimConfig` so
experiments can sweep them; defaults are calibrated to the paper's setup.
"""

from __future__ import annotations

from dataclasses import dataclass, field

from .serve import ContainerPool
from .sim import Env, Event, Network, Resource

__all__ = ["SimConfig", "Node", "Cluster", "MASTER"]

MASTER = "master"


@dataclass(frozen=True)
class SimConfig:
    """All knobs of the simulated cluster + data planes (SI units: s, B)."""

    n_workers: int = 7                    # + 1 master = paper's 8 nodes
    cores_per_node: int = 8               # ecs.g7.2xlarge vCPUs
    bandwidth: float = 50e6               # per-node link, B/s (wondershaper)
    msg_latency: float = 0.5e-3           # LAN RTT for control messages
    meta_write: float = 150e-6            # paper §3.3.1: ~150 us
    meta_query: float = 150e-6            # directory lookup service time
    local_bw: float = 1.5e9               # container<->local-store memcpy/gRPC
    local_op: float = 0.3e-3              # per-op local store overhead
    # Central-store per-op overheads (request handling, (de)serialisation).
    couch_op: float = 15e-3               # CouchDB HTTP + disk commit
    couch_bw_eff: float = 0.6             # CouchDB effective wire efficiency
    redis_op: float = 1.0e-3              # Redis RESP overhead
    redis_bw_eff: float = 0.95
    stream_chunk: float = 1e6             # DStream chunk size (B)
    cold_start: float = 0.8               # container cold boot (docker run)
    keepalive: float = 600.0              # warm-container TTL (paper: 600 s)
    knix_process_start: float = 0.02      # KNIX in-container process fork
    max_containers: int = 96              # 32GB / 256MB, with headroom
    timeout: float = 60.0                 # experiment timeout (paper: 60 s)
    # DShard transport tiers (router.py / ShardedDStorePlane): routed Gets
    # resolve against a node-local table and hand bytes over the cheapest
    # applicable tier instead of the uniform local_op/local_bw gRPC path.
    route_lookup: float = 2e-6            # local routing-table lookup
    ipc_latency: float = 5e-6             # same-container handoff (ipc tier)
    mem_op: float = 60e-6                 # same-node memoryview op (mem tier)
    mem_bw: float = 8e9                   # same-node shared-memory bandwidth

    def worker_names(self) -> list[str]:
        return [f"node{i + 1}" for i in range(self.n_workers)]

    def all_names(self) -> list[str]:
        return [MASTER] + self.worker_names()


class _SimLease:
    """Virtual-clock lease handle: delivered as the ``acquire`` event's
    value, so a sim process writes ``lease = yield pool.acquire()`` and
    later ``lease.release()`` — returning the *specific* leased container
    (the simulator twin of :class:`repro.core.serve.Lease`)."""

    __slots__ = ("_pool", "lease")

    def __init__(self, pool: "_ContainerPool", lease):
        self._pool = pool
        self.lease = lease

    @property
    def cold(self) -> bool:
        return self.lease.cold

    @property
    def delay(self) -> float:
        return self.lease.delay

    def release(self) -> None:
        p = self._pool
        p.model.release(self.lease, p.env.now)
        p._reconcile_cap()


class _ContainerPool:
    """Container pool for one (node, function-image) pair — a virtual-clock
    adapter over the shared lifecycle model
    (:class:`repro.core.serve.ContainerPool`), so the simulator and the
    threaded serving layer share one implementation of cold boot, warm
    reuse, keep-alive TTL eviction, prewarm, and the derived metrics.

    ``acquire`` returns an event that triggers — after the startup delay:
    0 for a warm hit, the residual boot time when joining a container that
    is already booting (a prewarm in flight), ``cold_start`` otherwise —
    with a :class:`_SimLease` pinning *which* container was leased (the
    same lease-token discipline as the threaded engine; releasing "some
    busy container" corrupts idle_since/TTL accounting).  Booted
    containers hold one slot of the node's container capacity until TTL
    eviction reclaims it.
    """

    def __init__(self, env: Env, cold_start: float, cap: Resource,
                 keepalive: float = 600.0):
        self.env = env
        self.cap = cap
        self.model = ContainerPool(cold_start=cold_start,
                                   keepalive=keepalive)
        self._cap_released = 0

    # -- back-compat metrics/state ---------------------------------------
    @property
    def cold_starts(self) -> int:
        """Total container boots (request-path + prewarm), the paper's
        cold-start count metric."""
        return self.model.boots

    @property
    def warm(self) -> int:
        """Idle containers ready right now."""
        return self.model.idle_count(self.env.now)

    @property
    def available(self) -> int:
        """Idle containers including ones still booting (joinable)."""
        return self.model.available(self.env.now)

    def _reconcile_cap(self) -> None:
        """Release node capacity for containers the model TTL-evicted."""
        while self._cap_released < self.model.evictions:
            self._cap_released += 1
            self.cap.release()

    # -- lifecycle --------------------------------------------------------
    def acquire(self):
        lease = self.model.try_acquire_warm(self.env.now)
        self._reconcile_cap()
        if lease is not None:
            return self.env.timeout(lease.delay, _SimLease(self, lease))
        done = self.env.event()

        def boot(_):
            boots_before = self.model.boots
            lease = self.model.acquire(self.env.now)
            if self.model.boots == boots_before:
                # A container became idle while we were queued on capacity:
                # no new boot happened, so hand the slot straight back
                # (otherwise the node's effective capacity leaks away).
                self.cap.release()
            self._reconcile_cap()
            self.env._at(self.env.now + lease.delay, done.trigger,
                         _SimLease(self, lease))
        self.cap.acquire().add_waiter(boot)
        return done

    def set_target(self, target: int | None) -> tuple[int, int]:
        """DScale autoscaler hook (virtual clock): pin the pool's live
        target, booting up to it within the node's container capacity and
        releasing capacity for early-reclaimed idles."""
        if target is not None:
            # Scale-up boots consume node capacity like any other boot;
            # clamp to what the capacity Resource can grant right now.
            room = self.cap.capacity - self.cap.in_use
            target_now = min(int(target), self.model.live() + max(0, room))
            booted, _ = self.model.set_target(target_now, self.env.now)
            self.model.target = int(target)
            for _ in range(booted):
                self.cap.acquire()
        else:
            self.model.set_target(None, self.env.now)
        self._reconcile_cap()
        return (0, 0)

    def prewarm(self) -> Event:
        """Boot one container ahead of need; triggers when one is ready.
        No-op (beyond waiting) if an idle or booting container exists."""
        done = self.env.event()
        if self.model.available(self.env.now) > 0:
            d = self.model.prewarm(self.env.now)     # joins existing boot
            self._reconcile_cap()
            self.env._at(self.env.now + d, done.trigger, None)
            return done

        def boot(_):
            boots_before = self.model.boots
            d = self.model.prewarm(self.env.now)
            if self.model.boots == boots_before:
                self.cap.release()          # idle appeared while queued
            self._reconcile_cap()
            self.env._at(self.env.now + d, done.trigger, None)
        self.cap.acquire().add_waiter(boot)
        return done


class Node:
    def __init__(self, env: Env, name: str, cfg: SimConfig):
        self.env = env
        self.name = name
        self.cfg = cfg
        self.cores = Resource(env, cfg.cores_per_node)
        self.container_cap = Resource(env, cfg.max_containers)
        self._pools: dict[str, _ContainerPool] = {}

    def pool(self, image: str, cold_start: float | None = None) -> _ContainerPool:
        p = self._pools.get(image)
        if p is None:
            p = _ContainerPool(
                self.env,
                self.cfg.cold_start if cold_start is None else cold_start,
                self.container_cap, keepalive=self.cfg.keepalive)
            self._pools[image] = p
        return p

    @property
    def total_cold_starts(self) -> int:
        return sum(p.cold_starts for p in self._pools.values())


class Cluster:
    """Nodes + fluid network + control-message helper."""

    def __init__(self, env: Env, cfg: SimConfig):
        self.env = env
        self.cfg = cfg
        names = cfg.all_names()
        self.nodes = {n: Node(env, n, cfg) for n in names}
        bw = {n: cfg.bandwidth for n in names}
        self.network = Network(env, uplink=dict(bw), downlink=dict(bw),
                               latency=cfg.msg_latency)

    def workers(self) -> list[str]:
        return self.cfg.worker_names()

    def message(self, src: str, dst: str) -> Event:
        """Small control message (invocation / completion notify)."""
        if src == dst:
            return self.env.timeout(0.0)
        return self.env.timeout(self.cfg.msg_latency)

    def local_copy(self, size: float) -> Event:
        """Container <-> local store copy (gRPC over loopback / memcpy)."""
        return self.env.timeout(self.cfg.local_op + size / self.cfg.local_bw)

    # -- metrics ---------------------------------------------------------
    def internode_bytes(self) -> float:
        return sum(entry[2] for entry in self.network.log)

    def cold_starts(self) -> int:
        return sum(n.total_cold_starts for n in self.nodes.values())
