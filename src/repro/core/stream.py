"""DStream — chunked, pipelined data exchange over the DStore (beyond-paper).

The paper's Get/Put (Table 1) moves every datum as one monolithic blob: a
consumer's fetch cannot even *begin* until the producer's entire output is
written, so the §3.3.2 auto blocking/waking overlap stops at the data layer.
DStream extends the fine-grained optimizations of §3.3 to *chunk*
granularity:

* ``put_stream(node, key)`` returns a :class:`StreamWriter` that publishes
  fixed-size chunks.  Every chunk gets its own directory record (the
  producer's local store holds the bytes; the :class:`StreamDirectory`
  holds per-chunk metadata) and every publish wakes blocked consumers —
  §3.3.2's auto blocking/waking-up applied per chunk.
* ``get_stream(node, key)`` returns a :class:`StreamReader`, a blocking
  iterator: the consumer pulls chunk 0 — receiver-driven, exactly like a
  monolithic Get (§3.3.1/§3.3.4) but per chunk — while the producer is
  still emitting chunk N.  A background prefetcher keeps pulls overlapped
  with the consumer's own processing.
* Duplicate producers (straggler re-issue) **co-write** the stream: chunk
  publication is idempotent per index (first writer of chunk *i* wins, the
  same immutability argument as monolithic first-writer-wins, which already
  presumes deterministic functions), so a duplicate can finish a stream
  that its stalled original never closes and consumers are never wedged.
* On ``close`` the writer also materialises the monolithic value under the
  plain key, so non-streaming consumers (and the engine's sink collection)
  keep working; a reader on a key that was only ever Put monolithically
  falls back to chunking that value locally.
* Fault handling: when a node dies mid-stream (``DStore.fail_node``),
  every stream it owned and had not closed is *aborted*; blocked readers
  raise :class:`StreamBroken` instead of hanging until timeout.
"""

from __future__ import annotations

import queue
import threading
import time
from contextlib import nullcontext
from dataclasses import dataclass, field
from typing import Any, Iterable, Iterator

__all__ = ["StreamBroken", "StreamDirectory", "StreamWriter", "StreamReader",
           "chunk_key", "base_key", "chunk_count", "is_chunk_key",
           "DEFAULT_CHUNK"]

DEFAULT_CHUNK = 1 << 18          # 256 KiB
_PREFETCH_DEPTH = 32             # reader-side bounded chunk queue


_CHUNK_SEP = "::chunk."


def chunk_key(key: str, i: int) -> str:
    """Directory key of one chunk of a stream (immutable, like any key)."""
    return f"{key}{_CHUNK_SEP}{i}"


def base_key(key: str) -> str:
    """Inverse of :func:`chunk_key`: chunk key -> stream key (identity for
    plain keys).  Recovery uses this to map lost *chunk* records back to
    the producer function that must re-run, and DShard's routing tables
    use it so one installed route (the stream key's home) covers every
    chunk of the stream — chunk keys are never routed individually."""
    return key.split(_CHUNK_SEP, 1)[0]


def is_chunk_key(key: str) -> bool:
    """True when ``key`` names one chunk of a stream (router/diagnostics
    helper; avoids leaking the separator constant)."""
    return _CHUNK_SEP in key


def chunk_count(size: int, chunk_size: int = DEFAULT_CHUNK) -> int:
    """Chunks a ``size``-byte stream splits into (at least 1: empty
    streams still emit a terminating chunk record)."""
    if chunk_size <= 0:
        return 1
    return max(1, -(-int(size) // int(chunk_size)))


class StreamBroken(RuntimeError):
    """The producer of a stream failed before closing it."""


@dataclass
class _StreamMeta:
    key: str
    owners: set[str]                          # producing node(s); duplicates
    chunks: dict[int, int] = field(default_factory=dict)   # idx -> size
    total: int | None = None                  # chunk count, set on close
    aborted: bool = False


class StreamDirectory:
    """Directory-service extension holding per-stream/per-chunk metadata.

    Thread-safe; a single condition variable backs every blocking wait (the
    same auto blocking/waking design as :class:`DataDirectoryService`, at
    chunk granularity).  Chunk *bytes* live in the per-node LocalStores
    under :func:`chunk_key` names and move via the normal receiver-driven
    pull path.
    """

    def __init__(self) -> None:
        self._lock = threading.Lock()
        self._cv = threading.Condition(self._lock)
        self._streams: dict[str, _StreamMeta] = {}
        self._plain: set[str] = set()         # keys Put monolithically
        # DCheck hook (see check.py): set via DStore.attach_tracer.  Chunk
        # publishes are recorded by DStore.put_chunk; the directory records
        # the stream-lifecycle events (close/abort) it alone decides.
        self.tracer = None

    # -- producer ----------------------------------------------------------
    def claim(self, key: str, node: str) -> None:
        """Register ``node`` as a producer of the stream.  A duplicate
        (straggler re-issue) becomes a co-writer — chunk publication is
        idempotent per index, safe under the engine's deterministic-function
        premise — so a stalled original cannot wedge consumers.  An aborted
        stream is reset (recovery re-executes the producer)."""
        with self._cv:
            m = self._streams.get(key)
            if m is None or m.aborted:
                self._streams[key] = _StreamMeta(key, {node})
            else:
                m.owners.add(node)
            self._cv.notify_all()

    def publish_chunk(self, key: str, idx: int, size: int) -> None:
        """First writer of chunk ``idx`` wins; later publishes are no-ops."""
        with self._cv:
            self._streams[key].chunks.setdefault(idx, int(size))
            self._cv.notify_all()

    def close(self, key: str, total: int) -> None:
        """Seal the stream at ``total`` chunks (first closer wins)."""
        with self._cv:
            if self.tracer is not None:
                # Every close attempt is recorded (not just the winning
                # one) so divergent co-closer totals are checkable.
                self.tracer.record("stream_close", key, size=total)
            m = self._streams[key]
            if m.total is None:
                m.total = total
            self._cv.notify_all()

    def abort(self, key: str, node: str | None = None) -> None:
        """Producer failure.  With ``node``, only that co-writer withdraws;
        the stream aborts (waking blocked readers with a clean error) when
        no producer remains and it was never closed."""
        with self._cv:
            m = self._streams.get(key)
            if m is None or m.total is not None:
                return
            if node is not None:
                m.owners.discard(node)
                if m.owners:
                    self._cv.notify_all()
                    return
            m.aborted = True
            if self.tracer is not None:
                self.tracer.record("stream_abort", key, node or "")
            self._cv.notify_all()

    def notify_plain(self, key: str) -> None:
        """A monolithic Put happened; wakes ``get_stream`` fallbacks."""
        with self._cv:
            self._plain.add(key)
            self._cv.notify_all()

    def evict_prefix(self, prefix: str) -> None:
        """Instance-scoped eviction: forget every stream (and plain-key
        marker) in a completed instance's namespace.  Chunk *bytes* live in
        the LocalStores and are reclaimed by the caller
        (:meth:`DStore.evict_instance`)."""
        with self._cv:
            for k in [k for k in self._streams if k.startswith(prefix)]:
                del self._streams[k]
            self._plain -= {k for k in self._plain if k.startswith(prefix)}

    def fail_owner(self, node: str) -> None:
        """Fault handling for a dead node.  Streams it co-wrote lose that
        producer; when the last producer of an unclosed stream dies it
        aborts (blocked readers raise :class:`StreamBroken`), and closed
        streams whose last producer died are evicted so a recovery
        re-execution can re-claim and re-publish them."""
        with self._cv:
            for k, m in list(self._streams.items()):
                if node not in m.owners:
                    continue
                m.owners.discard(node)
                if m.owners:
                    continue            # a co-writer is still alive
                if m.total is None:
                    m.aborted = True
                    if self.tracer is not None:
                        self.tracer.record("stream_abort", k, node)
                else:
                    del self._streams[k]
            self._cv.notify_all()

    # -- consumer ----------------------------------------------------------
    def _deadline(self, timeout: float | None) -> float | None:
        return None if timeout is None else time.monotonic() + timeout

    def _remaining(self, deadline: float | None, key: str) -> float | None:
        if deadline is None:
            return None
        remaining = deadline - time.monotonic()
        if remaining <= 0:
            from .dstore import GetTimeout
            raise GetTimeout(f"get_stream({key!r}) timed out")
        return remaining

    def wait_mode(self, key: str, timeout: float | None = None) -> str:
        """Block until ``key`` is either a claimed stream ('stream') or a
        monolithically-Put value ('plain'); streams win ties."""
        deadline = self._deadline(timeout)
        with self._cv:
            while True:
                if key in self._streams:
                    return "stream"
                if key in self._plain:
                    return "plain"
                self._cv.wait(self._remaining(deadline, key))

    def wait_chunk(self, key: str, idx: int,
                   timeout: float | None = None) -> int | None:
        """Block until chunk ``idx`` is published (returns its size) or the
        stream closed below ``idx`` (returns None = end of stream)."""
        deadline = self._deadline(timeout)
        with self._cv:
            while True:
                m = self._streams.get(key)
                if m is not None:
                    if m.aborted:
                        raise StreamBroken(
                            f"stream {key!r}: producer failed before close")
                    if idx in m.chunks:
                        return m.chunks[idx]
                    if m.total is not None and idx >= m.total:
                        return None
                self._cv.wait(self._remaining(deadline, key))


class StreamWriter:
    """Chunked producer handle returned by :meth:`DStore.put_stream`.

    ``write`` buffers bytes and publishes fixed-size chunks as the buffer
    fills; ``close`` flushes the tail chunk, seals the stream, and
    materialises the monolithic value under the plain key.  Usable as a
    context manager.  A duplicate producer (straggler re-issue) co-writes:
    its chunk publishes are idempotent no-ops wherever the original already
    published, and whoever finishes first seals the stream.
    """

    def __init__(self, store: Any, node: str, key: str,
                 chunk_size: int = DEFAULT_CHUNK):
        if chunk_size <= 0:
            raise ValueError(f"chunk_size must be positive, got {chunk_size}")
        self._store = store
        self.node = node
        self.key = key
        self.chunk_size = int(chunk_size)
        self._buf = bytearray()
        self._count = 0
        self._closed = False
        store.streams.claim(key, node)

    def write(self, data: bytes | bytearray | memoryview) -> None:
        if self._closed:
            raise ValueError(f"write to closed stream {self.key!r}")
        self._buf += bytes(data)
        while len(self._buf) >= self.chunk_size:
            self._emit(bytes(self._buf[:self.chunk_size]))
            del self._buf[:self.chunk_size]

    def _emit(self, chunk: bytes) -> None:
        # Chunk bytes live in the local store only (no second copy here);
        # close() re-reads them to build the monolithic twin.
        self._store.put_chunk(self.node, self.key, self._count, chunk)
        self._count += 1

    def abort(self) -> None:
        """This producer failed; the stream breaks when no co-writer
        remains (readers then raise :class:`StreamBroken`)."""
        self._closed = True
        self._store.streams.abort(self.key, self.node)

    def close(self) -> None:
        if self._closed:
            return
        self._closed = True
        if self._buf:
            self._emit(bytes(self._buf))
            self._buf = bytearray()
        self._store.streams.close(self.key, self._count)
        # Monolithic twin for non-streaming Gets / sink collection, built
        # from the chunks already resident in the local store.  If the node
        # was failed mid-stream (chunks wiped under us), surface it as
        # StreamBroken: the engine's retry re-runs the producer, which
        # rewrites every chunk idempotently and closes cleanly.
        local = self._store.stores[self.node]
        try:
            whole = b"".join(local.read(chunk_key(self.key, i))
                             for i in range(self._count))
        except KeyError:
            raise StreamBroken(
                f"stream {self.key!r}: local chunks lost before close "
                f"(node failed mid-stream)") from None
        self._store.put(self.node, self.key, whole)

    def __enter__(self) -> "StreamWriter":
        return self

    def __exit__(self, exc_type, exc, tb) -> None:
        if exc_type is not None:
            self.abort()
        else:
            self.close()


class StreamReader:
    """Blocking chunk iterator returned by :meth:`DStore.get_stream`.

    A background prefetcher pulls chunks (receiver-driven, registering the
    consumer-side replica per chunk) as soon as the producer publishes them,
    bounded to ``_PREFETCH_DEPTH`` chunks of look-ahead, so network pulls
    overlap both the producer's emission and the consumer's processing.
    Falls back to locally chunking a monolithic value when the key was only
    ever Put whole.
    """

    def __init__(self, store: Any, node: str, key: str,
                 timeout: float | None = None, prefetch: bool = True):
        self._store = store
        self.node = node
        self.key = key
        self.timeout = timeout
        self._prefetch = prefetch
        self._queue: queue.Queue | None = None
        self._plain_iter: Iterator[bytes] | None = None
        self._idx = 0
        self._started = False

    # -- iteration ---------------------------------------------------------
    def __iter__(self) -> "StreamReader":
        return self

    def __next__(self) -> Any:
        if not self._started:
            self._start()
        if self._plain_iter is not None:
            return next(self._plain_iter)
        if self._queue is not None:
            item = self._queue.get()
            if item is _EOS:
                self._queue.put(_EOS)        # keep subsequent next() clean
                raise StopIteration
            if isinstance(item, BaseException):
                self._queue.put(item)
                raise item
            return item
        return self._next_sync()

    def read_all(self) -> bytes:
        """Drain the stream and return the concatenated bytes."""
        return b"".join(self)

    # -- internals ---------------------------------------------------------
    def _start(self) -> None:
        self._started = True
        # DScope: the prefetch pump runs on its own thread, so the span
        # context active *here* (the consumer's invocation span) is
        # captured explicitly and re-activated inside the pump — the
        # per-chunk Get spans it emits then parent correctly.
        spans = getattr(self._store, "_spans", None)
        self._span_parent = spans.current() if spans is not None else None
        mode = self._store.streams.wait_mode(self.key, self.timeout)
        if mode == "plain":
            value = self._store.get(self.node, self.key, timeout=self.timeout)
            self._plain_iter = iter(_chunked(value))
            return
        if self._prefetch:
            self._queue = queue.Queue(maxsize=_PREFETCH_DEPTH)
            th = threading.Thread(target=self._pump, daemon=True,
                                  name=f"dstream-pull-{self.key}")
            th.start()

    def _observe_chunk(self, elapsed: float) -> None:
        metrics = getattr(self._store, "_metrics", None)
        if metrics is not None:
            metrics.histogram("stream_chunk_seconds").observe(elapsed)

    def _pump(self) -> None:
        assert self._queue is not None
        spans = getattr(self._store, "_spans", None)
        ctx = spans.activate(self._span_parent) if spans is not None \
            else nullcontext()
        i = 0
        try:
            with ctx:
                while True:
                    t0 = time.monotonic()
                    size = self._store.streams.wait_chunk(self.key, i,
                                                          self.timeout)
                    if size is None:
                        self._queue.put(_EOS)
                        return
                    data = self._store.get(self.node,
                                           chunk_key(self.key, i),
                                           timeout=self.timeout)
                    self._observe_chunk(time.monotonic() - t0)
                    self._queue.put(data)
                    i += 1
        except BaseException as exc:          # noqa: BLE001 - hand to reader
            self._queue.put(exc)

    def _next_sync(self) -> Any:
        t0 = time.monotonic()
        size = self._store.streams.wait_chunk(self.key, self._idx,
                                              self.timeout)
        if size is None:
            raise StopIteration
        data = self._store.get(self.node, chunk_key(self.key, self._idx),
                               timeout=self.timeout)
        self._observe_chunk(time.monotonic() - t0)
        self._idx += 1
        return data


class _EOSType:
    __slots__ = ()

    def __repr__(self) -> str:               # pragma: no cover - debug aid
        return "<end-of-stream>"


_EOS = _EOSType()


def _chunked(value: Any, chunk: int = DEFAULT_CHUNK) -> Iterable[Any]:
    """Monolithic-fallback chunking: bytes split at ``chunk``; anything
    else is delivered as a single-item stream."""
    if isinstance(value, (bytes, bytearray, memoryview)):
        b = bytes(value)
        return (b[i:i + chunk] for i in range(0, len(b), chunk))
    return (value,)
