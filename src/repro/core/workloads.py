"""Paper benchmark workflows (Table 3): WC, FP, Cyc, Epi, Gen, Soy.

The originals come from FaaSFlow's benchmark suite and the Pegasus
scientific-workflow gallery.  We regenerate the same DAG *shapes* (stage
structure, fan-out, >40 functions for the scientific apps, >50 for Genome)
with deterministic execution times and output sizes in the ranges the paper
reports ("the output of a single function is at most tens of MB", §4).

Each generator returns a :class:`~repro.core.dag.Workflow`; exec times and
sizes are seeded by a simple LCG so every run of every experiment sees the
exact same workload.
"""

from __future__ import annotations

from .dag import FunctionSpec, Workflow

__all__ = ["BENCHMARKS", "make_workflow", "wordcount", "file_processing",
           "cycles", "epigenomics", "genome", "soykb",
           "wordcount_large", "genome_large",
           "serving_chain", "serving_fanout"]

MB = 1 << 20


class _Det:
    """Tiny deterministic LCG so workloads never depend on global RNG."""

    def __init__(self, seed: int):
        self.s = (seed * 2654435761 + 0x9E3779B9) & 0xFFFFFFFF

    def next(self) -> float:
        self.s = (1103515245 * self.s + 12345) & 0x7FFFFFFF
        return self.s / 0x7FFFFFFF

    def uniform(self, lo: float, hi: float) -> float:
        return lo + (hi - lo) * self.next()


def _fn(name, inputs, outputs, t, sizes, cpu=1.0):
    return FunctionSpec(name=name, inputs=tuple(inputs),
                        outputs=tuple(outputs), exec_time=t,
                        output_sizes=sizes, cpu=cpu)


# ----------------------------------------------------------------------
def wordcount(shards: int = 16) -> Workflow:
    """WC: split -> count.{i} -> merge (map/reduce, real-world app)."""
    rng = _Det(101)
    fns = [_fn("split", ["corpus"], [f"shard.{i}" for i in range(shards)],
               0.6, {f"shard.{i}": int(3 * MB) for i in range(shards)})]
    for i in range(shards):
        fns.append(_fn(f"count.{i}", [f"shard.{i}"], [f"wc.{i}"],
                       rng.uniform(0.5, 1.2), {f"wc.{i}": int(1 * MB)}))
    fns.append(_fn("merge", [f"wc.{i}" for i in range(shards)], ["result"],
                   0.8, {"result": int(1 * MB)}))
    return Workflow("WC", fns, {"corpus": int(32 * MB)})


def file_processing(files: int = 8) -> Workflow:
    """FP: per-file chains (extract->transform->compress) then archive."""
    rng = _Det(202)
    fns = [_fn("index", ["bundle"], [f"file.{i}" for i in range(files)],
               0.5, {f"file.{i}": int(4 * MB) for i in range(files)})]
    for i in range(files):
        fns.append(_fn(f"extract.{i}", [f"file.{i}"], [f"raw.{i}"],
                       rng.uniform(0.4, 0.9), {f"raw.{i}": int(5 * MB)}))
        fns.append(_fn(f"transform.{i}", [f"raw.{i}"], [f"tf.{i}"],
                       rng.uniform(0.8, 1.6), {f"tf.{i}": int(4 * MB)}))
        fns.append(_fn(f"compress.{i}", [f"tf.{i}"], [f"zip.{i}"],
                       rng.uniform(0.5, 1.0), {f"zip.{i}": int(2 * MB)}))
    fns.append(_fn("archive", [f"zip.{i}" for i in range(files)],
                   ["archive"], 0.9, {"archive": int(8 * MB)}))
    return Workflow("FP", fns, {"bundle": int(24 * MB)})


def cycles(crops: int = 12) -> Workflow:
    """Cyc: Pegasus Cycles (agroecosystem) — widest data exchange.

    Per-crop chain of 3 simulations feeding a cross-crop analysis layer and
    a summarizing tail.  40+ functions, large outputs (the paper's only
    CFlow timeout at 50 MB/s is Cyc — data volume dominates).
    """
    rng = _Det(303)
    fns = [_fn("prepare", ["params"],
               [f"soil.{i}" for i in range(crops)], 0.7,
               {f"soil.{i}": int(6 * MB) for i in range(crops)})]
    for i in range(crops):
        fns.append(_fn(f"baseline.{i}", [f"soil.{i}"], [f"base.{i}"],
                       rng.uniform(1.2, 2.2), {f"base.{i}": int(14 * MB)}))
        fns.append(_fn(f"cycles.{i}", [f"base.{i}"], [f"cyc.{i}"],
                       rng.uniform(1.5, 2.8), {f"cyc.{i}": int(16 * MB)}))
        fns.append(_fn(f"fertilizer.{i}", [f"cyc.{i}"], [f"fert.{i}"],
                       rng.uniform(1.0, 2.0), {f"fert.{i}": int(10 * MB)}))
    for j in range(4):
        ins = [f"fert.{i}" for i in range(crops) if i % 4 == j]
        fns.append(_fn(f"analysis.{j}", ins, [f"ana.{j}"],
                       rng.uniform(1.2, 2.0), {f"ana.{j}": int(6 * MB)}))
    fns.append(_fn("summarize", [f"ana.{j}" for j in range(4)], ["summary"],
                   1.0, {"summary": int(4 * MB)}))
    fns.append(_fn("visualize", ["summary"], ["plots"], 0.8,
                   {"plots": int(6 * MB)}))
    return Workflow("Cyc", fns, {"params": int(2 * MB)})


def epigenomics(lanes: int = 12) -> Workflow:
    """Epi: Pegasus Epigenomics — deep per-lane chains then merge tail."""
    rng = _Det(404)
    fns = [_fn("fastq_split", ["fastq"],
               [f"chunk.{i}" for i in range(lanes)], 0.8,
               {f"chunk.{i}": int(3 * MB) for i in range(lanes)})]
    for i in range(lanes):
        fns.append(_fn(f"filter.{i}", [f"chunk.{i}"], [f"filt.{i}"],
                       rng.uniform(0.6, 1.2), {f"filt.{i}": int(3 * MB)}))
        fns.append(_fn(f"sol2sanger.{i}", [f"filt.{i}"], [f"sang.{i}"],
                       rng.uniform(0.4, 0.8), {f"sang.{i}": int(3 * MB)}))
        fns.append(_fn(f"fastq2bfq.{i}", [f"sang.{i}"], [f"bfq.{i}"],
                       rng.uniform(0.4, 0.8), {f"bfq.{i}": int(2 * MB)}))
        fns.append(_fn(f"map.{i}", [f"bfq.{i}", "ref_genome"], [f"bam.{i}"],
                       rng.uniform(1.4, 2.4), {f"bam.{i}": int(4 * MB)}))
    fns.append(_fn("map_merge", [f"bam.{i}" for i in range(lanes)],
                   ["merged"], 1.2, {"merged": int(10 * MB)}))
    fns.append(_fn("maq_index", ["merged"], ["index"], 0.9,
                   {"index": int(4 * MB)}))
    fns.append(_fn("pileup", ["index"], ["pileup"], 1.1,
                   {"pileup": int(4 * MB)}))
    return Workflow("Epi", fns, {"fastq": int(40 * MB),
                                 "ref_genome": int(8 * MB)})


def genome(individuals: int = 30, analyses: int = 20) -> Workflow:
    """Gen: 1000Genome — >50 functions (§5.2), large exchanged data."""
    rng = _Det(505)
    fns = []
    for i in range(individuals):
        fns.append(_fn(f"individuals.{i}", ["chromosome"], [f"ind.{i}"],
                       rng.uniform(1.0, 2.0), {f"ind.{i}": int(2 * MB)}))
    fns.append(_fn("individuals_merge", [f"ind.{i}" for i in range(individuals)],
                   ["merged_ind"], 1.6, {"merged_ind": int(4 * MB)}))
    fns.append(_fn("sifting", ["chromosome"], ["sifted"], 1.2,
                   {"sifted": int(2 * MB)}))
    half = analyses // 2
    for j in range(half):
        fns.append(_fn(f"mutation_overlap.{j}", ["merged_ind", "sifted"],
                       [f"mut.{j}"], rng.uniform(1.0, 1.8),
                       {f"mut.{j}": int(1 * MB)}))
    for j in range(analyses - half):
        fns.append(_fn(f"frequency.{j}", ["merged_ind", "sifted"],
                       [f"freq.{j}"], rng.uniform(1.2, 2.0),
                       {f"freq.{j}": int(1 * MB)}))
    fns.append(_fn("report", [f"mut.{j}" for j in range(half)] +
                   [f"freq.{j}" for j in range(analyses - half)],
                   ["report"], 0.9, {"report": int(1 * MB)}))
    return Workflow("Gen", fns, {"chromosome": int(16 * MB)})


def soykb(samples: int = 7, chromosomes: int = 4) -> Workflow:
    """Soy: Pegasus SoyKB — deep per-sample chains + joint genotyping."""
    rng = _Det(606)
    fns = []
    stages = ["align", "sort", "dedup", "add_rg", "realign", "haplotype"]
    for i in range(samples):
        prev_key = "reads"
        for s, stage in enumerate(stages):
            out = f"{stage}.{i}"
            fns.append(_fn(f"{stage}.{i}", [prev_key], [out],
                           rng.uniform(0.7, 1.5), {out: int(3 * MB)}))
            prev_key = out
    gvcfs = [f"haplotype.{i}" for i in range(samples)]
    for c in range(chromosomes):
        fns.append(_fn(f"genotype.{c}", gvcfs, [f"geno.{c}"],
                       rng.uniform(1.2, 2.2), {f"geno.{c}": int(3 * MB)}))
    fns.append(_fn("combine", [f"geno.{c}" for c in range(chromosomes)],
                   ["combined"], 1.0, {"combined": int(5 * MB)}))
    fns.append(_fn("filtering", ["combined"], ["filtered"], 0.8,
                   {"filtered": int(3 * MB)}))
    fns.append(_fn("merge", ["filtered"], ["final"], 0.6,
                   {"final": int(2 * MB)}))
    return Workflow("Soy", fns, {"reads": int(20 * MB)})


# ----------------------------------------------------------------------
# DStream stress variants: same DAG shapes, output sizes scaled so every
# edge carries many stream chunks (SimConfig.stream_chunk defaults to 1 MB)
# and inter-node transfer time rivals execution time — the regime where
# chunked pipelining (overlap of production and transfer) has headroom.

def wordcount_large(shards: int = 8) -> Workflow:
    """WC-L: map/reduce with tens-of-MB shards (chunk-aware WC variant)."""
    rng = _Det(707)
    fns = [_fn("split", ["corpus"], [f"shard.{i}" for i in range(shards)],
               1.2, {f"shard.{i}": int(24 * MB) for i in range(shards)})]
    for i in range(shards):
        fns.append(_fn(f"count.{i}", [f"shard.{i}"], [f"wc.{i}"],
                       rng.uniform(0.8, 1.6), {f"wc.{i}": int(12 * MB)}))
    fns.append(_fn("merge", [f"wc.{i}" for i in range(shards)], ["result"],
                   1.0, {"result": int(8 * MB)}))
    return Workflow("WC-L", fns, {"corpus": int(64 * MB)})


def genome_large(individuals: int = 12, analyses: int = 8) -> Workflow:
    """Gen-L: 1000Genome with a fat shared intermediate (chunk-aware).

    ``merged_ind`` (32 MB) fans out to every analysis function, so the
    monolithic plane serialises a long transfer per remote consumer while
    DStream starts every consumer on chunk 0 during the merge."""
    rng = _Det(808)
    fns = []
    for i in range(individuals):
        fns.append(_fn(f"individuals.{i}", ["chromosome"], [f"ind.{i}"],
                       rng.uniform(1.0, 2.0), {f"ind.{i}": int(8 * MB)}))
    fns.append(_fn("individuals_merge",
                   [f"ind.{i}" for i in range(individuals)],
                   ["merged_ind"], 2.0, {"merged_ind": int(32 * MB)}))
    fns.append(_fn("sifting", ["chromosome"], ["sifted"], 1.4,
                   {"sifted": int(16 * MB)}))
    half = analyses // 2
    for j in range(half):
        fns.append(_fn(f"mutation_overlap.{j}", ["merged_ind", "sifted"],
                       [f"mut.{j}"], rng.uniform(1.0, 1.8),
                       {f"mut.{j}": int(4 * MB)}))
    for j in range(analyses - half):
        fns.append(_fn(f"frequency.{j}", ["merged_ind", "sifted"],
                       [f"freq.{j}"], rng.uniform(1.2, 2.0),
                       {f"freq.{j}": int(4 * MB)}))
    fns.append(_fn("report", [f"mut.{j}" for j in range(half)] +
                   [f"freq.{j}" for j in range(analyses - half)],
                   ["report"], 1.0, {"report": int(2 * MB)}))
    return Workflow("Gen-L", fns, {"chromosome": int(32 * MB)})


# ----------------------------------------------------------------------
# Serving workloads: small request-scale DAGs with *real callables* so the
# threaded DServe layer (repro.core.serve) can execute them end-to-end.
# Execution sleeps `exec_time` and emits a deterministic digest-derived
# payload, so differential/serving tests can assert exact bytes while the
# container-pool dynamics (cold boot vs prewarm) stay observable.

def _digest_fn(out_key: str, exec_time: float, payload: int):
    import hashlib
    import time as _time

    def fn(**kw):
        if exec_time:
            _time.sleep(exec_time)
        h = hashlib.sha256(out_key.encode())
        for k in sorted(kw):
            v = kw[k]
            h.update(k.encode())
            h.update(v if isinstance(v, (bytes, bytearray))
                     else repr(v).encode())
        d = h.digest()
        return {out_key: (d * (payload // len(d) + 1))[:payload]}
    return fn


def serving_chain(stages: int = 4, *, exec_time: float = 0.03,
                  cold_start: float = 0.12,
                  payload: int = 64 * 1024) -> Workflow:
    """Srv: a latency-sensitive request pipeline (stage0 -> ... -> stageN).

    The worst case for controlflow cold starts: every stage's container
    boot sits on the critical path unless it was prewarmed when its
    precursor launched (paper §3.2)."""
    fns = []
    prev = "request"
    for i in range(stages):
        out = f"s{i}"
        fns.append(FunctionSpec(
            f"stage{i}", inputs=(prev,), outputs=(out,),
            fn=_digest_fn(out, exec_time, payload), exec_time=exec_time,
            output_sizes={out: payload}, cold_start=cold_start))
        prev = out
    return Workflow("Srv", fns, {"request": 1024})


def serving_fanout(workers: int = 4, *, exec_time: float = 0.03,
                   cold_start: float = 0.12,
                   payload: int = 32 * 1024) -> Workflow:
    """SrvF: scatter/gather request shape (route -> worker.{i} -> merge)."""
    fns = [FunctionSpec(
        "route", inputs=("request",),
        outputs=tuple(f"part.{i}" for i in range(workers)),
        fn=_digest_multi(
            [f"part.{i}" for i in range(workers)], exec_time, payload),
        exec_time=exec_time,
        output_sizes={f"part.{i}": payload for i in range(workers)},
        cold_start=cold_start)]
    for i in range(workers):
        fns.append(FunctionSpec(
            f"worker.{i}", inputs=(f"part.{i}",), outputs=(f"res.{i}",),
            fn=_digest_fn(f"res.{i}", exec_time, payload),
            exec_time=exec_time, output_sizes={f"res.{i}": payload},
            cold_start=cold_start))
    fns.append(FunctionSpec(
        "merge", inputs=tuple(f"res.{i}" for i in range(workers)),
        outputs=("response",),
        fn=_digest_fn("response", exec_time, payload),
        exec_time=exec_time, output_sizes={"response": payload},
        cold_start=cold_start))
    return Workflow("SrvF", fns, {"request": 1024})


def _digest_multi(out_keys: list[str], exec_time: float, payload: int):
    fns = {k: _digest_fn(k, 0.0, payload) for k in out_keys}
    import time as _time

    def fn(**kw):
        if exec_time:
            _time.sleep(exec_time)
        out = {}
        for k, f in fns.items():
            out.update(f(**kw))
        return out
    return fn


BENCHMARKS = {
    "WC": wordcount,
    "FP": file_processing,
    "Cyc": cycles,
    "Epi": epigenomics,
    "Gen": genome,
    "Soy": soykb,
    "WC-L": wordcount_large,
    "Gen-L": genome_large,
    "Srv": serving_chain,
    "SrvF": serving_fanout,
}


def make_workflow(name: str) -> Workflow:
    return BENCHMARKS[name]()
