"""Data pipeline: deterministic token streams with prefetch overlap."""

from .pipeline import (DataConfig, SyntheticLM, FileTokenSource,
                       Prefetcher, make_pipeline)

__all__ = ["DataConfig", "SyntheticLM", "FileTokenSource", "Prefetcher",
           "make_pipeline"]
