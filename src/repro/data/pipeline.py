"""Training data pipeline.

Sources:
* :class:`SyntheticLM` — deterministic Zipf-ish token stream (seeded; every
  restart resumes exactly, keyed by global step — required for the
  checkpoint/restart fault-tolerance path to be bitwise reproducible).
* :class:`FileTokenSource` — memory-mapped ``.bin`` of uint16/uint32 tokens
  with epoch shuffling by block permutation.

:class:`Prefetcher` runs the source on a background thread with a bounded
queue — host-side batch assembly overlaps device compute (the data-pipeline
instance of the paper's dataflow-invocation overlap; the training
orchestrator schedules it as a DFlow function, see runtime/orchestrator).
"""

from __future__ import annotations

import dataclasses
import queue
import threading
from typing import Iterator, Mapping

import numpy as np

__all__ = ["DataConfig", "SyntheticLM", "FileTokenSource", "Prefetcher",
           "make_pipeline"]


@dataclasses.dataclass(frozen=True)
class DataConfig:
    vocab: int
    seq_len: int
    global_batch: int
    seed: int = 0
    source: str = "synthetic"       # "synthetic" | path to .bin
    prefetch: int = 2


class SyntheticLM:
    """Deterministic pseudo-natural token stream.

    Tokens follow a Zipf-like marginal with a short-range Markov blend so
    the loss actually decreases during the example runs; ``batch_at(step)``
    is a pure function of (seed, step) — restart-safe."""

    def __init__(self, cfg: DataConfig):
        self.cfg = cfg
        base = np.random.default_rng(cfg.seed)
        ranks = np.arange(1, cfg.vocab + 1, dtype=np.float64)
        probs = 1.0 / ranks ** 1.1
        self._probs = probs / probs.sum()
        self._mix = base.integers(0, cfg.vocab, size=4096)

    def batch_at(self, step: int) -> Mapping[str, np.ndarray]:
        cfg = self.cfg
        rng = np.random.default_rng((cfg.seed << 20) ^ step)
        iid = rng.choice(cfg.vocab, size=(cfg.global_batch, cfg.seq_len + 1),
                         p=self._probs)
        # short-range structure: every other token repeats a mixed copy of
        # its predecessor (gives the model something learnable).
        mixed = self._mix[iid[:, :-1] % self._mix.size]
        toks = iid.copy()
        toks[:, 1::2] = np.where((iid[:, 1::2] % 3) == 0,
                                 mixed[:, ::2][:, :toks[:, 1::2].shape[1]],
                                 iid[:, 1::2])
        return {"tokens": toks.astype(np.int32)}

    def __iter__(self) -> Iterator[Mapping[str, np.ndarray]]:
        step = 0
        while True:
            yield self.batch_at(step)
            step += 1


class FileTokenSource:
    """Memory-mapped flat token file -> fixed-length sequences."""

    def __init__(self, cfg: DataConfig, path: str, dtype=np.uint16):
        self.cfg = cfg
        self.tokens = np.memmap(path, dtype=dtype, mode="r")
        n_seq = (len(self.tokens) - 1) // cfg.seq_len
        if n_seq < 1:
            raise ValueError("token file shorter than one sequence")
        self.n_seq = n_seq

    def batch_at(self, step: int) -> Mapping[str, np.ndarray]:
        cfg = self.cfg
        rng = np.random.default_rng((cfg.seed << 20) ^ (step // self.n_seq))
        perm = rng.permutation(self.n_seq)
        out = np.empty((cfg.global_batch, cfg.seq_len + 1), np.int32)
        for i in range(cfg.global_batch):
            j = perm[(step * cfg.global_batch + i) % self.n_seq]
            start = j * cfg.seq_len
            out[i] = self.tokens[start:start + cfg.seq_len + 1]
        return {"tokens": out}

    def __iter__(self):
        step = 0
        while True:
            yield self.batch_at(step)
            step += 1


class Prefetcher:
    """Background-thread prefetch with a bounded queue (host overlap)."""

    _STOP = object()

    def __init__(self, source, start_step: int = 0, depth: int = 2):
        self.source = source
        self._q: queue.Queue = queue.Queue(maxsize=depth)
        self._step = start_step
        self._stop = threading.Event()
        self._thread = threading.Thread(target=self._run, daemon=True)
        self._thread.start()

    def _run(self):
        step = self._step
        while not self._stop.is_set():
            batch = self.source.batch_at(step)
            while not self._stop.is_set():
                try:
                    self._q.put((step, batch), timeout=0.1)
                    break
                except queue.Full:
                    continue
            step += 1

    def next(self) -> tuple[int, Mapping[str, np.ndarray]]:
        return self._q.get()

    def close(self):
        self._stop.set()
        try:
            while True:
                self._q.get_nowait()
        except queue.Empty:
            pass
        self._thread.join(timeout=2)


def make_pipeline(cfg: DataConfig, start_step: int = 0) -> Prefetcher:
    if cfg.source == "synthetic":
        src = SyntheticLM(cfg)
    else:
        src = FileTokenSource(cfg, cfg.source)
    return Prefetcher(src, start_step=start_step, depth=cfg.prefetch)
