"""Pallas TPU kernels for the compute hot-spots of the assigned archs.

The paper's contribution is data-plane/scheduler level (no kernel of its
own), so kernels/ covers the assigned architectures' hot loops, each with an
``ops.py`` jit wrapper and a ``ref.py`` pure-jnp oracle:

* ``flash_attention``  — blocked online-softmax attention (train/prefill),
  causal + GQA-aware, VMEM-tiled, MXU-aligned.
* ``decode_attention`` — streaming single-token attention against a long KV
  cache (decode_32k / long_500k shapes), split over KV blocks.
* ``ssd``              — Mamba-2 SSD chunked scan (intra-chunk dual form +
  carried recurrent state).

Kernels target TPU (``pl.pallas_call`` + ``BlockSpec``); on this CPU-only
container they are validated with ``interpret=True`` against the oracles.
The XLA model paths default to the jnp implementations; configs can opt in
with ``attention_impl="pallas"`` on TPU.
"""
