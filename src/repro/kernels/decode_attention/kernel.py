"""Flash-decode Pallas TPU kernel: one new token vs a long KV cache.

The decode shapes (decode_32k: 32k keys × 128 requests; long_500k: 524k
keys) are bandwidth-bound: the kernel streams K/V blocks from HBM through
VMEM once, carrying the online-softmax state in scratch, and masks the tail
beyond the cache's valid ``length`` (scalar-prefetched so the same compiled
kernel serves any fill level).

Layout: q (B, Hk, G, D) — the G query rows per KV head form the matmul's M
dimension (M=G·1; for GQA groups of 6–8 this still feeds the MXU better
than one row, and B·Hk grid parallelism covers the chip).
Grid: (B, Hk, nk), nk innermost/sequential.
"""

from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

__all__ = ["decode_attention_kernel", "decode_attention_pallas"]

NEG_INF = -1e30


def decode_attention_kernel(length_ref, q_ref, k_ref, v_ref, o_ref,
                            m_scr, l_scr, acc_scr, *,
                            sm_scale: float, block_k: int,
                            num_kv_blocks: int):
    ik = pl.program_id(2)
    G, D = q_ref.shape
    length = length_ref[0]

    @pl.when(ik == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    k_start = ik * block_k
    # Skip blocks entirely beyond the valid region.
    @pl.when(k_start < length)
    def _compute():
        q = q_ref[...]                                         # (G, D)
        k = k_ref[...]                                         # (bk, D)
        v = v_ref[...]
        s = jax.lax.dot_general(
            q, k, (((1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32) * sm_scale     # (G, bk)
        kpos = k_start + jax.lax.broadcasted_iota(jnp.int32, (G, block_k), 1)
        s = jnp.where(kpos < length, s, NEG_INF)
        m_prev = m_scr[...]
        m_new = jnp.maximum(m_prev, s.max(axis=1))
        p = jnp.exp(s - m_new[:, None])
        corr = jnp.exp(m_prev - m_new)
        l_scr[...] = l_scr[...] * corr + p.sum(axis=1)
        m_scr[...] = m_new
        pv = jax.lax.dot_general(
            p.astype(v.dtype), v, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)
        acc_scr[...] = acc_scr[...] * corr[:, None] + pv

    @pl.when(ik == num_kv_blocks - 1)
    def _finalize():
        denom = jnp.maximum(l_scr[...], 1e-30)
        o_ref[...] = (acc_scr[...] / denom[:, None]).astype(o_ref.dtype)


def decode_attention_pallas(q: jax.Array, k: jax.Array, v: jax.Array,
                            length: jax.Array, *, block_k: int = 512,
                            interpret: bool = False) -> jax.Array:
    """q: (B, Hk, G, D); k, v: (B, Hk, L, D); length: () int32.

    Returns (B, Hk, G, D)."""
    B, Hk, G, D = q.shape
    L = k.shape[2]
    block_k = min(block_k, L)
    if L % block_k:
        raise ValueError(f"cache len {L} % block_k {block_k}")
    nk = L // block_k
    sm_scale = 1.0 / math.sqrt(D)

    kernel = functools.partial(decode_attention_kernel, sm_scale=sm_scale,
                               block_k=block_k, num_kv_blocks=nk)
    length_arr = jnp.asarray(length, jnp.int32).reshape(1)

    return pl.pallas_call(
        kernel,
        grid=(B, Hk, nk),
        in_specs=[
            pl.BlockSpec(memory_space=pltpu.SMEM),
            pl.BlockSpec((None, None, G, D), lambda b, h, ik: (b, h, 0, 0)),
            pl.BlockSpec((None, None, block_k, D),
                         lambda b, h, ik: (b, h, ik, 0)),
            pl.BlockSpec((None, None, block_k, D),
                         lambda b, h, ik: (b, h, ik, 0)),
        ],
        out_specs=pl.BlockSpec((None, None, G, D),
                               lambda b, h, ik: (b, h, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((B, Hk, G, D), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((G,), jnp.float32),
            pltpu.VMEM((G,), jnp.float32),
            pltpu.VMEM((G, D), jnp.float32),
        ],
        interpret=interpret,
    )(length_arr, q, k, v)
