"""Jit'd public wrapper for decode attention (model-layout adapter)."""

from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from .kernel import decode_attention_pallas
from .ref import decode_attention_ref

__all__ = ["decode_attention"]


@partial(jax.jit, static_argnames=("block_k", "interpret", "use_kernel"))
def decode_attention(q: jax.Array, k: jax.Array, v: jax.Array,
                     length: jax.Array, *, block_k: int = 512,
                     interpret: bool = False,
                     use_kernel: bool = True) -> jax.Array:
    """Model layout: q (B, 1, H, D); k, v (B, L, Hk, D) → (B, 1, H, D)."""
    B, Sq, H, D = q.shape
    if Sq != 1:
        raise ValueError("decode expects a single query token")
    Hk = k.shape[2]
    G = H // Hk
    qg = q[:, 0].reshape(B, Hk, G, D)
    kg = k.transpose(0, 2, 1, 3)
    vg = v.transpose(0, 2, 1, 3)
    if use_kernel:
        out = decode_attention_pallas(qg, kg, vg, length, block_k=block_k,
                                      interpret=interpret)
    else:
        out = decode_attention_ref(qg, kg, vg, length)
    return out.reshape(B, 1, H, D)
