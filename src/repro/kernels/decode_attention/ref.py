"""Pure-jnp oracle for the decode attention kernel."""

from __future__ import annotations

import math

import jax
import jax.numpy as jnp

__all__ = ["decode_attention_ref"]


def decode_attention_ref(q: jax.Array, k: jax.Array, v: jax.Array,
                         length: jax.Array) -> jax.Array:
    """q: (B, Hk, G, D); k, v: (B, Hk, L, D); mask keys >= length."""
    D = q.shape[-1]
    L = k.shape[2]
    s = jnp.einsum("bhgd,bhkd->bhgk", q.astype(jnp.float32),
                   k.astype(jnp.float32)) / math.sqrt(D)
    valid = jnp.arange(L) < length
    s = jnp.where(valid[None, None, None], s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bhgk,bhkd->bhgd", p, v.astype(jnp.float32))
    return out.astype(q.dtype)
