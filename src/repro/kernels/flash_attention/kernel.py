"""Flash attention Pallas TPU kernel (GQA-native, causal-skipping).

Layout: q is (B, Hk, G, Sq, D) — GQA groups folded next to the query rows so
K/V are *never* repeated; each kernel invocation reshapes its (G, bq, D)
query tile to a (G·bq, D) matrix, which keeps both matmuls MXU-shaped
((G·bq, D) @ (D, bk) and (G·bq, bk) @ (bk, D)).

Grid: (B, Hk, nq, nk) with nk innermost — TPU executes the last grid axis
sequentially on a core, so the online-softmax running state (m, l, acc)
lives in VMEM scratch and is carried across the nk steps; the output tile is
written once on the final visited kv block.  Fully-masked causal tiles are
skipped with ``@pl.when`` (the causal FLOP savings the XLA path cannot
express — see DESIGN.md roofline notes).

VMEM working set per step: q tile G·bq·D + k/v tiles 2·bk·D + scores
G·bq·bk + acc G·bq·D (fp32) — e.g. G=8, bq=bk=128, D=128 → ~1.3 MB, far
under the ~16 MB v5e VMEM budget; block sizes are parameters so the sweep
test exercises several.
"""

from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

__all__ = ["flash_attention_kernel", "flash_attention_pallas"]

NEG_INF = -1e30


def flash_attention_kernel(q_ref, k_ref, v_ref, o_ref,
                           m_scr, l_scr, acc_scr, *,
                           causal: bool, sm_scale: float,
                           block_q: int, block_k: int,
                           num_kv_blocks: int):
    """One (b, hk, iq, ik) grid step."""
    iq = pl.program_id(2)
    ik = pl.program_id(3)

    G = q_ref.shape[0]
    D = q_ref.shape[2]
    rows = G * block_q

    @pl.when(ik == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    # Causal: tile is live unless every q-row precedes every k-column.
    q_start = iq * block_q
    k_start = ik * block_k
    live = (q_start + block_q - 1 >= k_start) if causal else True

    @pl.when(live)
    def _compute():
        q = q_ref[...].reshape(rows, D)                       # (G·bq, D)
        k = k_ref[...]                                        # (bk, D)
        v = v_ref[...]                                        # (bk, D)
        s = jax.lax.dot_general(
            q, k, (((1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32) * sm_scale    # (G·bq, bk)
        if causal:
            rq = jax.lax.broadcasted_iota(jnp.int32, (rows, block_k), 0)
            qpos = q_start + rq % block_q                     # row = g·bq+q
            kpos = k_start + jax.lax.broadcasted_iota(
                jnp.int32, (rows, block_k), 1)
            s = jnp.where(qpos >= kpos, s, NEG_INF)
        m_prev = m_scr[...]
        l_prev = l_scr[...]
        m_new = jnp.maximum(m_prev, s.max(axis=1))
        p = jnp.exp(s - m_new[:, None])
        corr = jnp.exp(m_prev - m_new)
        l_scr[...] = l_prev * corr + p.sum(axis=1)
        m_scr[...] = m_new
        pv = jax.lax.dot_general(
            p.astype(v.dtype), v, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)               # (G·bq, D)
        acc_scr[...] = acc_scr[...] * corr[:, None] + pv

    @pl.when(ik == num_kv_blocks - 1)
    def _finalize():
        denom = jnp.maximum(l_scr[...], 1e-30)
        out = (acc_scr[...] / denom[:, None]).astype(o_ref.dtype)
        o_ref[...] = out.reshape(G, block_q, D)


def flash_attention_pallas(q: jax.Array, k: jax.Array, v: jax.Array, *,
                           causal: bool = True, block_q: int = 128,
                           block_k: int = 128,
                           interpret: bool = False) -> jax.Array:
    """q: (B, Hk, G, Sq, D); k, v: (B, Hk, Skv, D) → (B, Hk, G, Sq, D)."""
    B, Hk, G, Sq, D = q.shape
    Skv = k.shape[2]
    block_q = min(block_q, Sq)
    block_k = min(block_k, Skv)
    if Sq % block_q or Skv % block_k:
        raise ValueError("sequence not divisible by block size")
    nq, nk = Sq // block_q, Skv // block_k
    sm_scale = 1.0 / math.sqrt(D)

    kernel = functools.partial(
        flash_attention_kernel, causal=causal, sm_scale=sm_scale,
        block_q=block_q, block_k=block_k, num_kv_blocks=nk)

    grid = (B, Hk, nq, nk)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((None, None, G, block_q, D),
                         lambda b, h, iq, ik: (b, h, 0, iq, 0)),
            pl.BlockSpec((None, None, block_k, D),
                         lambda b, h, iq, ik: (b, h, ik, 0)),
            pl.BlockSpec((None, None, block_k, D),
                         lambda b, h, iq, ik: (b, h, ik, 0)),
        ],
        out_specs=pl.BlockSpec((None, None, G, block_q, D),
                               lambda b, h, iq, ik: (b, h, 0, iq, 0)),
        out_shape=jax.ShapeDtypeStruct(q.shape, q.dtype),
        scratch_shapes=[
            pltpu.VMEM((G * block_q,), jnp.float32),
            pltpu.VMEM((G * block_q,), jnp.float32),
            pltpu.VMEM((G * block_q, D), jnp.float32),
        ],
        interpret=interpret,
    )(q, k, v)
