"""Jit'd public wrapper for flash attention (model-layout adapter)."""

from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from .kernel import flash_attention_pallas
from .ref import flash_attention_ref

__all__ = ["flash_attention"]


@partial(jax.jit, static_argnames=("causal", "block_q", "block_k",
                                   "interpret", "use_kernel"))
def flash_attention(q: jax.Array, k: jax.Array, v: jax.Array, *,
                    causal: bool = True, block_q: int = 128,
                    block_k: int = 128, interpret: bool = False,
                    use_kernel: bool = True) -> jax.Array:
    """Model layout adapter: q (B, Sq, H, D); k, v (B, Skv, Hk, D).

    Folds GQA groups, calls the Pallas kernel (or the oracle when
    ``use_kernel=False``), and restores (B, Sq, H, D).
    """
    B, Sq, H, D = q.shape
    Hk = k.shape[2]
    G = H // Hk
    qg = q.reshape(B, Sq, Hk, G, D).transpose(0, 2, 3, 1, 4)  # (B,Hk,G,Sq,D)
    kg = k.transpose(0, 2, 1, 3)                              # (B,Hk,Skv,D)
    vg = v.transpose(0, 2, 1, 3)
    if use_kernel:
        out = flash_attention_pallas(qg, kg, vg, causal=causal,
                                     block_q=block_q, block_k=block_k,
                                     interpret=interpret)
    else:
        out = flash_attention_ref(qg, kg, vg, causal=causal)
    return out.transpose(0, 3, 1, 2, 4).reshape(B, Sq, H, D)
