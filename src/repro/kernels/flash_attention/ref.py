"""Pure-jnp oracle for the flash attention kernel."""

from __future__ import annotations

import math

import jax
import jax.numpy as jnp

__all__ = ["flash_attention_ref"]


def flash_attention_ref(q: jax.Array, k: jax.Array, v: jax.Array, *,
                        causal: bool = True) -> jax.Array:
    """q: (B, Hk, G, Sq, D); k, v: (B, Hk, Skv, D) — exact softmax in fp32."""
    B, Hk, G, Sq, D = q.shape
    Skv = k.shape[2]
    s = jnp.einsum("bhgqd,bhkd->bhgqk", q.astype(jnp.float32),
                   k.astype(jnp.float32)) / math.sqrt(D)
    if causal:
        mask = jnp.arange(Sq)[:, None] >= jnp.arange(Skv)[None, :]
        s = jnp.where(mask[None, None, None], s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bhgqk,bhkd->bhgqd", p, v.astype(jnp.float32))
    return out.astype(q.dtype)
