from .kernel import ssd_pallas
from .ops import ssd
from .ref import ssd_ref

__all__ = ["ssd", "ssd_pallas", "ssd_ref"]
