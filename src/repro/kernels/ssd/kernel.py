"""Mamba-2 SSD chunked-scan Pallas TPU kernel.

Grid: (B, H, n_chunks) with the chunk axis innermost/sequential; the
recurrent state (P, N) is carried in VMEM scratch across chunk steps — the
TPU-native shape of the SSD "state passing" from the paper (arXiv:2405.21060
§6): intra-chunk work is the dual quadratic form (three MXU matmuls of
shapes (Q,N)@(N,Q), (Q,Q)@(Q,P), (Q,N)@(N,P)), inter-chunk work is a rank-Q
state update.

Per-step VMEM: x (Q,P) + B/C (Q,N) + L (Q,Q) + state (P,N) fp32 — for
Q=128, P=64, N=128: ~250 KB.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

__all__ = ["ssd_kernel", "ssd_pallas"]


def ssd_kernel(a_ref, x_ref, dt_ref, b_ref, c_ref, y_ref, state_ref, *,
               chunk: int, num_chunks: int):
    ic = pl.program_id(2)
    h = pl.program_id(1)
    Q = chunk
    P = x_ref.shape[1]
    N = b_ref.shape[1]

    @pl.when(ic == 0)
    def _init():
        state_ref[...] = jnp.zeros_like(state_ref)

    A = a_ref[h]                                     # scalar (negative)
    x = x_ref[...].astype(jnp.float32)               # (Q, P)
    dt = dt_ref[...].astype(jnp.float32).reshape(Q)  # (Q,)
    Bm = b_ref[...].astype(jnp.float32)              # (Q, N)
    Cm = c_ref[...].astype(jnp.float32)              # (Q, N)

    logd = dt * A                                    # (Q,)
    cum = jnp.cumsum(logd)                           # (Q,)
    xdt = x * dt[:, None]                            # (Q, P)

    # intra-chunk: ((C @ B^T) ∘ L) @ xdt   with L = exp(segsum) lower-tri
    scores = jax.lax.dot_general(Cm, Bm, (((1,), (1,)), ((), ())),
                                 preferred_element_type=jnp.float32)  # (Q,Q)
    seg = cum[:, None] - cum[None, :]                # log decay j -> i
    ri = jax.lax.broadcasted_iota(jnp.int32, (Q, Q), 0)
    ci = jax.lax.broadcasted_iota(jnp.int32, (Q, Q), 1)
    L = jnp.where(ri >= ci, jnp.exp(seg), 0.0)
    y = jax.lax.dot_general(scores * L, xdt, (((1,), (0,)), ((), ())),
                            preferred_element_type=jnp.float32)       # (Q,P)

    # inter-chunk: contribution of the carried state
    state = state_ref[...]                           # (P, N)
    decay_in = jnp.exp(cum)                          # (Q,)
    y_inter = jax.lax.dot_general(Cm, state, (((1,), (1,)), ((), ())),
                                  preferred_element_type=jnp.float32)
    y = y + y_inter * decay_in[:, None]

    # state update: state' = state·exp(sum logd) + (decay_out·xdt)^T @ B
    total = jnp.exp(cum[Q - 1])
    decay_out = jnp.exp(cum[Q - 1] - cum)            # (Q,)
    upd = jax.lax.dot_general(xdt * decay_out[:, None], Bm,
                              (((0,), (0,)), ((), ())),
                              preferred_element_type=jnp.float32)     # (P,N)
    state_ref[...] = state * total + upd
    y_ref[...] = y.astype(y_ref.dtype)


def ssd_pallas(x: jax.Array, dt: jax.Array, A: jax.Array, Bm: jax.Array,
               Cm: jax.Array, *, chunk: int = 128,
               interpret: bool = False):
    """x: (B, S, H, P); dt: (B, S, H); A: (H,); Bm, Cm: (B, S, N).

    Returns y: (B, S, H, P).  (Final state retrieval is the jnp path's job —
    the kernel targets the training/prefill hot loop.)
    """
    Bb, S, H, P = x.shape
    N = Bm.shape[-1]
    chunk = min(chunk, S)
    if S % chunk:
        raise ValueError(f"S {S} % chunk {chunk}")
    nc = S // chunk

    # kernel-major layouts
    xk = x.transpose(0, 2, 1, 3)                     # (B, H, S, P)
    dtk = dt.transpose(0, 2, 1)[..., None]           # (B, H, S, 1)

    kernel = functools.partial(ssd_kernel, chunk=chunk, num_chunks=nc)
    y = pl.pallas_call(
        kernel,
        grid=(Bb, H, nc),
        in_specs=[
            pl.BlockSpec(memory_space=pltpu.SMEM),                 # A (H,)
            pl.BlockSpec((None, None, chunk, P),
                         lambda b, h, ic: (b, h, ic, 0)),          # x
            pl.BlockSpec((None, None, chunk, 1),
                         lambda b, h, ic: (b, h, ic, 0)),          # dt
            pl.BlockSpec((None, chunk, N), lambda b, h, ic: (b, ic, 0)),
            pl.BlockSpec((None, chunk, N), lambda b, h, ic: (b, ic, 0)),
        ],
        out_specs=pl.BlockSpec((None, None, chunk, P),
                               lambda b, h, ic: (b, h, ic, 0)),
        out_shape=jax.ShapeDtypeStruct((Bb, H, S, P), x.dtype),
        scratch_shapes=[pltpu.VMEM((P, N), jnp.float32)],
        interpret=interpret,
    )(jnp.asarray(A, jnp.float32), xk, dtk, Bm, Cm)
    return y.transpose(0, 2, 1, 3)                   # (B, S, H, P)
