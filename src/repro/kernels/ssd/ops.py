"""Jit'd public wrapper for the SSD kernel."""

from __future__ import annotations

from functools import partial

import jax

from .kernel import ssd_pallas
from .ref import ssd_ref

__all__ = ["ssd"]


@partial(jax.jit, static_argnames=("chunk", "interpret", "use_kernel"))
def ssd(x: jax.Array, dt: jax.Array, A: jax.Array, Bm: jax.Array,
        Cm: jax.Array, *, chunk: int = 128, interpret: bool = False,
        use_kernel: bool = True) -> jax.Array:
    """x: (B,S,H,P); dt: (B,S,H); A: (H,); Bm, Cm: (B,S,N) → y (B,S,H,P)."""
    if use_kernel:
        return ssd_pallas(x, dt, A, Bm, Cm, chunk=chunk, interpret=interpret)
    return ssd_ref(x, dt, A, Bm, Cm, chunk=chunk)
