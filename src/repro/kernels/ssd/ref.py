"""Pure-jnp oracle for the SSD kernel — the model's own chunked scan."""

from __future__ import annotations

import jax

from repro.models.ssm import ssd_chunked

__all__ = ["ssd_ref"]


def ssd_ref(x: jax.Array, dt: jax.Array, A: jax.Array, Bm: jax.Array,
            Cm: jax.Array, *, chunk: int = 128) -> jax.Array:
    y, _ = ssd_chunked(x, dt, A, Bm, Cm, chunk)
    return y
