import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
# ^ MUST precede any jax import (jax locks the device count at first init).
# This is the ONLY entry point that forces 512 placeholder devices; smoke
# tests and benches see the real single CPU device.

"""Multi-pod dry-run: lower + compile every (arch × shape × mesh) cell.

For each live cell (see input_specs.live_cells) on both production meshes
(16×16 single-pod; 2×16×16 multi-pod), this driver:

1. builds the jitted step (train_step / prefill / decode) with explicit
   in/out shardings from the sharding rules,
2. ``.lower(...)`` on ShapeDtypeStruct inputs (no allocation),
3. ``.compile()`` — SPMD partitioning must succeed; sharding mismatches,
   unsupported collectives or compile-time OOMs are bugs,
4. records ``memory_analysis()`` / ``cost_analysis()`` and the collective
   operations parsed from the optimized HLO into a JSON blob consumed by
   ``analysis/roofline.py`` and EXPERIMENTS.md.

Usage::

    PYTHONPATH=src python -m repro.launch.dryrun [--arch A] [--shape S]
        [--mesh single|multi|both] [--out results/dryrun]
        [--zero1] [--zero3] [--seq-parallel]
"""

import argparse
import json
import pathlib
import time
import traceback

import jax
import jax.numpy as jnp

from repro.configs import get_config, list_archs
from repro.launch.input_specs import (Cell, SHAPES, input_specs, is_skipped,
                                      live_cells)
from repro.launch.mesh import make_production_mesh
from repro.analysis.hlo import (collective_summary, count_scan_trips,
                                hbm_bytes, matmul_flops)
from repro.analysis.flops import model_flops

__all__ = ["run_cell", "main"]


def _apply_overrides(cfg, overrides):
    if not overrides:
        return cfg
    import dataclasses
    kw = {}
    for item in overrides:
        k, v = item.split("=", 1)
        cur = getattr(cfg, k)
        if isinstance(cur, bool):
            kw[k] = v.lower() in ("1", "true", "yes")
        elif isinstance(cur, int):
            kw[k] = int(v)
        elif isinstance(cur, float):
            kw[k] = float(v)
        else:
            kw[k] = v
    return dataclasses.replace(cfg, **kw)


def _build_lowered(cell: Cell, mesh, *, zero1=False, zero3=False,
                   overrides=None):
    """Returns jax.stages.Lowered for the cell's step on the mesh."""
    from repro.models import build_model
    from repro.optim.adamw import AdamWConfig
    from repro.runtime.serve_lib import (abstract_cache, build_decode_step,
                                         build_prefill_step, cache_specs)
    from repro.runtime.train_lib import (abstract_train_state,
                                         build_train_step)
    from repro.launch.input_specs import FRAMES_LEN

    cfg = _apply_overrides(get_config(cell.arch), overrides)
    model = build_model(cfg)
    specs = input_specs(cell, cfg)

    if cell.kind == "train":
        opt_cfg = AdamWConfig(
            state_dtype=jnp.bfloat16 if cell.arch == "kimi-k2-1t-a32b"
            else jnp.float32)
        step, _ = build_train_step(model, mesh, opt_cfg, zero1=zero1,
                                   zero3=zero3,
                                   batch_tree=specs["batch"])
        state = abstract_train_state(model, mesh, opt_cfg)
        return step.lower(state, specs["batch"])

    if cell.kind == "prefill":
        step = build_prefill_step(model, mesh, cell.batch, cell.seq,
                                  zero3=zero3)
        cache = abstract_cache(model, cell.batch, cell.seq, filled=False,
                               memory_len=FRAMES_LEN)
        if cfg.family == "encdec":
            return step.lower(_abs_params(model), specs["frames"],
                              specs["tokens"], cache)
        return step.lower(_abs_params(model), specs["tokens"], cache)

    # decode
    step = build_decode_step(model, mesh, cell.batch, cell.seq, zero3=zero3)
    cache = abstract_cache(model, cell.batch, cell.seq, filled=True,
                           memory_len=FRAMES_LEN)
    return step.lower(_abs_params(model), specs["token"], cache)


def _abs_params(model):
    from repro.models.param import abstract_params
    return abstract_params(model.param_decls())


def run_cell(cell: Cell, mesh_kind: str, *, zero1=False, zero3=False,
             hlo_path=None, overrides=None) -> dict:
    """Lower + compile one cell; returns the roofline-input record."""
    multi = mesh_kind == "multi"
    mesh = make_production_mesh(multi_pod=multi)
    n_chips = mesh.size
    t0 = time.time()
    lowered = _build_lowered(cell, mesh, zero1=zero1, zero3=zero3,
                             overrides=overrides)
    t_lower = time.time() - t0
    t0 = time.time()
    compiled = lowered.compile()
    t_compile = time.time() - t0

    cost = compiled.cost_analysis() or {}
    try:
        mem = compiled.memory_analysis()
        mem_rec = {
            "argument_size_bytes": getattr(mem, "argument_size_in_bytes", None),
            "output_size_bytes": getattr(mem, "output_size_in_bytes", None),
            "temp_size_bytes": getattr(mem, "temp_size_in_bytes", None),
            "generated_code_size_bytes":
                getattr(mem, "generated_code_size_in_bytes", None),
        } if mem is not None else {}
    except Exception:           # pragma: no cover - backend-dependent
        mem_rec = {}

    hlo = compiled.as_text()
    if hlo_path is not None:
        import gzip
        with gzip.open(hlo_path, "wt") as fh:
            fh.write(hlo)
    coll = collective_summary(hlo)
    scans = count_scan_trips(hlo)
    dot_flops = matmul_flops(hlo)      # per device, loop-scaled
    hbm = hbm_bytes(hlo)               # per device, loop-scaled
    cfg = get_config(cell.arch)
    mf = model_flops(cfg, cell)

    rec = {
        "arch": cell.arch,
        "shape": cell.shape,
        "kind": cell.kind,
        "mesh": mesh_kind,
        "n_chips": n_chips,
        "seq": cell.seq,
        "batch": cell.batch,
        "lower_s": round(t_lower, 2),
        "compile_s": round(t_compile, 2),
        "hlo_flops": cost.get("flops"),
        "hlo_bytes": cost.get("bytes accessed"),
        "dot_flops_per_device": dot_flops,
        "hbm_bytes_per_device": hbm,
        "cost_analysis": {k: v for k, v in cost.items()
                          if isinstance(v, (int, float))},
        "memory_analysis": mem_rec,
        "collectives": coll,
        "scan_trip_counts": scans,
        "model_flops": mf,
        "zero1": zero1, "zero3": zero3,
        "overrides": list(overrides or ()),
    }
    return rec


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--arch", default=None, choices=list_archs() + [None])
    ap.add_argument("--shape", default=None, choices=list(SHAPES) + [None])
    ap.add_argument("--mesh", default="both",
                    choices=["single", "multi", "both"])
    ap.add_argument("--out", default="results/dryrun")
    ap.add_argument("--zero1", action="store_true")
    ap.add_argument("--zero3", action="store_true")
    ap.add_argument("--tag", default="baseline")
    ap.add_argument("--save-hlo", action="store_true",
                    help="also write <cell>.hlo.gz for offline re-analysis")
    ap.add_argument("--override", action="append", default=[],
                    metavar="KEY=VAL",
                    help="ModelConfig field override (repeatable)")
    args = ap.parse_args(argv)

    cells = [c for c in live_cells()
             if (args.arch is None or c.arch == args.arch)
             and (args.shape is None or c.shape == args.shape)]
    meshes = {"single": ["single"], "multi": ["multi"],
              "both": ["single", "multi"]}[args.mesh]
    outdir = pathlib.Path(args.out)
    outdir.mkdir(parents=True, exist_ok=True)

    failures = 0
    for cell in cells:
        for mk in meshes:
            name = f"{cell.arch}__{cell.shape}__{mk}__{args.tag}"
            path = outdir / f"{name}.json"
            try:
                rec = run_cell(cell, mk, zero1=args.zero1, zero3=args.zero3,
                               hlo_path=(outdir / f"{name}.hlo.gz")
                               if args.save_hlo else None,
                               overrides=args.override)
                path.write_text(json.dumps(rec, indent=1))
                print(f"OK   {name}: compile={rec['compile_s']}s "
                      f"flops={rec['hlo_flops']:.3e} "
                      f"coll_bytes={rec['collectives']['total_bytes']:.3e}",
                      flush=True)
            except Exception as e:   # noqa: BLE001 - report and continue
                failures += 1
                print(f"FAIL {name}: {type(e).__name__}: {e}", flush=True)
                (outdir / f"{name}.err").write_text(traceback.format_exc())
    print(f"done: {len(cells) * len(meshes) - failures} ok, "
          f"{failures} failed")
    return 1 if failures else 0


if __name__ == "__main__":
    raise SystemExit(main())
