"""Abstract inputs for every (architecture × shape) dry-run cell.

Everything is ``jax.ShapeDtypeStruct`` — the multi-billion/trillion
parameter configs are *lowered*, never materialized.  The modality
frontends are stubs per the assignment: VLM cells get precomputed patch
embeddings + 3D M-RoPE positions, audio cells get precomputed frame
embeddings.

Shape cells (LM pool):
  train_4k     seq 4096   global_batch 256   → train_step
  prefill_32k  seq 32768  global_batch 32    → serve prefill
  decode_32k   seq 32768  global_batch 128   → serve decode (1 new token)
  long_500k    seq 524288 global_batch 1     → serve decode; only for
               sub-quadratic archs (mamba2, jamba) — see DESIGN.md skips.
"""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from ..configs import get_config, list_archs
from ..models import build_model
from ..models.config import ModelConfig

__all__ = ["SHAPES", "Cell", "live_cells", "input_specs", "is_skipped"]

SDS = jax.ShapeDtypeStruct

SHAPES = {
    "train_4k": dict(kind="train", seq=4096, batch=256),
    "prefill_32k": dict(kind="prefill", seq=32768, batch=32),
    "decode_32k": dict(kind="decode", seq=32768, batch=128),
    "long_500k": dict(kind="decode", seq=524288, batch=1),
}

# long_500k runs only for sub-quadratic sequence mixers (brief: "skip for
# pure full-attention archs ... run for SSM/hybrid").
LONG_OK = {"mamba2-370m", "jamba-1.5-large-398b"}

# Audio/vision stub lengths.
VISION_PATCHES = {"train_4k": 256, "prefill_32k": 1024}
FRAMES_LEN = 1024


@dataclasses.dataclass(frozen=True)
class Cell:
    arch: str
    shape: str

    @property
    def kind(self) -> str:
        return SHAPES[self.shape]["kind"]

    @property
    def seq(self) -> int:
        return SHAPES[self.shape]["seq"]

    @property
    def batch(self) -> int:
        return SHAPES[self.shape]["batch"]

    def __str__(self) -> str:
        return f"{self.arch}×{self.shape}"


def is_skipped(arch: str, shape: str) -> str | None:
    """Returns a skip reason or None."""
    if shape == "long_500k" and arch not in LONG_OK:
        return ("full-attention arch: 524k dense-softmax decode is the "
                "quadratic regime the brief excludes")
    return None


def live_cells() -> list[Cell]:
    out = []
    for arch in list_archs():
        for shape in SHAPES:
            if not is_skipped(arch, shape):
                out.append(Cell(arch, shape))
    return out


def input_specs(cell: Cell, cfg: ModelConfig | None = None) -> dict:
    """Abstract model inputs for the cell (batch dict for train; token /
    extras for serving).  Cache structs are built by the dry-run via
    ``serve_lib.abstract_cache`` (they are state, not inputs)."""
    cfg = cfg or get_config(cell.arch)
    B, S = cell.batch, cell.seq
    kind = cell.kind
    if kind == "train":
        batch = {"tokens": SDS((B, S + 1), jnp.int32)}
        if cfg.family == "vlm":
            nv = VISION_PATCHES[cell.shape]
            batch["vision_embeds"] = SDS((B, nv, cfg.d_model), jnp.bfloat16)
            batch["mrope_positions"] = SDS((3, B, S), jnp.int32)
        if cfg.family == "encdec":
            batch["frames"] = SDS((B, FRAMES_LEN, cfg.d_model), jnp.bfloat16)
        return {"batch": batch}
    if kind == "prefill":
        out = {"tokens": SDS((B, S), jnp.int32)}
        if cfg.family == "vlm":
            out["mrope_positions"] = SDS((3, B, S), jnp.int32)
        if cfg.family == "encdec":
            out["frames"] = SDS((B, FRAMES_LEN, cfg.d_model), jnp.bfloat16)
        return out
    # decode: one new token against a cache of length `seq`
    return {"token": SDS((B, 1), jnp.int32)}
