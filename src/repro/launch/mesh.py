"""Production mesh construction (assignment-mandated shapes).

Defined as functions — importing this module never touches jax device
state, so smoke tests keep their single CPU device.
"""

from __future__ import annotations

import jax
from jax.sharding import Mesh

__all__ = ["make_production_mesh", "make_local_mesh"]


def make_production_mesh(*, multi_pod: bool = False) -> Mesh:
    """16x16 single pod (256 chips) or 2x16x16 two-pod (512 chips)."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_local_mesh(data: int = 1, model: int = 1) -> Mesh:
    """Small mesh over however many local devices exist (tests/examples)."""
    n = len(jax.devices())
    if data * model > n:
        data, model = n, 1
    return jax.make_mesh((data, model), ("data", "model"))
