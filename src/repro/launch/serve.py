"""Serving driver: ``python -m repro.launch.serve --arch <id> ...``

Batched prefill + decode loop over synthetic requests (reduced configs on
CPU).  Requests are orchestrated as a DFlow workflow when ``--dflow`` is
set: per-request ``prefill.r`` functions feed a shared batched ``decode``
chain, so a late-arriving request's prefill overlaps the running decode of
earlier ones (the serverless-workflow pattern applied to serving).
"""

from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config, list_archs
from repro.launch.mesh import make_local_mesh
from repro.models import build_model, init_params
from repro.sharding.context import mesh_context

__all__ = ["main", "serve_loop"]


def serve_loop(arch: str, *, batch: int = 4, prompt_len: int = 32,
               gen_tokens: int = 16, seed: int = 0) -> dict:
    import dataclasses

    cfg = get_config(arch, reduced=True)
    max_len = prompt_len + gen_tokens
    cfg = dataclasses.replace(cfg, q_chunk=max(prompt_len // 2, 16),
                              kv_chunk=max(prompt_len // 2, 16),
                              max_cache_len=max_len)
    mesh = make_local_mesh()
    model = build_model(cfg)
    with mesh_context(mesh):
        params = init_params(model.param_decls(), jax.random.key(seed))
        rng = np.random.default_rng(seed)
        prompts = jnp.asarray(rng.integers(
            0, cfg.vocab, size=(batch, prompt_len)), jnp.int32)

        if cfg.family == "encdec":
            frames = jnp.asarray(
                rng.normal(size=(batch, 16, cfg.d_model)), jnp.bfloat16)
            cache = model.init_cache(batch, max_len=max_len, memory_len=16)
            prefill = jax.jit(model.prefill)
            decode = jax.jit(model.decode_step)
            t0 = time.time()
            logits, cache = prefill(params, frames, prompts, cache)
        else:
            cache = model.init_cache(batch, max_len=max_len)
            prefill = jax.jit(model.prefill)
            decode = jax.jit(model.decode_step)
            t0 = time.time()
            logits, cache = prefill(params, prompts, cache)
        t_prefill = time.time() - t0

        tok = jnp.argmax(logits[:, -1], axis=-1)[:, None].astype(jnp.int32)
        generated = [tok]
        t0 = time.time()
        for _ in range(gen_tokens - 1):
            logits, cache = decode(params, tok, cache)
            tok = jnp.argmax(logits[:, -1], axis=-1)[:, None].astype(jnp.int32)
            generated.append(tok)
        jax.block_until_ready(tok)
        t_decode = time.time() - t0
        out_tokens = jnp.concatenate(generated, axis=1)
        return {
            "prefill_s": t_prefill,
            "decode_s": t_decode,
            "decode_tok_per_s": batch * (gen_tokens - 1) / max(t_decode, 1e-9),
            "tokens": np.asarray(out_tokens),
        }


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--arch", required=True, choices=list_archs())
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--gen-tokens", type=int, default=16)
    args = ap.parse_args(argv)
    out = serve_loop(args.arch, batch=args.batch,
                     prompt_len=args.prompt_len,
                     gen_tokens=args.gen_tokens)
    print(f"[serve] prefill={out['prefill_s']:.2f}s "
          f"decode={out['decode_s']:.2f}s "
          f"({out['decode_tok_per_s']:.1f} tok/s)")
    print(f"[serve] sample tokens: {out['tokens'][0][:8].tolist()}")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
