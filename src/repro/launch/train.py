"""Training driver: ``python -m repro.launch.train --arch <id> ...``

End-to-end loop on real devices (reduced configs on this CPU container;
full configs on TPU): data pipeline with background prefetch, jitted
sharded train step, checkpoint/restart fault tolerance, and optional
DFlow-orchestrated mode where the job DAG (fetch → step → async-ckpt) runs
under the paper's dataflow scheduler.

Fault tolerance: ``--simulate-failure K`` raises after step K; rerunning
the same command resumes from the last complete checkpoint and reproduces
the identical loss trajectory (the data pipeline is keyed by step).
"""

from __future__ import annotations

import argparse
import time

import jax
import numpy as np

from repro.configs import get_config, list_archs
from repro.data import DataConfig, make_pipeline
from repro.checkpoint import CheckpointManager
from repro.launch.mesh import make_local_mesh
from repro.models import build_model
from repro.optim.adamw import AdamWConfig
from repro.runtime.train_lib import (build_train_step, init_train_state,
                                     make_train_state_specs)
from repro.sharding.context import mesh_context

__all__ = ["main", "train_loop"]


def train_loop(arch: str, *, steps: int = 20, batch: int = 8, seq: int = 128,
               reduced: bool = True, ckpt_dir: str | None = None,
               ckpt_every: int = 0, resume: bool = False,
               simulate_failure: int | None = None, seed: int = 0,
               log_every: int = 1, data: int = 1, model: int = 1,
               microbatches: int | None = None) -> dict:
    import dataclasses

    cfg = get_config(arch, reduced=reduced)
    cfg = dataclasses.replace(cfg, q_chunk=max(seq // 2, 16),
                              kv_chunk=max(seq // 2, 16),
                              microbatches=microbatches or 1)
    if cfg.family == "encdec":
        raise SystemExit("use examples/seamless_train.py for enc-dec")
    mesh = make_local_mesh(data=data, model=model)
    model_obj = build_model(cfg)
    opt_cfg = AdamWConfig(lr=1e-3, warmup_steps=max(steps // 10, 1),
                          total_steps=steps)

    with mesh_context(mesh):
        step_fn, specs = build_train_step(model_obj, mesh, opt_cfg)
        state = init_train_state(model_obj, mesh, opt_cfg, seed=seed)

        mgr = None
        start_step = 0
        if ckpt_dir:
            mgr = CheckpointManager(ckpt_dir, keep=2, async_save=True)
            if resume:
                latest = mgr.latest()
                if latest is not None:
                    state, start_step = mgr.restore(state)
                    print(f"[train] resumed from step {start_step}")

        dcfg = DataConfig(vocab=cfg.vocab, seq_len=seq, global_batch=batch,
                          seed=seed)
        pipe = make_pipeline(dcfg, start_step=start_step)
        losses = []
        t0 = time.time()
        try:
            for i in range(start_step, steps):
                step_idx, np_batch = pipe.next()
                assert step_idx == i, (step_idx, i)
                batch_dev = {k: jax.numpy.asarray(v)
                             for k, v in np_batch.items()}
                state, metrics = step_fn(state, batch_dev)
                loss = float(metrics["loss"])
                losses.append(loss)
                if log_every and i % log_every == 0:
                    print(f"[train] step {i:4d} loss {loss:.4f} "
                          f"gnorm {float(metrics['grad_norm']):.3f}",
                          flush=True)
                if mgr and ckpt_every and (i + 1) % ckpt_every == 0:
                    mgr.save(i + 1, state)
                if simulate_failure is not None and i + 1 == simulate_failure:
                    raise RuntimeError(
                        f"simulated node failure at step {i + 1}")
        finally:
            pipe.close()
            if mgr:
                mgr.wait()
        wall = time.time() - t0
        tokens = (steps - start_step) * batch * seq
        return {"losses": losses, "wall_s": wall,
                "tokens_per_s": tokens / max(wall, 1e-9),
                "final_loss": losses[-1] if losses else float("nan"),
                "start_step": start_step}


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--arch", required=True, choices=list_archs())
    ap.add_argument("--steps", type=int, default=20)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--full", action="store_true",
                    help="full config (TPU pods only)")
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--ckpt-every", type=int, default=0)
    ap.add_argument("--resume", action="store_true")
    ap.add_argument("--simulate-failure", type=int, default=None)
    ap.add_argument("--microbatches", type=int, default=None)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)
    out = train_loop(args.arch, steps=args.steps, batch=args.batch,
                     seq=args.seq, reduced=not args.full,
                     ckpt_dir=args.ckpt_dir, ckpt_every=args.ckpt_every,
                     resume=args.resume,
                     simulate_failure=args.simulate_failure,
                     microbatches=args.microbatches, seed=args.seed)
    print(f"[train] done: final_loss={out['final_loss']:.4f} "
          f"tokens/s={out['tokens_per_s']:.0f}")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
