"""DCheck lint CLI — ``python -m repro.lint``.

Lints workflow.yaml documents and/or the built-in benchmark workloads
against the DF-code registry in :mod:`repro.core.lint`.

Usage::

    python -m repro.lint examples/workflows/wordcount.yaml
    python -m repro.lint --builtin all            # every BENCHMARKS entry
    python -m repro.lint --builtin WC --builtin Gen file.yaml --strict
    python -m repro.lint --list-codes

Exit status is 1 when any error-severity diagnostic fires (``--strict``
also fails on warnings), so the command gates CI directly.
"""

from __future__ import annotations

import argparse
import json
import sys

from repro.core.lint import CODES, Diagnostic, lint, max_severity

__all__ = ["main"]


def _lint_builtin(name: str, require_fns: bool) -> list[Diagnostic]:
    from repro.core.workloads import BENCHMARKS

    wf = BENCHMARKS[name]()
    return lint(wf, require_fns=require_fns)


def _lint_file(path: str, require_fns: bool) -> list[Diagnostic]:
    with open(path, "r", encoding="utf-8") as fh:
        return lint(fh.read(), require_fns=require_fns)


def main(argv: list[str] | None = None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m repro.lint",
        description="DCheck workflow linter (stable DF diagnostic codes)")
    ap.add_argument("paths", nargs="*", help="workflow.yaml files to lint")
    ap.add_argument("--builtin", action="append", default=[],
                    metavar="NAME",
                    help="lint a built-in workload (repeatable; 'all' "
                    "lints every BENCHMARKS entry)")
    ap.add_argument("--strict", action="store_true",
                    help="also fail on warning-severity diagnostics")
    ap.add_argument("--require-fns", action="store_true",
                    help="treat missing fn bindings as errors (intended "
                    "engine run)")
    ap.add_argument("--format", choices=("text", "json"), default="text")
    ap.add_argument("--list-codes", action="store_true",
                    help="print the diagnostic code table and exit")
    args = ap.parse_args(argv)

    if args.list_codes:
        for code, (severity, title) in sorted(CODES.items()):
            print(f"{code}  {severity:8s}  {title}")
        return 0

    targets: list[tuple[str, list[Diagnostic]]] = []
    builtins = args.builtin
    if "all" in builtins:
        from repro.core.workloads import BENCHMARKS

        builtins = sorted(BENCHMARKS)
    for name in builtins:
        targets.append((f"builtin:{name}",
                        _lint_builtin(name, args.require_fns)))
    for path in args.paths:
        targets.append((path, _lint_file(path, args.require_fns)))
    if not targets:
        ap.error("nothing to lint: pass paths and/or --builtin")

    fail_at = ("error",) if not args.strict else ("error", "warning")
    failed = 0
    if args.format == "json":
        doc = [{"target": t, "diagnostics": [vars(d) for d in diags]}
               for t, diags in targets]
        json.dump(doc, sys.stdout, indent=2)
        print()
    for target, diags in targets:
        if args.format == "text":
            verdict = max_severity(diags) or "clean"
            print(f"{target}: {verdict} "
                  f"({len(diags)} diagnostic(s))")
            for d in diags:
                print(f"  {d.format()}")
        if any(d.severity in fail_at for d in diags):
            failed += 1
    if args.format == "text":
        print(f"# linted {len(targets)} workflow(s), {failed} failed "
              f"(fail on: {', '.join(fail_at)})")
    return 1 if failed else 0


if __name__ == "__main__":
    raise SystemExit(main())
