"""Model zoo: composable pure-JAX layers + the per-family assemblies."""

from .config import ModelConfig
from .lm import LM, Cache
from .encdec import EncDecLM, EncDecCache
from .param import (ArrayDecl, abstract_params, init_params, logical_axes,
                    param_bytes, param_count)

__all__ = ["ModelConfig", "LM", "Cache", "EncDecLM", "EncDecCache",
           "ArrayDecl", "abstract_params", "init_params", "logical_axes",
           "param_bytes", "param_count", "build_model"]


def build_model(cfg: ModelConfig):
    """Family-dispatching factory."""
    if cfg.family == "encdec":
        return EncDecLM(cfg)
    return LM(cfg)
