"""GQA attention: blockwise (flash-style) training/prefill + cached decode.

The training/prefill path is *blockwise*: an outer ``lax.scan`` over query
chunks and an inner ``lax.scan`` over KV chunks with an online-softmax
running (max, denominator, accumulator).  This keeps the live working set at
``O(q_chunk × kv_chunk)`` instead of ``O(S²)`` — mandatory for the 32k
prefill shapes, and it is the exact algorithm the Pallas kernel
(:mod:`repro.kernels.flash_attention`) implements on TPU VMEM tiles; this
jnp version doubles as its oracle.

GQA is handled *ungrouped*: K/V keep ``n_kv_heads`` and Q is reshaped to
``(kv_heads, group)`` so no K/V repetition is materialized.

Cached decode: single-token queries against a fixed-capacity cache with a
length mask (used by ``serve_step``; 32k and 500k decode cells).
"""

from __future__ import annotations

import math
from typing import NamedTuple

import jax
import jax.numpy as jnp

from .common import apply_mrope, apply_rope, rms_norm, rope_table
from .config import ModelConfig
from .param import ArrayDecl, normal_init, ones_init

__all__ = ["attention_decls", "attention", "blockwise_attention",
           "decode_attention", "KVCache", "init_cache"]

NEG_INF = -1e30


class KVCache(NamedTuple):
    k: jax.Array          # (B, max_len, kv_heads, head_dim)
    v: jax.Array          # (B, max_len, kv_heads, head_dim)
    length: jax.Array     # () int32 — tokens currently valid


def attention_decls(cfg: ModelConfig, layers: int | None = None) -> dict:
    """Parameter declarations; ``layers`` adds a leading stacked-layer axis.

    ``n_heads_eff`` (zero-mask-padded when the table head count does not
    divide the model axis) keeps every attention activation flat on a
    single shardable heads dimension — no (Hk, G) split reshapes, which
    SPMD cannot re-partition without involuntary rematerialization."""
    H, Hk, D, M = cfg.n_heads_eff, cfg.n_kv_heads, cfg.head_dim, cfg.d_model
    lead = (layers,) if layers else ()
    lax_ = ("layers",) if layers else ()
    decls = {
        "wq": ArrayDecl(lead + (M, H, D), lax_ + ("embed", "heads", "head_dim")),
        "wk": ArrayDecl(lead + (M, Hk, D), lax_ + ("embed", "kv_heads", "head_dim")),
        "wv": ArrayDecl(lead + (M, Hk, D), lax_ + ("embed", "kv_heads", "head_dim")),
        "wo": ArrayDecl(lead + (H, D, M), lax_ + ("heads", "head_dim", "embed")),
    }
    if cfg.qk_norm:
        decls["q_norm"] = ArrayDecl(lead + (D,), lax_ + (None,),
                                    init=ones_init)
        decls["k_norm"] = ArrayDecl(lead + (D,), lax_ + (None,),
                                    init=ones_init)
    return decls


# ----------------------------------------------------------------------
def blockwise_attention(q: jax.Array, k: jax.Array, v: jax.Array, *,
                        causal: bool, q_chunk: int, kv_chunk: int,
                        q_offset: int = 0,
                        softmax_dtype=jnp.float32) -> jax.Array:
    """Online-softmax attention.

    q: (B, Sq, H, D);  k, v: (B, Skv, Hk, D) with H % Hk == 0.
    Returns (B, Sq, H, D).  ``q_offset`` shifts query positions for causal
    masking (prefill continuation).  ``softmax_dtype`` sets the materialized
    score-pipeline dtype (running max/denominator stay fp32).
    """
    B, Sq, H, D = q.shape
    _, Skv, Hk, _ = k.shape
    G = H // Hk
    scale = 1.0 / math.sqrt(D)
    q_chunk = min(q_chunk, Sq)
    kv_chunk = min(kv_chunk, Skv)
    if Sq % q_chunk or Skv % kv_chunk:
        raise ValueError(f"chunking must divide: {Sq}%{q_chunk}, "
                         f"{Skv}%{kv_chunk}")
    nq, nk = Sq // q_chunk, Skv // kv_chunk

    qr = (q.reshape(B, nq, q_chunk, Hk, G, D) * scale).astype(q.dtype)
    kr = k.reshape(B, nk, kv_chunk, Hk, D)
    vr = v.reshape(B, nk, kv_chunk, Hk, D)
    # scan over q chunks (leading axis first)
    qr = jnp.moveaxis(qr, 1, 0)           # (nq, B, cq, Hk, G, D)
    kr = jnp.moveaxis(kr, 1, 0)           # (nk, B, ck, Hk, D)
    vr = jnp.moveaxis(vr, 1, 0)

    q_pos_base = jnp.arange(q_chunk)
    k_pos_base = jnp.arange(kv_chunk)

    def q_body(_, qi_qc):
        qi, qc = qi_qc                    # qc: (B, cq, Hk, G, D)
        m0 = jnp.full((B, q_chunk, Hk, G), NEG_INF, jnp.float32)
        l0 = jnp.zeros((B, q_chunk, Hk, G), jnp.float32)
        a0 = jnp.zeros((B, q_chunk, Hk, G, D), jnp.float32)

        def kv_body(carry, ki_kc):
            m, l, acc = carry
            ki, kc, vc = ki_kc
            s = jnp.einsum("bqhgd,bkhd->bqhgk", qc, kc,
                           preferred_element_type=softmax_dtype)
            if causal:
                qpos = q_offset + qi * q_chunk + q_pos_base   # (cq,)
                kpos = ki * kv_chunk + k_pos_base             # (ck,)
                mask = qpos[:, None] >= kpos[None, :]
                s = jnp.where(mask[None, :, None, None, :],
                              s, jnp.asarray(NEG_INF, s.dtype))
            m_new = jnp.maximum(m, s.max(axis=-1).astype(jnp.float32))
            p = jnp.exp(s - m_new[..., None].astype(s.dtype))
            corr = jnp.exp(m - m_new)
            l_new = l * corr + p.sum(axis=-1).astype(jnp.float32)
            pv = jnp.einsum("bqhgk,bkhd->bqhgd", p.astype(vc.dtype), vc,
                            preferred_element_type=jnp.float32)
            acc_new = acc * corr[..., None] + pv
            return (m_new, l_new, acc_new), None

        (m, l, acc), _ = jax.lax.scan(
            kv_body, (m0, l0, a0), (jnp.arange(nk), kr, vr))
        out = acc / jnp.maximum(l, 1e-30)[..., None]
        return None, out.astype(q.dtype)

    _, outs = jax.lax.scan(q_body, None, (jnp.arange(nq), qr))
    # outs: (nq, B, cq, Hk, G, D) -> (B, Sq, H, D)
    outs = jnp.moveaxis(outs, 0, 1).reshape(B, Sq, Hk, G, D)
    return outs.reshape(B, Sq, H, D)


def decode_attention(q: jax.Array, cache: KVCache) -> jax.Array:
    """Single-step attention against a masked fixed-size cache.

    q: (B, 1, H, D); cache.k/v: (B, L, Hk, D).  Returns (B, 1, H, D).
    """
    B, Sq, H, D = q.shape
    _, L, Hk, _ = cache.k.shape
    G = H // Hk
    scale = 1.0 / math.sqrt(D)
    qr = q.reshape(B, Sq, Hk, G, D) * scale
    s = jnp.einsum("bqhgd,bkhd->bqhgk", qr, cache.k,
                   preferred_element_type=jnp.float32)
    valid = jnp.arange(L) < cache.length                  # (L,)
    s = jnp.where(valid[None, None, None, None, :], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bqhgk,bkhd->bqhgd", p.astype(cache.v.dtype), cache.v,
                     preferred_element_type=jnp.float32)
    return out.reshape(B, Sq, H, D).astype(q.dtype)


def init_cache(cfg: ModelConfig, batch: int, max_len: int,
               dtype=jnp.bfloat16) -> KVCache:
    return KVCache(
        k=jnp.zeros((batch, max_len, cfg.n_kv_heads, cfg.head_dim), dtype),
        v=jnp.zeros((batch, max_len, cfg.n_kv_heads, cfg.head_dim), dtype),
        length=jnp.zeros((), jnp.int32),
    )


# ----------------------------------------------------------------------
def attention(params: dict, x: jax.Array, cfg: ModelConfig, *,
              positions: jax.Array | None = None,
              mrope_positions: jax.Array | None = None,
              causal: bool = True,
              cache: KVCache | None = None,
              kv_source: jax.Array | None = None):
    """Full attention sublayer: projections + rope + core + output proj.

    x: (B, S, M).  Modes:
      * cache is None                    → training / full prefill;
      * cache given and S == 1           → cached decode step;
      * cache given and S > 1            → prefill that fills the cache.
    ``kv_source`` (encoder memory) switches to cross-attention (no rope,
    no cache update, not causal).
    Returns (out, new_cache_or_None).
    """
    B, S, M = x.shape
    H, Hk, D = cfg.n_heads_eff, cfg.n_kv_heads, cfg.head_dim
    G = H // Hk
    q = jnp.einsum("bsm,mhd->bshd", x, params["wq"])
    kv_in = x if kv_source is None else kv_source
    k = jnp.einsum("bsm,mhd->bshd", kv_in, params["wk"])
    v = jnp.einsum("bsm,mhd->bshd", kv_in, params["wv"])

    if cfg.qk_norm:
        q = rms_norm(q, params["q_norm"])
        k = rms_norm(k, params["k_norm"])

    def expand_kv(t):
        """(B, S', Hk, D) -> (B, S', H, D): local broadcast on the XLA path
        (KV is model-replicated; the Pallas kernel keeps true GQA on TPU)."""
        if G == 1:
            return t
        return jnp.repeat(t, G, axis=2)

    is_cross = kv_source is not None
    if not is_cross:
        if positions is None:
            base = cache.length if cache is not None else 0
            positions = base + jnp.arange(S)[None, :]          # (1, S)
            positions = jnp.broadcast_to(positions, (B, S))
        if cfg.use_mrope and mrope_positions is not None:
            q = apply_mrope(q, mrope_positions, D, theta=cfg.rope_theta)
            k = apply_mrope(k, mrope_positions, D, theta=cfg.rope_theta)
        else:
            cos, sin = rope_table(positions, D, cfg.rope_theta)
            q = apply_rope(q, cos, sin)
            k = apply_rope(k, cos, sin)

    new_cache = None
    if cache is not None and not is_cross:
        if cfg.onehot_cache_update and S == 1:
            # Elementwise masked write: SPMD keeps the cache sharding (a
            # traced-offset DUS into a seq-sharded cache all-gathers it).
            sel = (jnp.arange(cache.k.shape[1]) == cache.length)
            sel = sel[None, :, None, None]
            k_all = jnp.where(sel, k.astype(cache.k.dtype), cache.k)
            v_all = jnp.where(sel, v.astype(cache.v.dtype), cache.v)
        else:
            k_all = jax.lax.dynamic_update_slice_in_dim(
                cache.k, k.astype(cache.k.dtype), cache.length, axis=1)
            v_all = jax.lax.dynamic_update_slice_in_dim(
                cache.v, v.astype(cache.v.dtype), cache.length, axis=1)
        new_cache = KVCache(k_all, v_all, cache.length + S)
        if S == 1:
            if cfg.decode_unexpanded_gqa:
                out = decode_attention(q, new_cache)
            else:
                out = decode_attention(
                    q, KVCache(expand_kv(k_all), expand_kv(v_all),
                               new_cache.length))
        else:
            # Prefill: attend over the fresh tokens blockwise (cache assumed
            # empty before a prefill; continuation uses q_offset).
            out = blockwise_attention(
                q, expand_kv(k), expand_kv(v), causal=causal,
                q_chunk=cfg.q_chunk, kv_chunk=cfg.kv_chunk,
                softmax_dtype=jnp.dtype(cfg.softmax_dtype))
    else:
        out = blockwise_attention(
            q, expand_kv(k), expand_kv(v), causal=causal and not is_cross,
            q_chunk=cfg.q_chunk, kv_chunk=cfg.kv_chunk,
            softmax_dtype=jnp.dtype(cfg.softmax_dtype))

    if cfg.pad_heads_to:
        # Hard-mask the padded heads: output-exact w.r.t. the table config.
        mask = (jnp.arange(H) < cfg.n_heads).astype(out.dtype)
        out = out * mask[None, None, :, None]
    y = jnp.einsum("bshd,hdm->bsm", out, params["wo"])
    return y, new_cache
