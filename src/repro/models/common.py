"""Shared layer primitives: norms, activations, rotary embeddings.

Everything is a pure function over explicit params; dtypes follow the
"compute in bf16, normalize/softmax in fp32" convention used by production
LM stacks.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

__all__ = ["rms_norm", "layer_norm", "silu", "gelu", "squared_relu",
           "rope_table", "apply_rope", "apply_mrope", "softmax_fp32",
           "cross_entropy_loss"]


def rms_norm(x: jax.Array, scale: jax.Array, eps: float = 1e-6) -> jax.Array:
    dtype = x.dtype
    x32 = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(x32), axis=-1, keepdims=True)
    y = x32 * jax.lax.rsqrt(var + eps)
    return (y * scale.astype(jnp.float32)).astype(dtype)


def layer_norm(x: jax.Array, scale: jax.Array, bias: jax.Array,
               eps: float = 1e-6) -> jax.Array:
    dtype = x.dtype
    x32 = x.astype(jnp.float32)
    mu = jnp.mean(x32, axis=-1, keepdims=True)
    var = jnp.var(x32, axis=-1, keepdims=True)
    y = (x32 - mu) * jax.lax.rsqrt(var + eps)
    return (y * scale.astype(jnp.float32)
            + bias.astype(jnp.float32)).astype(dtype)


def silu(x: jax.Array) -> jax.Array:
    return x * jax.nn.sigmoid(x)


def gelu(x: jax.Array) -> jax.Array:
    return jax.nn.gelu(x, approximate=True)


def squared_relu(x: jax.Array) -> jax.Array:
    """Primer / Nemotron-4 activation: relu(x)**2."""
    r = jax.nn.relu(x)
    return r * r


ACTIVATIONS = {"silu": silu, "gelu": gelu, "squared_relu": squared_relu}


def softmax_fp32(x: jax.Array, axis: int = -1) -> jax.Array:
    return jax.nn.softmax(x.astype(jnp.float32), axis=axis)


# ----------------------------------------------------------------------
# Rotary position embeddings
# ----------------------------------------------------------------------

def rope_table(positions: jax.Array, head_dim: int,
               theta: float = 10000.0) -> tuple[jax.Array, jax.Array]:
    """cos/sin tables for given integer positions.

    positions: (...,) int32  →  cos, sin: (..., head_dim // 2) fp32.
    """
    half = head_dim // 2
    freqs = 1.0 / (theta ** (jnp.arange(half, dtype=jnp.float32) / half))
    angles = positions.astype(jnp.float32)[..., None] * freqs
    return jnp.cos(angles), jnp.sin(angles)


def apply_rope(x: jax.Array, cos: jax.Array, sin: jax.Array) -> jax.Array:
    """Rotate pairs (split-half convention).  x: (B, S, H, D);
    cos/sin: (B, S, D/2) or (S, D/2)."""
    dtype = x.dtype
    half = x.shape[-1] // 2
    x1, x2 = x[..., :half].astype(jnp.float32), x[..., half:].astype(jnp.float32)
    if cos.ndim == 2:          # (S, D/2) -> broadcast over batch, heads
        cos = cos[None, :, None, :]
        sin = sin[None, :, None, :]
    else:                      # (B, S, D/2) -> broadcast over heads
        cos = cos[:, :, None, :]
        sin = sin[:, :, None, :]
    out1 = x1 * cos - x2 * sin
    out2 = x2 * cos + x1 * sin
    return jnp.concatenate([out1, out2], axis=-1).astype(dtype)


def apply_mrope(x: jax.Array, positions_3d: jax.Array, head_dim: int,
                sections: tuple[int, int, int] | None = None,
                theta: float = 1e6) -> jax.Array:
    """Qwen2-VL M-RoPE: the head dim is split into (temporal, h, w)
    sections, each rotated by its own position stream.

    x: (B, S, H, D); positions_3d: (3, B, S) int32.
    ``sections`` are in *half-dim* units and must sum to D // 2; the default
    reproduces Qwen2-VL's (16, 24, 24) split (1:1.5:1.5) for any head_dim.
    """
    half_total = head_dim // 2
    if sections is None:
        t = half_total // 4
        w = (half_total - t) // 2
        h = half_total - t - w
        sections = (t, h, w)
    if sum(sections) != half_total:
        raise ValueError(f"sections {sections} must sum to {half_total}")
    half = head_dim // 2
    freqs = 1.0 / (theta ** (jnp.arange(half, dtype=jnp.float32) / half))
    # Build a per-position angle table by section.
    sec_id = jnp.repeat(jnp.arange(3), jnp.array(sections),
                        total_repeat_length=half)             # (half,)
    pos = positions_3d.astype(jnp.float32)                    # (3, B, S)
    # angle[b, s, i] = pos[sec_id[i], b, s] * freqs[i]
    pos_sel = jnp.take(pos, sec_id, axis=0)                   # (half, B, S)
    angles = jnp.moveaxis(pos_sel, 0, -1) * freqs             # (B, S, half)
    return apply_rope(x, jnp.cos(angles), jnp.sin(angles))


# ----------------------------------------------------------------------
def cross_entropy_loss(logits: jax.Array, labels: jax.Array,
                       mask: jax.Array | None = None) -> jax.Array:
    """Mean token-level cross entropy in fp32.  logits: (..., V)."""
    logits = logits.astype(jnp.float32)
    logz = jax.nn.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, labels[..., None], axis=-1)[..., 0]
    nll = logz - gold
    if mask is not None:
        nll = nll * mask
        return nll.sum() / jnp.maximum(mask.sum(), 1.0)
    return nll.mean()
