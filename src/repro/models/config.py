"""Model configuration — one dataclass covers all 10 assigned families.

``family`` selects the assembly:
  * ``dense``   — decoder-only transformer (GQA + MLP)
  * ``moe``     — decoder-only with MoE FFN layers
  * ``ssm``     — Mamba-2 (SSD) stack, attention-free
  * ``hybrid``  — Jamba-style 1:7 attn:mamba interleave with periodic MoE
  * ``encdec``  — encoder-decoder (seamless-m4t backbone)
  * ``vlm``     — decoder-only with M-RoPE + vision-embedding inputs (the
                  modality frontend is a stub: inputs are precomputed patch
                  embeddings, per the assignment brief)
"""

from __future__ import annotations

import dataclasses
from typing import Literal

__all__ = ["ModelConfig"]


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: Literal["dense", "moe", "ssm", "hybrid", "encdec", "vlm"]
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab: int
    head_dim: int | None = None          # default d_model // n_heads
    # TP divisibility: lower with this many heads, the extras hard-masked to
    # zero (output-exact; ~H_pad/H extra attention FLOPs — see DESIGN.md).
    pad_heads_to: int | None = None

    # positional / norm flavor
    rope_theta: float = 10000.0
    use_mrope: bool = False              # qwen2-vl
    qk_norm: bool = False                # qwen3
    activation: str = "silu"             # "silu" | "gelu" | "squared_relu"
    glu: bool = True                     # gated FFN (SwiGLU); False → plain
    tie_embeddings: bool = False

    # MoE
    n_experts: int = 0
    top_k: int = 0
    n_shared_experts: int = 0
    capacity_factor: float = 1.25

    # SSM (mamba2)
    ssm_state: int = 0
    ssm_conv: int = 4
    ssm_head_dim: int = 64
    ssm_expand: int = 2

    # hybrid (jamba): layers per super-block and which are attention / MoE
    hybrid_period: int = 8
    hybrid_attn_index: int = 3           # 1 attn : 7 mamba
    hybrid_moe_every: int = 2            # MoE on every 2nd sublayer

    # encdec
    n_encoder_layers: int = 0

    # attention implementation for prefill/train ("xla" blockwise ref or
    # "pallas" kernels — kernels target TPU; dry-run lowers the xla path)
    attention_impl: str = "xla"
    q_chunk: int = 512
    kv_chunk: int = 1024

    # training-time behavior
    remat: bool = True                   # checkpoint each scanned layer
    microbatches: int = 1                # grad-accumulation steps

    # inference
    max_cache_len: int = 32768
    # §Perf levers (hillclimb; defaults = paper-faithful baseline):
    # one-hot masked cache write instead of dynamic_update_slice — elementwise
    # and sharding-preserving, avoids the per-layer cache all-gather that
    # SPMD inserts for a traced-offset DUS into a sequence-sharded cache.
    onehot_cache_update: bool = False
    # decode with unexpanded GQA K/V (the (Hk,G) reshape is negligible for a
    # single query token; skips materializing the G-times-expanded cache).
    decode_unexpanded_gqa: bool = False
    # map the model axis to extra data parallelism (small archs for which
    # 16-way tensor parallel is pure overhead).
    dp_only: bool = False
    # attention softmax pipeline dtype on the XLA path ("float32" matches
    # the kernels' fp32 VMEM accumulators; "bfloat16" halves the HBM
    # traffic of the materialized score pipeline at ~1e-2 rel tolerance).
    softmax_dtype: str = "float32"
    # remat policy for the layer scan: "full" (recompute everything),
    # "dots" (save matmul outputs — trades HBM capacity for bandwidth).
    remat_policy: str = "full"
    # MoE data plane: "scatter" materializes (T·k, M) dispatch/combine
    # tensors (baseline); "gather" inverts the slot→token map so only
    # (E_local·C, M) tensors ever exist — O(k·capacity_factor/E_local)
    # smaller (§Perf hillclimb on the MoE cells).
    moe_dispatch: str = "scatter"

    def __post_init__(self):
        if self.head_dim is None:
            object.__setattr__(self, "head_dim",
                               self.d_model // max(self.n_heads, 1))
        if self.family in ("moe", "hybrid") and not self.n_experts:
            raise ValueError(f"{self.name}: MoE family needs n_experts")
        if self.family in ("ssm", "hybrid") and not self.ssm_state:
            raise ValueError(f"{self.name}: SSM family needs ssm_state")

    # -- derived -----------------------------------------------------------
    @property
    def n_heads_eff(self) -> int:
        """Lowered head count (pad_heads_to when set)."""
        return self.pad_heads_to or self.n_heads

    @property
    def d_inner(self) -> int:
        """Mamba inner width."""
        return self.ssm_expand * self.d_model

    @property
    def ssm_heads(self) -> int:
        return self.d_inner // self.ssm_head_dim

    @property
    def attends(self) -> bool:
        return self.family != "ssm"

    def reduced(self, seq_hint: int = 128) -> "ModelConfig":
        """Tiny same-family config for CPU smoke tests."""
        kv = max(1, min(self.n_kv_heads, 2))
        heads = max(kv * 2, 4)
        return dataclasses.replace(
            self,
            name=self.name + "-reduced",
            n_layers=min(self.n_layers, 4) if self.family != "hybrid"
                     else self.hybrid_period,
            d_model=64,
            n_heads=heads,
            n_kv_heads=kv,
            head_dim=16,
            d_ff=128,
            vocab=256,
            n_experts=min(self.n_experts, 8) if self.n_experts else 0,
            top_k=min(self.top_k, 2) if self.top_k else 0,
            n_shared_experts=min(self.n_shared_experts, 1),
            ssm_state=min(self.ssm_state, 16) if self.ssm_state else 0,
            ssm_head_dim=16,
            n_encoder_layers=min(self.n_encoder_layers, 2),
            pad_heads_to=None,
            q_chunk=max(seq_hint // 2, 16),
            kv_chunk=max(seq_hint // 2, 16),
            max_cache_len=seq_hint,
            microbatches=1,
        )
