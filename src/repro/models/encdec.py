"""Encoder–decoder LM (seamless-m4t-v2 backbone).

The audio/text modality frontend is a STUB per the assignment brief:
``input_specs()`` supplies precomputed frame embeddings (B, T, d_model) for
the encoder.  The decoder is a standard causal transformer with
cross-attention; decode caches both the self-attention KV *and* the
projected encoder memory K/V (computed once at prefill, the receiver-driven
"fetch once, replicate locally" pattern of DStore applied to activations).
"""

from __future__ import annotations

from typing import Any, NamedTuple

import jax
import jax.numpy as jnp

from .attention import (KVCache, attention, attention_decls,
                        blockwise_attention, init_cache)
from .common import cross_entropy_loss, rms_norm
from .config import ModelConfig
from .ffn import mlp, mlp_decls
from .lm import _constrain_tokens
from .param import ArrayDecl, normal_init, ones_init

__all__ = ["EncDecLM", "EncDecCache"]


class EncDecCache(NamedTuple):
    self_kv: Any              # stacked KVCache (decoder self-attn)
    cross_k: jax.Array        # (L, B, T, Hk, D) projected encoder memory
    cross_v: jax.Array


class EncDecLM:
    def __init__(self, cfg: ModelConfig):
        if cfg.family != "encdec":
            raise ValueError(cfg.family)
        if not cfg.n_encoder_layers:
            raise ValueError("encdec needs n_encoder_layers")
        self.cfg = cfg

    # -- schema ------------------------------------------------------------
    def param_decls(self) -> dict:
        cfg = self.cfg
        Le, Ld = cfg.n_encoder_layers, cfg.n_layers
        return {
            "embed": ArrayDecl((cfg.vocab, cfg.d_model), ("vocab", "embed"),
                               init=normal_init(0.02)),
            "head": ArrayDecl((cfg.d_model, cfg.vocab), ("embed", "vocab")),
            "enc_final_norm": ArrayDecl((cfg.d_model,), ("embed",),
                                        init=ones_init),
            "final_norm": ArrayDecl((cfg.d_model,), ("embed",),
                                    init=ones_init),
            "encoder": {
                "ln1": ArrayDecl((Le, cfg.d_model), ("layers", "embed"),
                                 init=ones_init),
                "attn": attention_decls(cfg, layers=Le),
                "ln2": ArrayDecl((Le, cfg.d_model), ("layers", "embed"),
                                 init=ones_init),
                "mlp": mlp_decls(cfg, layers=Le),
            },
            "decoder": {
                "ln1": ArrayDecl((Ld, cfg.d_model), ("layers", "embed"),
                                 init=ones_init),
                "self_attn": attention_decls(cfg, layers=Ld),
                "ln2": ArrayDecl((Ld, cfg.d_model), ("layers", "embed"),
                                 init=ones_init),
                "cross_attn": attention_decls(cfg, layers=Ld),
                "ln3": ArrayDecl((Ld, cfg.d_model), ("layers", "embed"),
                                 init=ones_init),
                "mlp": mlp_decls(cfg, layers=Ld),
            },
        }

    # -- encoder -----------------------------------------------------------
    def encode(self, params, frames):
        """frames: (B, T, M) precomputed embeddings → memory (B, T, M)."""
        cfg = self.cfg
        x = _constrain_tokens(frames.astype(jnp.bfloat16))

        def body(x, lp):
            h, _ = attention(lp["attn"], rms_norm(x, lp["ln1"]), cfg,
                             causal=False)
            x = x + h
            x = x + mlp(lp["mlp"], rms_norm(x, lp["ln2"]), cfg)
            return _constrain_tokens(x), None

        if cfg.remat:
            body = jax.checkpoint(body)
        x, _ = jax.lax.scan(body, x, params["encoder"])
        return rms_norm(x, params["enc_final_norm"])

    # -- decoder -----------------------------------------------------------
    def _decoder_layer(self, lp, x, memory, *, self_cache=None,
                       cross_kv=None):
        cfg = self.cfg
        h, new_kv = attention(lp["self_attn"], rms_norm(x, lp["ln1"]), cfg,
                              cache=self_cache)
        x = x + h
        xn = rms_norm(x, lp["ln2"])
        if cross_kv is not None:
            ck, cv = cross_kv
            q = jnp.einsum("bsm,mhd->bshd", xn, lp["cross_attn"]["wq"])
            out = blockwise_attention(q, ck, cv, causal=False,
                                      q_chunk=cfg.q_chunk,
                                      kv_chunk=cfg.kv_chunk)
            h = jnp.einsum("bshd,hdm->bsm", out, lp["cross_attn"]["wo"])
        else:
            h, _ = attention(lp["cross_attn"], xn, cfg, kv_source=memory)
        x = x + h
        x = x + mlp(lp["mlp"], rms_norm(x, lp["ln3"]), cfg)
        return x, new_kv

    def forward(self, params, frames, tokens):
        """Training path: (B,T,M) frames + (B,S) tokens → logits (B,S,V)."""
        cfg = self.cfg
        memory = self.encode(params, frames)
        x = jnp.take(params["embed"], tokens, axis=0).astype(jnp.bfloat16)
        x = _constrain_tokens(x)

        def body(x, lp):
            x, _ = self._decoder_layer(lp, x, memory)
            return _constrain_tokens(x), None

        if cfg.remat:
            body = jax.checkpoint(body)
        x, _ = jax.lax.scan(body, x, params["decoder"])
        x = rms_norm(x, params["final_norm"])
        logits = jnp.einsum("bsm,mv->bsv", x,
                            params["head"].astype(x.dtype))
        return logits, jnp.zeros((), jnp.float32)

    def loss_fn(self, params, batch):
        """batch: {'frames': (B,T,M), 'tokens': (B,S+1)}."""
        tokens = batch["tokens"]
        logits, _ = self.forward(params, batch["frames"], tokens[:, :-1])
        return cross_entropy_loss(logits, tokens[:, 1:], batch.get("mask"))

    # -- serving -----------------------------------------------------------
    def init_cache(self, batch: int, max_len: int,
                   memory_len: int) -> EncDecCache:
        cfg = self.cfg
        L = cfg.n_layers
        # Self-attention KV stays at compute precision: the double-sublayer
        # decoder amplifies bf16 cache rounding through the residual stream
        # (~20x over 4 layers), breaking prefill+decode vs forward
        # consistency.  The cross K/V cache can stay bf16 — its inputs are
        # already bf16, so the round-trip is exact.
        kv = init_cache(cfg, batch, max_len, dtype=jnp.float32)
        stk = jax.tree.map(
            lambda a: (jnp.broadcast_to(a, (L,) + a.shape) if a.ndim
                       else jnp.broadcast_to(a, (L,))), kv)
        shape = (L, batch, memory_len, cfg.n_kv_heads, cfg.head_dim)
        return EncDecCache(self_kv=stk,
                           cross_k=jnp.zeros(shape, jnp.bfloat16),
                           cross_v=jnp.zeros(shape, jnp.bfloat16))

    def prefill(self, params, frames, tokens, cache: EncDecCache):
        """Encode + project memory K/V once + run decoder prefill."""
        cfg = self.cfg
        memory = self.encode(params, frames)
        ck = jnp.einsum("btm,lmhd->lbthd", memory,
                        params["decoder"]["cross_attn"]["wk"])
        cv = jnp.einsum("btm,lmhd->lbthd", memory,
                        params["decoder"]["cross_attn"]["wv"])
        cache = cache._replace(cross_k=ck.astype(cache.cross_k.dtype),
                               cross_v=cv.astype(cache.cross_v.dtype))
        return self._run_decoder_cached(params, tokens, cache)

    def decode_step(self, params, token, cache: EncDecCache):
        return self._run_decoder_cached(params, token, cache)

    def _run_decoder_cached(self, params, tokens, cache: EncDecCache):
        cfg = self.cfg
        x = jnp.take(params["embed"], tokens, axis=0).astype(jnp.bfloat16)
        x = _constrain_tokens(x)

        def body(x, inp):
            lp, kv, ck, cv = inp
            x, new_kv = self._decoder_layer(lp, x, None, self_cache=kv,
                                            cross_kv=(ck, cv))
            return x, new_kv

        x, new_kvs = jax.lax.scan(
            body, x, (params["decoder"], cache.self_kv,
                      cache.cross_k, cache.cross_v))
        x = rms_norm(x, params["final_norm"])
        logits = jnp.einsum("bsm,mv->bsv", x[:, -1:],
                            params["head"].astype(x.dtype))
        return logits, cache._replace(self_kv=new_kvs)
