"""Feed-forward sublayers: gated (SwiGLU) and plain (squared-ReLU) MLPs."""

from __future__ import annotations

import jax
import jax.numpy as jnp

from .common import ACTIVATIONS
from .config import ModelConfig
from .param import ArrayDecl

__all__ = ["mlp_decls", "mlp"]


def mlp_decls(cfg: ModelConfig, layers: int | None = None,
              d_ff: int | None = None) -> dict:
    M = cfg.d_model
    F = cfg.d_ff if d_ff is None else d_ff
    lead = (layers,) if layers else ()
    lax_ = ("layers",) if layers else ()
    decls = {
        "w_up": ArrayDecl(lead + (M, F), lax_ + ("embed", "mlp")),
        "w_down": ArrayDecl(lead + (F, M), lax_ + ("mlp", "embed")),
    }
    if cfg.glu:
        decls["w_gate"] = ArrayDecl(lead + (M, F), lax_ + ("embed", "mlp"))
    return decls


def mlp(params: dict, x: jax.Array, cfg: ModelConfig) -> jax.Array:
    act = ACTIVATIONS[cfg.activation]
    up = jnp.einsum("bsm,mf->bsf", x, params["w_up"])
    if cfg.glu:
        gate = jnp.einsum("bsm,mf->bsf", x, params["w_gate"])
        h = act(gate) * up
    else:
        h = act(up)
    return jnp.einsum("bsf,fm->bsm", h, params["w_down"])
