"""Decoder-only LM assembly for all decoder families.

One scan over stacked per-layer parameters (bounded compile time for the
40–94-layer assigned configs), `jax.checkpoint` remat per scanned layer,
activation sharding constraints at layer boundaries, and three entry points:

* ``loss_fn(params, batch)``          — next-token CE (+ MoE aux) for train;
* ``prefill(params, tokens, ...)``    — fills a stacked KV/SSM cache;
* ``decode_step(params, cache, tok)`` — one token (the ``decode_*`` and
  ``long_500k`` dry-run cells lower this).

Families: ``dense`` | ``moe`` | ``ssm`` (mamba-2) | ``hybrid`` (jamba) |
``vlm`` (M-RoPE + precomputed patch embeddings — frontend stubbed per the
assignment).
"""

from __future__ import annotations

import dataclasses
from functools import partial
from typing import Any, NamedTuple

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from .attention import (KVCache, attention, attention_decls, init_cache)
from .common import cross_entropy_loss, rms_norm
from .config import ModelConfig
from .ffn import mlp, mlp_decls
from .moe import moe, moe_decls
from .param import ArrayDecl, normal_init, ones_init
from .ssm import SSMCache, init_ssm_cache, mamba_block, ssm_decls
from ..sharding.context import current_mesh, data_axes

__all__ = ["LM", "Cache"]

AUX_COEF = 0.01


class Cache(NamedTuple):
    """Stacked per-layer serving cache (members may be None per family)."""
    kv: Any = None           # KVCache with leading layer dim
    ssm: Any = None          # SSMCache with leading layer dim


def _constrain_tokens(x: jax.Array, cfg=None) -> jax.Array:
    """batch→data sharding hint on (B, S, M) activations (dp_only archs
    spread the batch over the model axis as well)."""
    mesh = current_mesh()
    d = data_axes(mesh)
    if cfg is not None and getattr(cfg, "dp_only", False) \
            and "model" in mesh.axis_names:
        d = d + ("model",)
    if not d:
        return x
    spec = P(tuple(d) if len(d) > 1 else d[0], *([None] * (x.ndim - 1)))
    return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, spec))


class LM:
    def __init__(self, cfg: ModelConfig):
        if cfg.family not in ("dense", "moe", "ssm", "hybrid", "vlm"):
            raise ValueError(cfg.family)
        self.cfg = cfg

    # ------------------------------------------------------------------
    # parameter schema
    # ------------------------------------------------------------------
    def _layer_decls(self) -> dict:
        cfg = self.cfg
        L = cfg.n_layers
        if cfg.family in ("dense", "vlm"):
            return {
                "ln1": ArrayDecl((L, cfg.d_model), ("layers", "embed"),
                                 init=ones_init),
                "attn": attention_decls(cfg, layers=L),
                "ln2": ArrayDecl((L, cfg.d_model), ("layers", "embed"),
                                 init=ones_init),
                "mlp": mlp_decls(cfg, layers=L),
            }
        if cfg.family == "moe":
            return {
                "ln1": ArrayDecl((L, cfg.d_model), ("layers", "embed"),
                                 init=ones_init),
                "attn": attention_decls(cfg, layers=L),
                "ln2": ArrayDecl((L, cfg.d_model), ("layers", "embed"),
                                 init=ones_init),
                "moe": moe_decls(cfg, layers=L),
            }
        if cfg.family == "ssm":
            return {
                "ln1": ArrayDecl((L, cfg.d_model), ("layers", "embed"),
                                 init=ones_init),
                "mamba": ssm_decls(cfg, layers=L),
            }
        # hybrid (jamba): super-blocks of `period` sublayers
        nb = cfg.n_layers // cfg.hybrid_period
        per = cfg.hybrid_period
        n_mamba = per - 1
        n_moe = per // cfg.hybrid_moe_every
        n_mlp = per - n_moe
        sub = {
            "mamba": ssm_decls(cfg, layers=n_mamba),
            "attn": attention_decls(cfg),
            "moe": moe_decls(cfg, layers=n_moe),
            "mlp": mlp_decls(cfg, layers=n_mlp),
            "ln_mix": ArrayDecl((per, cfg.d_model), (None, "embed"),
                                init=ones_init),
            "ln_ffn": ArrayDecl((per, cfg.d_model), (None, "embed"),
                                init=ones_init),
        }

        def add_block_dim(d: ArrayDecl) -> ArrayDecl:
            return ArrayDecl((nb,) + d.shape, ("layers",) + d.axes,
                             dtype=d.dtype, init=d.init)
        return jax.tree.map(add_block_dim, sub,
                            is_leaf=lambda x: isinstance(x, ArrayDecl))

    def param_decls(self) -> dict:
        cfg = self.cfg
        decls = {
            "embed": ArrayDecl((cfg.vocab, cfg.d_model), ("vocab", "embed"),
                               init=normal_init(0.02)),
            "final_norm": ArrayDecl((cfg.d_model,), ("embed",),
                                    init=ones_init),
            "layers": self._layer_decls(),
        }
        if not cfg.tie_embeddings:
            decls["head"] = ArrayDecl((cfg.d_model, cfg.vocab),
                                      ("embed", "vocab"))
        return decls

    # ------------------------------------------------------------------
    # layer bodies
    # ------------------------------------------------------------------
    def _dense_layer(self, lp, x, *, mrope_positions=None, cache=None,
                     positions=None):
        cfg = self.cfg
        h, new_kv = attention(lp["attn"], rms_norm(x, lp["ln1"]), cfg,
                              mrope_positions=mrope_positions, cache=cache,
                              positions=positions)
        x = x + h
        if "moe" in lp:
            y, aux = moe(lp["moe"], rms_norm(x, lp["ln2"]), cfg)
            # name the EP-psum result so the "names" remat policy can save
            # it — otherwise the backward re-executes the fwd psum (§Perf).
            from jax.ad_checkpoint import checkpoint_name
            y = checkpoint_name(y, "moe_out")
        else:
            y, aux = mlp(lp["mlp"], rms_norm(x, lp["ln2"]), cfg), 0.0
        return x + y, aux, new_kv

    def _ssm_layer(self, lp, x, *, cache=None):
        h, new_ssm = mamba_block(lp["mamba"], rms_norm(x, lp["ln1"]),
                                 self.cfg, cache=cache)
        return x + h, new_ssm

    def _hybrid_block(self, bp, x, *, cache=None, positions=None):
        """One jamba super-block: `period` sublayers, attn at one index,
        MoE on alternating FFNs.  cache = (KVCache, SSMCache[n_mamba])."""
        cfg = self.cfg
        per = cfg.hybrid_period
        aux_total = 0.0
        mi = fi_moe = fi_mlp = 0
        kv_in = cache.kv if cache is not None else None
        ssm_in = cache.ssm if cache is not None else None
        kv_out, ssm_out = None, []
        for i in range(per):
            xn = rms_norm(x, bp["ln_mix"][i])
            if i == cfg.hybrid_attn_index:
                h, kv_out = attention(bp["attn"], xn, cfg, cache=kv_in,
                                      positions=positions)
            else:
                sc = jax.tree.map(lambda a: a[mi], ssm_in) \
                    if ssm_in is not None else None
                h, s_new = mamba_block(
                    jax.tree.map(lambda a: a[mi], bp["mamba"]), xn, cfg,
                    cache=sc)
                if s_new is not None:
                    ssm_out.append(s_new)
                mi += 1
            x = x + h
            xn = rms_norm(x, bp["ln_ffn"][i])
            if i % cfg.hybrid_moe_every == 1:
                y, aux = moe(jax.tree.map(lambda a: a[fi_moe], bp["moe"]),
                             xn, cfg)
                aux_total = aux_total + aux
                fi_moe += 1
            else:
                y = mlp(jax.tree.map(lambda a: a[fi_mlp], bp["mlp"]), xn, cfg)
                fi_mlp += 1
            x = x + y
        new_cache = None
        if cache is not None:
            new_cache = Cache(
                kv=kv_out,
                ssm=jax.tree.map(lambda *xs: jnp.stack(xs), *ssm_out)
                if ssm_out else None)
        return x, aux_total, new_cache

    # ------------------------------------------------------------------
    # forward (training / full-sequence)
    # ------------------------------------------------------------------
    def forward(self, params, tokens, *, vision_embeds=None,
                mrope_positions=None):
        """tokens: (B, S) → logits (B, S, V); also returns aux loss."""
        cfg = self.cfg
        x = jnp.take(params["embed"], tokens, axis=0).astype(jnp.bfloat16)
        if vision_embeds is not None:
            nv = vision_embeds.shape[1]
            x = jnp.concatenate(
                [vision_embeds.astype(x.dtype), x[:, :-nv or None]], axis=1) \
                if nv else x
            x = x[:, :tokens.shape[1]]
        x = _constrain_tokens(x, cfg)

        lp = params["layers"]
        fam = cfg.family

        def body(carry, layer_params):
            x, aux = carry
            if fam in ("dense", "vlm", "moe"):
                x2, a, _ = self._dense_layer(
                    layer_params, x, mrope_positions=mrope_positions)
            elif fam == "ssm":
                x2, _ = self._ssm_layer(layer_params, x)
                a = 0.0
            else:
                x2, a, _ = self._hybrid_block(layer_params, x)
            x2 = _constrain_tokens(x2, cfg)
            return (x2, aux + a), None

        if cfg.remat:
            if cfg.remat_policy == "dots":
                body = jax.checkpoint(
                    body, policy=jax.checkpoint_policies.checkpoint_dots)
            elif cfg.remat_policy == "names":
                body = jax.checkpoint(
                    body,
                    policy=jax.checkpoint_policies.save_only_these_names(
                        "moe_out"))
            else:
                body = jax.checkpoint(body)
        (x, aux), _ = jax.lax.scan(body, (x, jnp.zeros((), jnp.float32)), lp)
        x = rms_norm(x, params["final_norm"])
        head = params["embed"].T if cfg.tie_embeddings else params["head"]
        logits = jnp.einsum("bsm,mv->bsv", x, head.astype(x.dtype))
        return logits, aux

    def loss_fn(self, params, batch):
        """batch: {'tokens': (B, S+1), optional 'vision_embeds',
        'mrope_positions', 'mask'} → scalar fp32 loss."""
        tokens = batch["tokens"]
        logits, aux = self.forward(
            params, tokens[:, :-1],
            vision_embeds=batch.get("vision_embeds"),
            mrope_positions=batch.get("mrope_positions"))
        ce = cross_entropy_loss(logits, tokens[:, 1:], batch.get("mask"))
        return ce + AUX_COEF * aux

    # ------------------------------------------------------------------
    # serving
    # ------------------------------------------------------------------
    def init_cache(self, batch: int, max_len: int) -> Cache:
        cfg = self.cfg
        if cfg.family in ("dense", "vlm", "moe"):
            kv = init_cache(cfg, batch, max_len)
            return Cache(kv=jax.tree.map(
                lambda a: (jnp.broadcast_to(a, (cfg.n_layers,) + a.shape)
                           if a.ndim else
                           jnp.broadcast_to(a, (cfg.n_layers,))), kv))
        if cfg.family == "ssm":
            ssm = init_ssm_cache(cfg, batch)
            return Cache(ssm=jax.tree.map(
                lambda a: jnp.broadcast_to(a, (cfg.n_layers,) + a.shape),
                ssm))
        nb = cfg.n_layers // cfg.hybrid_period
        nm = cfg.hybrid_period - 1
        kv = init_cache(cfg, batch, max_len)
        ssm = init_ssm_cache(cfg, batch)
        return Cache(
            kv=jax.tree.map(
                lambda a: (jnp.broadcast_to(a, (nb,) + a.shape)
                           if a.ndim else jnp.broadcast_to(a, (nb,))), kv),
            ssm=jax.tree.map(
                lambda a: jnp.broadcast_to(a, (nb, nm) + a.shape), ssm))

    def _apply_cached(self, params, tokens, cache: Cache, *,
                      mrope_positions=None):
        cfg = self.cfg
        fam = cfg.family
        x = jnp.take(params["embed"], tokens, axis=0).astype(jnp.bfloat16)
        x = _constrain_tokens(x, cfg)

        def body(carry, inp):
            x = carry
            layer_params, layer_cache = inp
            if fam in ("dense", "vlm", "moe"):
                x2, _, new_kv = self._dense_layer(
                    layer_params, x, cache=layer_cache.kv,
                    mrope_positions=mrope_positions)
                new_cache = Cache(kv=new_kv)
            elif fam == "ssm":
                x2, new_ssm = self._ssm_layer(layer_params, x,
                                              cache=layer_cache.ssm)
                new_cache = Cache(ssm=new_ssm)
            else:
                x2, _, new_cache = self._hybrid_block(layer_params, x,
                                                      cache=layer_cache)
            return x2, new_cache

        x, new_caches = jax.lax.scan(body, x, (params["layers"], cache))
        x = rms_norm(x, params["final_norm"])
        head = params["embed"].T if cfg.tie_embeddings else params["head"]
        logits = jnp.einsum("bsm,mv->bsv", x[:, -1:], head.astype(x.dtype))
        return logits, new_caches

    def prefill(self, params, tokens, cache: Cache, **kw):
        """tokens: (B, S).  Returns (last-token logits, filled cache)."""
        return self._apply_cached(params, tokens, cache, **kw)

    def decode_step(self, params, token, cache: Cache, **kw):
        """token: (B, 1).  Returns (logits (B,1,V), updated cache)."""
        return self._apply_cached(params, token, cache, **kw)
