"""Mixture-of-Experts FFN with expert parallelism via ``shard_map``.

This is the DFlow-style data plane applied inside one layer (DESIGN.md §3):
experts are sharded over the ``model`` mesh axis; every model-rank receives
the (data-sharded, model-replicated) token block, routes it, and *locally*
dispatches only the tokens destined for its resident experts — a
receiver-driven exchange in which each expert shard pulls exactly its own
work, and the only collective is the final ``psum`` combine (the same
all-reduce shape dense tensor-parallel FFNs pay).

Dispatch is scatter-based (sort → rank-in-expert → scatter into an
``(E_local, C, M)`` buffer), never materializing the ``(tokens, E, C)``
one-hot of the classic GShard formulation — with 384-expert configs that
tensor would be ~100 GB.  Token overflow beyond the per-expert capacity
``C = ceil(T·k/E · capacity_factor)`` is dropped (standard GShard dropping
semantics); a load-balance auxiliary loss keeps the router honest.
"""

from __future__ import annotations

import math
from functools import partial

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from .common import ACTIVATIONS, softmax_fp32
from .config import ModelConfig
from .param import ArrayDecl, normal_init
from ..sharding.compat import shard_map
from ..sharding.context import current_mesh, data_axes, model_axis

__all__ = ["moe_decls", "moe"]


def moe_decls(cfg: ModelConfig, layers: int | None = None) -> dict:
    M, F, E = cfg.d_model, cfg.d_ff, cfg.n_experts
    lead = (layers,) if layers else ()
    lax_ = ("layers",) if layers else ()
    decls = {
        "router": ArrayDecl(lead + (M, E), lax_ + ("embed", None),
                            init=normal_init(0.02), dtype=jnp.float32),
        "w_up": ArrayDecl(lead + (E, M, F),
                          lax_ + ("experts", "embed", "expert_mlp")),
        "w_down": ArrayDecl(lead + (E, F, M),
                            lax_ + ("experts", "expert_mlp", "embed")),
    }
    if cfg.glu:
        decls["w_gate"] = ArrayDecl(lead + (E, M, F),
                                    lax_ + ("experts", "embed", "expert_mlp"))
    if cfg.n_shared_experts:
        Fs = F * cfg.n_shared_experts
        decls["shared_up"] = ArrayDecl(lead + (M, Fs), lax_ + ("embed", "mlp"))
        decls["shared_gate"] = ArrayDecl(lead + (M, Fs), lax_ + ("embed", "mlp"))
        decls["shared_down"] = ArrayDecl(lead + (Fs, M), lax_ + ("mlp", "embed"))
    return decls


def _capacity(tokens: int, k: int, n_experts: int, factor: float) -> int:
    c = int(math.ceil(tokens * k / n_experts * factor))
    return max(c, 4)


def _moe_local(x, topi, gates, w_gate, w_up, w_down, shared, *,
               cfg: ModelConfig, n_model: int, has_model_axis: bool,
               d_axes: tuple[str, ...] = ()):
    """Per-device block: x (B_loc, S, M); experts (E_loc, ...).

    Routing (``topi``/``gates``, (B_loc, S, k)) is computed *outside* the
    shard_map in global pjit land — computing it per-rank would make every
    routing intermediate a replicated value whose cotangent needs a psum
    over the model axis (measured: ~2 extra activation-sized all-reduces
    per layer, §Perf kimi iteration 2)."""
    B, S, M = x.shape
    E, k = cfg.n_experts, cfg.top_k
    E_loc = E // n_model
    act = ACTIVATIONS[cfg.activation]
    t = x.reshape(B * S, M)
    T = B * S
    topi = topi.reshape(T, k)
    gates = gates.reshape(T, k)
    # Capacity per (local) expert: expected load is T·k/E tokens from this
    # data shard's block, padded by the capacity factor.
    C = _capacity(T, k, E, cfg.capacity_factor)

    rank = jax.lax.axis_index("model") if has_model_axis else 0
    e_base = rank * E_loc
    local = topi - e_base                                      # (T, k)
    sel = (local >= 0) & (local < E_loc)
    lid = jnp.where(sel, local, E_loc)                         # E_loc = drop
    lid_f = lid.reshape(-1)                                    # (T*k,)

    # rank within expert (stable sort → arrival-order priority on overflow)
    order = jnp.argsort(lid_f, stable=True)
    sorted_lid = lid_f[order]
    starts = jnp.searchsorted(sorted_lid, jnp.arange(E_loc + 1))
    pos_sorted = jnp.arange(T * k) - starts[sorted_lid]

    if cfg.moe_dispatch == "gather":
        # Index-inverted data plane: build slot→(token,k) once (O(T·k) int
        # scatter, no M factor), then dispatch = one (E_loc·C, M) gather
        # and combine = one (E_loc·C, M) scatter-add.  The (T·k, M)
        # dispatch/combine tensors of the baseline never materialize.
        Cp1 = C + 1
        slot_sorted = jnp.minimum(pos_sorted, C)
        flat_sorted = sorted_lid * Cp1 + slot_sorted       # (T*k,) in
        n_flat = (E_loc + 1) * Cp1                         # incl. drop rows
        tok_k_for_flat = jnp.zeros((n_flat,), jnp.int32).at[
            flat_sorted].set(order.astype(jnp.int32))
        valid_flat = jnp.zeros((n_flat,), jnp.bool_).at[flat_sorted].set(
            (pos_sorted < C) & (sorted_lid < E_loc))
        grid = tok_k_for_flat.reshape(E_loc + 1, Cp1)[:E_loc, :C]
        vgrid = valid_flat.reshape(E_loc + 1, Cp1)[:E_loc, :C]
        tok_grid = grid // k                               # (E_loc, C)
        buf = jnp.where(vgrid[..., None], t[tok_grid], 0)  # (E_loc, C, M)
    else:
        pos = jnp.zeros_like(pos_sorted).at[order].set(pos_sorted)
        keep = (lid_f < E_loc) & (pos < C)
        slot = jnp.where(keep, pos, C)                     # C = trash slot
        eid = jnp.where(keep, lid_f, 0)
        tok = jnp.repeat(jnp.arange(T), k)
        x_rep = jnp.where(keep[:, None], t[tok], 0).astype(t.dtype)
        buf = jnp.zeros((E_loc, C + 1, M), t.dtype)
        buf = buf.at[eid, slot].add(x_rep)
        buf = buf[:, :C]                                   # (E_loc, C, M)

    up = jnp.einsum("ecm,emf->ecf", buf, w_up)
    if w_gate is not None:
        g = jnp.einsum("ecm,emf->ecf", buf, w_gate)
        h = act(g) * up
    else:
        h = act(up)
    out_buf = jnp.einsum("ecf,efm->ecm", h, w_down)        # (E_loc, C, M)

    if cfg.moe_dispatch == "gather":
        gate_grid = jnp.where(vgrid, gates.reshape(-1)[grid], 0.0)
        contrib = (out_buf.astype(jnp.float32)
                   * gate_grid[..., None].astype(jnp.float32))
        y = jnp.zeros((T, M), jnp.float32).at[tok_grid.reshape(-1)].add(
            contrib.reshape(-1, M))
    else:
        out_buf = jnp.concatenate(
            [out_buf, jnp.zeros((E_loc, 1, M), out_buf.dtype)], axis=1)
        y_tk = out_buf[eid, slot] * keep[:, None]          # (T*k, M)
        w = (gates.reshape(-1) * keep).astype(jnp.float32)
        y = (y_tk.astype(jnp.float32) * w[:, None]).reshape(T, k, M).sum(1)

    if shared is not None:
        s_gate, s_up, s_down = shared
        g = t @ s_gate
        u = t @ s_up
        y = y + ((act(g) * u) @ s_down).astype(jnp.float32)

    if has_model_axis:
        y = jax.lax.psum(y, "model")
    return y.reshape(B, S, M).astype(x.dtype)


def moe(params: dict, x: jax.Array, cfg: ModelConfig):
    """MoE sublayer.  x: (B, S, M) → (y, aux_loss)."""
    mesh = current_mesh()
    m_axis = model_axis(mesh)
    d_axes = data_axes(mesh)
    n_model = mesh.shape[m_axis] if m_axis else 1
    has_model = m_axis is not None
    E, k = cfg.n_experts, cfg.top_k

    # -- routing in global pjit land (replicated math stays out of the
    # manual region; see _moe_local docstring) --------------------------
    logits = jnp.einsum("bsm,me->bse", x.astype(jnp.float32),
                        params["router"])
    probs = jax.nn.softmax(logits, axis=-1)
    topv, topi = jax.lax.top_k(probs, k)                # (B, S, k)
    gates = topv / jnp.maximum(topv.sum(-1, keepdims=True), 1e-9)
    # GShard load-balance aux: importance × top-1 load over global tokens.
    me = probs.reshape(-1, E).mean(axis=0)
    ce = jax.nn.one_hot(topi[..., 0].reshape(-1), E,
                        dtype=jnp.float32).mean(axis=0)
    aux = E * jnp.sum(me * ce)

    shared = None
    if cfg.n_shared_experts:
        shared = (params["shared_gate"], params["shared_up"],
                  params["shared_down"])

    fn = partial(_moe_local, cfg=cfg, n_model=n_model,
                 has_model_axis=has_model, d_axes=d_axes)

    nd = 1
    for a in d_axes:
        nd *= mesh.shape[a]
    if d_axes and x.shape[0] % nd == 0:
        bspec = tuple(d_axes) if len(d_axes) > 1 else d_axes[0]
    else:
        bspec = None        # tiny decode batches: replicate tokens
    dspec = P(bspec, None, None)                        # (B, S, M)
    kspec = P(bspec, None, None)                        # (B, S, k)
    espec3 = P(m_axis, None, None)                      # (E, M, F)
    sspec = P(None, m_axis)                             # shared up/gate (M,Fs)
    sdspec = P(m_axis, None)                            # shared down (Fs,M)

    w_gate = params.get("w_gate")
    args = [x, topi, gates, params["w_up"], params["w_down"]]
    in_specs = [dspec, kspec, kspec, espec3, espec3]
    if w_gate is not None:
        args.append(w_gate)
        in_specs.append(espec3)
    if shared is not None:
        args.extend(shared)                 # gate, up, down
        in_specs.extend([sspec, sspec, sdspec])

    def wrapped(x_, ti_, g_, wu_, wd_, *rest):
        rest = list(rest)
        wg_ = rest.pop(0) if w_gate is not None else None
        sh_ = tuple(rest) if shared is not None else None  # (gate, up, down)
        return fn(x_, ti_, g_, wg_, wu_, wd_, sh_)

    y = shard_map(
        wrapped, mesh=mesh,
        in_specs=tuple(in_specs), out_specs=dspec,
        check_vma=False,
    )(*args)
    return y, aux
