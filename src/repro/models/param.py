"""Parameter declaration system: one schema → init, shapes, shardings.

Every model declares its parameters as a pytree of :class:`ArrayDecl`
(shape, dtype, *logical axes*, initializer).  From that single schema we
derive:

* ``init_params``      — materialized arrays (smoke tests, examples);
* ``abstract_params``  — ``jax.ShapeDtypeStruct`` tree (dry-run lowering:
  no allocation ever happens for the full-size configs);
* ``logical_axes``     — pytree of logical-axis tuples which
  :mod:`repro.sharding.rules` maps to mesh ``PartitionSpec``s.

Logical axis vocabulary (mapped in sharding/rules.py):
``batch, seq, embed, heads, kv_heads, head_dim, mlp, vocab, experts,
expert_mlp, ssm_heads, ssm_state, conv, layers, stage, None``.
"""

from __future__ import annotations

import dataclasses
import math
from typing import Any, Callable, Mapping

import jax
import jax.numpy as jnp
import numpy as np

__all__ = ["ArrayDecl", "init_params", "abstract_params", "logical_axes",
           "param_count", "param_bytes"]


Initializer = Callable[[jax.Array, tuple[int, ...], Any], jax.Array]


def _fan_in_init(fan_axis: int = -2):
    def init(key, shape, dtype):
        fan_in = shape[fan_axis] if len(shape) >= 2 else shape[-1]
        scale = 1.0 / math.sqrt(max(fan_in, 1))
        return (jax.random.normal(key, shape, jnp.float32) * scale).astype(dtype)
    return init


def zeros_init(key, shape, dtype):
    return jnp.zeros(shape, dtype)


def ones_init(key, shape, dtype):
    return jnp.ones(shape, dtype)


def normal_init(stddev: float = 0.02):
    def init(key, shape, dtype):
        return (jax.random.normal(key, shape, jnp.float32) * stddev).astype(dtype)
    return init


@dataclasses.dataclass(frozen=True)
class ArrayDecl:
    """Declaration of one parameter array."""

    shape: tuple[int, ...]
    axes: tuple[str | None, ...]          # logical axis names, len == ndim
    dtype: Any = jnp.bfloat16
    init: Initializer | None = None       # default: fan-in normal

    def __post_init__(self):
        if len(self.shape) != len(self.axes):
            raise ValueError(
                f"shape {self.shape} vs axes {self.axes} rank mismatch")

    def initializer(self) -> Initializer:
        return self.init if self.init is not None else _fan_in_init()

    @property
    def size(self) -> int:
        return int(np.prod(self.shape)) if self.shape else 1

    @property
    def nbytes(self) -> int:
        return self.size * jnp.dtype(self.dtype).itemsize


def _is_decl(x) -> bool:
    return isinstance(x, ArrayDecl)


def init_params(decls, key: jax.Array):
    """Materialize a pytree of ArrayDecl into arrays (deterministic)."""
    leaves, treedef = jax.tree.flatten(decls, is_leaf=_is_decl)
    keys = jax.random.split(key, len(leaves))
    arrays = [d.initializer()(k, d.shape, d.dtype)
              for d, k in zip(leaves, keys)]
    return jax.tree.unflatten(treedef, arrays)


def abstract_params(decls):
    """ShapeDtypeStruct tree — for .lower() without touching memory."""
    return jax.tree.map(
        lambda d: jax.ShapeDtypeStruct(d.shape, d.dtype),
        decls, is_leaf=_is_decl)


def logical_axes(decls):
    """Pytree of logical-axis tuples, same structure as the params."""
    return jax.tree.map(lambda d: d.axes, decls, is_leaf=_is_decl)


def param_count(decls) -> int:
    return sum(d.size for d in jax.tree.leaves(decls, is_leaf=_is_decl))


def param_bytes(decls) -> int:
    return sum(d.nbytes for d in jax.tree.leaves(decls, is_leaf=_is_decl))
