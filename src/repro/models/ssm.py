"""Mamba-2 (SSD — state-space duality) block, chunked scan + cached decode.

The SSD computation follows the minimal chunked formulation of the Mamba-2
paper (arXiv:2405.21060, `ssd_minimal`): the sequence is cut into chunks of
length Q; within a chunk the dual quadratic (attention-like) form is used,
and a ``lax.scan`` carries the (heads, head_dim, state) recurrent state
across chunks.  This gives O(S·Q) work with O(Q²) intra-chunk matrices —
the same structure the Pallas `ssd` kernel tiles into VMEM.

Decode is the O(1) recurrence: ``h ← h·exp(dt·A) + dt·x⊗B;  y = h·C + D·x``,
with a rolling buffer for the short causal conv.

Sharding: the inner width (``d_inner = 2·d_model``) is head-major
(heads × head_dim) and heads shard over the ``model`` axis; B/C projections
are head-shared (G=1 groups) and replicated.
"""

from __future__ import annotations

import math
from typing import NamedTuple

import jax
import jax.numpy as jnp

from .common import rms_norm, silu
from .config import ModelConfig
from .param import ArrayDecl, normal_init, ones_init, zeros_init

__all__ = ["ssm_decls", "SSMCache", "init_ssm_cache", "mamba_block",
           "ssd_chunked", "ssd_decode_step"]


class SSMCache(NamedTuple):
    state: jax.Array      # (B, H, P, N) recurrent state
    conv_x: jax.Array     # (B, K-1, DI) conv tail for x
    conv_B: jax.Array     # (B, K-1, N)
    conv_C: jax.Array     # (B, K-1, N)


def ssm_decls(cfg: ModelConfig, layers: int | None = None) -> dict:
    M, DI = cfg.d_model, cfg.d_inner
    H, P, N, K = cfg.ssm_heads, cfg.ssm_head_dim, cfg.ssm_state, cfg.ssm_conv
    lead = (layers,) if layers else ()
    lax_ = ("layers",) if layers else ()

    def dt_bias_init(key, shape, dtype):
        # dt in [1e-3, 1e-1] after softplus — standard mamba init.
        u = jax.random.uniform(key, shape, jnp.float32)
        dt = jnp.exp(u * (math.log(0.1) - math.log(1e-3)) + math.log(1e-3))
        return (dt + jnp.log(-jnp.expm1(-dt))).astype(dtype)

    def a_log_init(key, shape, dtype):
        a = jnp.arange(1, shape[-1] + 1, dtype=jnp.float32)
        return jnp.broadcast_to(jnp.log(a), shape).astype(dtype)

    return {
        "w_z": ArrayDecl(lead + (M, DI), lax_ + ("embed", "ssm_inner")),
        "w_x": ArrayDecl(lead + (M, DI), lax_ + ("embed", "ssm_inner")),
        "w_B": ArrayDecl(lead + (M, N), lax_ + ("embed", None)),
        "w_C": ArrayDecl(lead + (M, N), lax_ + ("embed", None)),
        "w_dt": ArrayDecl(lead + (M, H), lax_ + ("embed", "ssm_heads")),
        "dt_bias": ArrayDecl(lead + (H,), lax_ + ("ssm_heads",),
                             dtype=jnp.float32, init=dt_bias_init),
        "A_log": ArrayDecl(lead + (H,), lax_ + ("ssm_heads",),
                           dtype=jnp.float32, init=a_log_init),
        "D": ArrayDecl(lead + (H,), lax_ + ("ssm_heads",),
                       dtype=jnp.float32, init=ones_init),
        "conv_x": ArrayDecl(lead + (K, DI), lax_ + (None, "ssm_inner"),
                            init=normal_init(0.1)),
        "conv_B": ArrayDecl(lead + (K, N), lax_ + (None, None),
                            init=normal_init(0.1)),
        "conv_C": ArrayDecl(lead + (K, N), lax_ + (None, None),
                            init=normal_init(0.1)),
        "norm": ArrayDecl(lead + (DI,), lax_ + ("ssm_inner",),
                          init=ones_init),
        "out_proj": ArrayDecl(lead + (DI, M), lax_ + ("ssm_inner", "embed")),
    }


def init_ssm_cache(cfg: ModelConfig, batch: int,
                   dtype=jnp.float32) -> SSMCache:
    H, P, N, K = (cfg.ssm_heads, cfg.ssm_head_dim, cfg.ssm_state,
                  cfg.ssm_conv)
    DI = cfg.d_inner
    return SSMCache(
        state=jnp.zeros((batch, H, P, N), dtype),
        conv_x=jnp.zeros((batch, K - 1, DI), dtype),
        conv_B=jnp.zeros((batch, K - 1, N), dtype),
        conv_C=jnp.zeros((batch, K - 1, N), dtype),
    )


def _causal_conv(u: jax.Array, w: jax.Array,
                 tail: jax.Array | None = None) -> jax.Array:
    """Depthwise causal conv via K shifted adds.  u: (B,S,C); w: (K,C)."""
    K = w.shape[0]
    if tail is None:
        pad = jnp.zeros((u.shape[0], K - 1, u.shape[2]), u.dtype)
    else:
        pad = tail.astype(u.dtype)
    full = jnp.concatenate([pad, u], axis=1)           # (B, S+K-1, C)
    S = u.shape[1]
    out = jnp.zeros_like(u)
    for j in range(K):
        out = out + full[:, j:j + S, :] * w[j]
    return out


def _segsum(logd: jax.Array) -> jax.Array:
    """L[i,j] = sum_{j<t<=i} logd_t for j<=i else -inf.  logd: (..., Q)."""
    Q = logd.shape[-1]
    cs = jnp.cumsum(logd, axis=-1)
    diff = cs[..., :, None] - cs[..., None, :]         # (..., Q, Q)
    idx = jnp.arange(Q)
    mask = idx[:, None] >= idx[None, :]
    return jnp.where(mask, diff, -jnp.inf)


def ssd_chunked(x: jax.Array, dt: jax.Array, A: jax.Array, Bm: jax.Array,
                Cm: jax.Array, chunk: int,
                state0: jax.Array | None = None):
    """Chunked SSD.  x: (B,S,H,P); dt: (B,S,H); A: (H,) negative;
    Bm, Cm: (B,S,N) (head-shared, G=1).  Returns (y, final_state)."""
    Bb, S, H, P = x.shape
    N = Bm.shape[-1]
    chunk = min(chunk, S)
    if S % chunk:
        raise ValueError(f"seq {S} % chunk {chunk} != 0")
    nc = S // chunk

    xr = jnp.moveaxis(x.reshape(Bb, nc, chunk, H, P), 1, 0)
    dtr = jnp.moveaxis(dt.reshape(Bb, nc, chunk, H), 1, 0)
    Br = jnp.moveaxis(Bm.reshape(Bb, nc, chunk, N), 1, 0)
    Cr = jnp.moveaxis(Cm.reshape(Bb, nc, chunk, N), 1, 0)

    if state0 is None:
        state0 = jnp.zeros((Bb, H, P, N), jnp.float32)

    def body(state, inp):
        xc, dtc, Bc, Cc = inp                # (B,Q,H,P), (B,Q,H), (B,Q,N)
        dtc32 = dtc.astype(jnp.float32)
        logd = dtc32 * A                      # (B,Q,H) negative
        xdt = (xc.astype(jnp.float32) * dtc32[..., None])
        # intra-chunk (dual/attention form)
        Lseg = _segsum(jnp.moveaxis(logd, -1, 1))       # (B,H,Q,Q)
        L = jnp.exp(Lseg)
        scores = jnp.einsum("bqn,bkn->bqk", Cc.astype(jnp.float32),
                            Bc.astype(jnp.float32))     # (B,Q,Q)
        M_ = scores[:, None] * L                        # (B,H,Q,Q)
        y_intra = jnp.einsum("bhqk,bkhp->bqhp", M_, xdt)
        # inter-chunk: contribution of incoming state
        cum = jnp.cumsum(logd, axis=1)                  # (B,Q,H)
        decay_in = jnp.exp(cum)                         # decay from chunk start
        y_inter = jnp.einsum("bqn,bhpn,bqh->bqhp",
                             Cc.astype(jnp.float32), state, decay_in)
        # state update
        total = jnp.exp(cum[:, -1])                     # (B,H)
        decay_out = jnp.exp(cum[:, -1][:, None] - cum)  # (B,Q,H)
        chunk_state = jnp.einsum("bqhp,bqn,bqh->bhpn", xdt,
                                 Bc.astype(jnp.float32), decay_out)
        new_state = state * total[..., None, None] + chunk_state
        return new_state, (y_intra + y_inter).astype(x.dtype)

    final_state, ys = jax.lax.scan(body, state0, (xr, dtr, Br, Cr))
    y = jnp.moveaxis(ys, 0, 1).reshape(Bb, S, H, P)
    return y, final_state


def ssd_decode_step(x: jax.Array, dt: jax.Array, A: jax.Array,
                    Bm: jax.Array, Cm: jax.Array, state: jax.Array):
    """One-token recurrence.  x: (B,H,P); dt: (B,H); Bm,Cm: (B,N);
    state: (B,H,P,N) fp32.  Returns (y, new_state)."""
    dt32 = dt.astype(jnp.float32)
    dA = jnp.exp(dt32 * A)                               # (B,H)
    xdt = x.astype(jnp.float32) * dt32[..., None]        # (B,H,P)
    upd = jnp.einsum("bhp,bn->bhpn", xdt, Bm.astype(jnp.float32))
    new_state = state * dA[..., None, None] + upd
    y = jnp.einsum("bhpn,bn->bhp", new_state, Cm.astype(jnp.float32))
    return y.astype(x.dtype), new_state


# ----------------------------------------------------------------------
def mamba_block(params: dict, u: jax.Array, cfg: ModelConfig, *,
                cache: SSMCache | None = None):
    """Full Mamba-2 block.  u: (B, S, M) → (out, new_cache_or_None)."""
    Bb, S, M = u.shape
    H, P, N, K = (cfg.ssm_heads, cfg.ssm_head_dim, cfg.ssm_state,
                  cfg.ssm_conv)
    z = jnp.einsum("bsm,md->bsd", u, params["w_z"])
    x = jnp.einsum("bsm,md->bsd", u, params["w_x"])
    Bm = jnp.einsum("bsm,mn->bsn", u, params["w_B"])
    Cm = jnp.einsum("bsm,mn->bsn", u, params["w_C"])
    dt_raw = jnp.einsum("bsm,mh->bsh", u, params["w_dt"])
    dt = jax.nn.softplus(dt_raw.astype(jnp.float32) + params["dt_bias"])
    A = -jnp.exp(params["A_log"])                        # (H,) negative

    if cache is not None and S == 1:
        # conv via rolling buffers
        cx = jnp.concatenate([cache.conv_x, x.astype(cache.conv_x.dtype)], 1)
        cB = jnp.concatenate([cache.conv_B, Bm.astype(cache.conv_B.dtype)], 1)
        cC = jnp.concatenate([cache.conv_C, Cm.astype(cache.conv_C.dtype)], 1)
        xc = jnp.einsum("bkd,kd->bd", cx, params["conv_x"].astype(cx.dtype))
        Bc = jnp.einsum("bkn,kn->bn", cB, params["conv_B"].astype(cB.dtype))
        Cc = jnp.einsum("bkn,kn->bn", cC, params["conv_C"].astype(cC.dtype))
        xa, Ba, Ca = silu(xc), silu(Bc), silu(Cc)
        xh = xa.reshape(Bb, H, P)
        y, new_state = ssd_decode_step(xh, dt[:, 0], A, Ba, Ca, cache.state)
        y = y + params["D"][:, None] * xh.astype(jnp.float32)
        y = y.reshape(Bb, 1, H * P)
        new_cache = SSMCache(state=new_state, conv_x=cx[:, 1:],
                             conv_B=cB[:, 1:], conv_C=cC[:, 1:])
    else:
        tail = (cache.conv_x, cache.conv_B, cache.conv_C) \
            if cache is not None else (None, None, None)
        xa = silu(_causal_conv(x, params["conv_x"].astype(x.dtype), tail[0]))
        Ba = silu(_causal_conv(Bm, params["conv_B"].astype(Bm.dtype), tail[1]))
        Ca = silu(_causal_conv(Cm, params["conv_C"].astype(Cm.dtype), tail[2]))
        xh = xa.reshape(Bb, S, H, P)
        state0 = cache.state if cache is not None else None
        y, final_state = ssd_chunked(xh, dt, A, Ba, Ca,
                                     chunk=min(cfg.q_chunk, 256),
                                     state0=state0)
        y = y + params["D"][None, None, :, None] * xh
        y = y.reshape(Bb, S, H * P)
        new_cache = None
        if cache is not None:
            new_cache = SSMCache(
                state=final_state,
                conv_x=x[:, -(K - 1):].astype(cache.conv_x.dtype),
                conv_B=Bm[:, -(K - 1):].astype(cache.conv_B.dtype),
                conv_C=Cm[:, -(K - 1):].astype(cache.conv_C.dtype))

    y = y.astype(u.dtype)
    y = rms_norm(y * silu(z), params["norm"])
    out = jnp.einsum("bsd,dm->bsm", y, params["out_proj"])
    return out.astype(u.dtype), new_cache
