"""DScope CLI — ``python -m repro.obs``.

Works over the span files that ``--spans`` flags (serve_load,
dshard_routing) and :func:`repro.core.obs.write_spans_jsonl` produce,
and over the standardized ``dflow-bench/v1`` documents every
``BENCH_*.json`` emitter now writes.

Subcommands::

    python -m repro.obs summarize spans.jsonl          # span-tree stats
    python -m repro.obs attribute spans.jsonl          # plan vs actual
    python -m repro.obs perfetto  spans.jsonl -o t.json  # Chrome trace
    python -m repro.obs diff BENCH_old.json BENCH_new.json  # regressions

``attribute`` needs the DPlan attribution document; ``write_spans_jsonl``
embeds it in the head line when the producer had a plan, or pass
``--plan plan.json`` explicitly.  ``diff`` exits 1 when any gated metric
regressed beyond its tolerance — it is the CI regression gate.
"""

from __future__ import annotations

import argparse
import json
import math
import sys
from collections import Counter, defaultdict

from repro.core.obs import (Span, attribute, compare_docs, read_spans_jsonl,
                            to_chrome_trace)

__all__ = ["main"]


def _load(path: str) -> tuple[list[Span], dict]:
    try:
        return read_spans_jsonl(path)
    except (OSError, ValueError, KeyError) as exc:
        raise SystemExit(f"error: cannot read span file {path!r}: {exc}")


def _fmt_s(v: float) -> str:
    if not math.isfinite(v):
        return "-"
    return f"{v * 1e3:.2f}ms" if v < 1.0 else f"{v:.3f}s"


def _cmd_summarize(args) -> int:
    spans, meta = _load(args.spans)
    if not spans:
        print("no spans")
        return 0
    by_kind: dict[str, list[float]] = defaultdict(list)
    traces = Counter()
    for s in spans:
        by_kind[s.kind].append(s.duration)
        traces[s.trace] += 1
    print(f"{args.spans}: {len(spans)} span(s), {len(traces)} trace(s)")
    if meta.get("plan"):
        print(f"  plan: workflow {meta['plan'].get('workflow')!r} "
              f"critical_path {meta['plan'].get('critical_path')}")
    print(f"  {'kind':10s} {'n':>5s} {'mean':>9s} {'max':>9s}")
    for kind in sorted(by_kind):
        ds = [d for d in by_kind[kind] if math.isfinite(d)]
        mean = sum(ds) / len(ds) if ds else float("nan")
        mx = max(ds) if ds else float("nan")
        print(f"  {kind:10s} {len(by_kind[kind]):5d} "
              f"{_fmt_s(mean):>9s} {_fmt_s(mx):>9s}")
    if args.tree:
        _print_trees(spans, limit=args.tree)
    return 0


def _print_trees(spans: list[Span], limit: int) -> None:
    children: dict[str | None, list[Span]] = defaultdict(list)
    ids = {s.id for s in spans}
    for s in spans:
        parent = s.parent if s.parent in ids else None
        children[parent].append(s)
    roots = sorted(children[None], key=lambda s: s.seq)[:limit]

    def walk(s: Span, depth: int) -> None:
        print(f"  {'  ' * depth}{s.kind}:{s.name} "
              f"[{_fmt_s(s.duration)}]"
              + (f" {s.attrs}" if s.attrs else ""))
        for c in sorted(children[s.id], key=lambda c: c.seq):
            walk(c, depth + 1)

    for r in roots:
        walk(r, 0)


def _cmd_attribute(args) -> int:
    spans, meta = _load(args.spans)
    plan_doc = meta.get("plan")
    if args.plan:
        with open(args.plan, "r", encoding="utf-8") as fh:
            plan_doc = json.load(fh)
    if not plan_doc:
        raise SystemExit("error: no plan attribution document — the span "
                         "file has no embedded plan; pass --plan FILE")
    report = attribute(spans, plan_doc)
    if args.format == "json":
        print(json.dumps(report, indent=2))
        return 0

    def mean(agg: dict) -> str:
        return _fmt_s(agg["mean"]) if agg.get("n") else "-"

    print(f"workflow {report['workflow']!r}: {report['requests']} "
          f"request(s), critical path {report['critical_path']:.3f}s")
    lat, cpd = report["latency"], report["cp_drift"]
    print(f"  latency   mean {mean(lat)}  max "
          f"{_fmt_s(lat['max']) if lat.get('n') else '-'}")
    print(f"  cp drift  mean {mean(cpd)}  "
          f"(actual latency minus planned critical path)")
    print(f"  {'function':24s} {'start drift':>12s} {'finish drift':>12s} "
          f"{'wait':>9s} {'cold%':>6s} {'prewarm lead':>13s}")
    for row in report["functions"]:
        cold = row.get("cold_rate")
        print(f"  {row['function']:24s} "
              f"{mean(row['start_drift']):>12s} "
              f"{mean(row['finish_drift']):>12s} "
              f"{mean(row['acquire_wait']):>9s} "
              f"{(f'{cold * 100:.0f}%' if cold is not None else '-'):>6s} "
              f"{mean(row['prewarm_lead']):>13s}")
    ev = report.get("eviction_lag")
    if ev and ev["n"]:
        print(f"  eviction lag: n={ev['n']} mean {_fmt_s(ev['mean'])} "
              f"max {_fmt_s(ev['max'])} (evict after last read)")
    return 0


def _cmd_perfetto(args) -> int:
    spans, _ = _load(args.spans)
    doc = to_chrome_trace(spans)
    with open(args.out, "w", encoding="utf-8") as fh:
        json.dump(doc, fh)
        fh.write("\n")
    print(f"wrote {len(doc['traceEvents'])} trace event(s) to {args.out} "
          f"(open in ui.perfetto.dev or chrome://tracing)")
    return 0


def _cmd_diff(args) -> int:
    docs = []
    for path in (args.old, args.new):
        try:
            with open(path, "r", encoding="utf-8") as fh:
                docs.append(json.load(fh))
        except (OSError, ValueError) as exc:
            raise SystemExit(f"error: cannot read bench doc {path!r}: {exc}")
    old, new = docs
    rows, failures = compare_docs(old, new,
                                  default_tolerance=args.tolerance)
    if not rows:
        print(f"no comparable metrics ({args.old} has no standardized "
              f"'metrics' list)")
        return 1 if failures else 0
    print(f"{'system':10s} {'metric':28s} {'old':>12s} {'new':>12s} "
          f"{'delta':>8s}  gate")
    for r in rows:
        gate = ("REGRESSED" if r["regressed"]
                else r["direction"] or "report-only")
        print(f"{r['system']:10s} {r['metric']:28s} {r['old']:12.4g} "
              f"{r['new']:12.4g} {r['rel']:+8.1%}  {gate}")
    for f in failures:
        print(f"REGRESSION: {f}", file=sys.stderr)
    return 1 if failures else 0


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m repro.obs",
        description="DScope span/bench tooling")
    sub = ap.add_subparsers(dest="cmd", required=True)

    p = sub.add_parser("summarize", help="span counts + durations by kind")
    p.add_argument("spans", help="JSONL span file (write_spans_jsonl)")
    p.add_argument("--tree", type=int, default=0, metavar="N",
                   help="also print the first N request trees")
    p.set_defaults(fn=_cmd_summarize)

    p = sub.add_parser("attribute", help="plan-vs-actual drift report")
    p.add_argument("spans")
    p.add_argument("--plan", help="plan attribution JSON (defaults to the "
                   "document embedded in the span file head line)")
    p.add_argument("--format", choices=("text", "json"), default="text")
    p.set_defaults(fn=_cmd_attribute)

    p = sub.add_parser("perfetto",
                       help="export Chrome trace_event JSON (Perfetto)")
    p.add_argument("spans")
    p.add_argument("-o", "--out", default="trace.json")
    p.set_defaults(fn=_cmd_perfetto)

    p = sub.add_parser("diff",
                       help="compare two dflow-bench/v1 docs; exit 1 on "
                       "gated regression")
    p.add_argument("old", help="committed baseline BENCH_*.json")
    p.add_argument("new", help="fresh BENCH_*.json")
    p.add_argument("--tolerance", type=float, default=0.10,
                   help="default relative tolerance for gated metrics "
                   "without an explicit one (default 0.10)")
    p.set_defaults(fn=_cmd_diff)

    args = ap.parse_args(argv)
    return args.fn(args)


if __name__ == "__main__":
    raise SystemExit(main())
