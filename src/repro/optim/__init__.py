"""Optimizers: sharded AdamW + schedules + gradient compression hooks."""

from .adamw import (AdamWConfig, OptState, adamw_init, adamw_update,
                    clip_by_global_norm, warmup_cosine)
from .compress import (compress_gradients, decompress_gradients,
                       CompressionConfig)

__all__ = ["AdamWConfig", "OptState", "adamw_init", "adamw_update",
           "clip_by_global_norm", "warmup_cosine",
           "compress_gradients", "decompress_gradients", "CompressionConfig"]
