"""Pure-JAX AdamW with global-norm clipping and warmup-cosine schedule.

Production knobs:

* ``state_dtype`` — fp32 moments by default; bf16 halves optimizer HBM for
  the trillion-parameter configs (kimi-k2; see EXPERIMENTS.md memory notes).
* moments inherit the parameter sharding; with ``zero1`` the train-step
  builder additionally shards them over the data axes (ZeRO-1).
* stateless functions over explicit pytrees — the checkpointer and the
  elastic-reshard path treat the optimizer state like any other tree.
"""

from __future__ import annotations

import dataclasses
from typing import Any, NamedTuple

import jax
import jax.numpy as jnp

__all__ = ["AdamWConfig", "OptState", "adamw_init", "adamw_update",
           "clip_by_global_norm", "warmup_cosine"]


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    beta1: float = 0.9
    beta2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: float = 1.0
    warmup_steps: int = 100
    total_steps: int = 10000
    state_dtype: Any = jnp.float32


class OptState(NamedTuple):
    step: jax.Array
    m: Any
    v: Any


def warmup_cosine(cfg: AdamWConfig, step: jax.Array) -> jax.Array:
    """Linear warmup then cosine decay to 10% of peak."""
    step = step.astype(jnp.float32)
    warm = step / jnp.maximum(cfg.warmup_steps, 1)
    total = jnp.maximum(cfg.total_steps - cfg.warmup_steps, 1)
    frac = jnp.clip((step - cfg.warmup_steps) / total, 0.0, 1.0)
    cos = 0.1 + 0.9 * 0.5 * (1.0 + jnp.cos(jnp.pi * frac))
    return cfg.lr * jnp.where(step < cfg.warmup_steps, warm, cos)


def clip_by_global_norm(grads, max_norm: float):
    leaves = jax.tree.leaves(grads)
    gn = jnp.sqrt(sum(jnp.sum(jnp.square(g.astype(jnp.float32)))
                      for g in leaves))
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(gn, 1e-9))
    return jax.tree.map(lambda g: (g.astype(jnp.float32) * scale), grads), gn


def adamw_init(params, cfg: AdamWConfig) -> OptState:
    zeros = lambda p: jnp.zeros(p.shape, cfg.state_dtype)
    return OptState(step=jnp.zeros((), jnp.int32),
                    m=jax.tree.map(zeros, params),
                    v=jax.tree.map(zeros, params))


def _no_decay(path: tuple) -> bool:
    """No weight decay on norms / biases / 1-d scales."""
    last = str(path[-1]) if path else ""
    return any(k in last for k in ("norm", "ln", "bias", "dt_bias",
                                   "A_log", "D"))


def adamw_update(params, grads, state: OptState, cfg: AdamWConfig):
    """One AdamW step; returns (new_params, new_state, metrics)."""
    grads, gnorm = clip_by_global_norm(grads, cfg.clip_norm)
    step = state.step + 1
    lr = warmup_cosine(cfg, step)
    b1, b2 = cfg.beta1, cfg.beta2
    c1 = 1.0 - b1 ** step.astype(jnp.float32)
    c2 = 1.0 - b2 ** step.astype(jnp.float32)

    flat_p, treedef = jax.tree_util.tree_flatten_with_path(params)
    flat_g = jax.tree.leaves(grads)
    flat_m = jax.tree.leaves(state.m)
    flat_v = jax.tree.leaves(state.v)
    new_p, new_m, new_v = [], [], []
    for (path, p), g, m, v in zip(flat_p, flat_g, flat_m, flat_v):
        m32 = m.astype(jnp.float32) * b1 + g * (1 - b1)
        v32 = v.astype(jnp.float32) * b2 + jnp.square(g) * (1 - b2)
        upd = (m32 / c1) / (jnp.sqrt(v32 / c2) + cfg.eps)
        if cfg.weight_decay and not _no_decay(path):
            upd = upd + cfg.weight_decay * p.astype(jnp.float32)
        new_p.append((p.astype(jnp.float32) - lr * upd).astype(p.dtype))
        new_m.append(m32.astype(cfg.state_dtype))
        new_v.append(v32.astype(cfg.state_dtype))

    unflatten = jax.tree_util.tree_structure(params).unflatten
    return (unflatten(new_p),
            OptState(step=step, m=unflatten(new_m), v=unflatten(new_v)),
            {"grad_norm": gnorm, "lr": lr})
