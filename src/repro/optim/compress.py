"""Gradient compression for the data-parallel axis (beyond-paper).

Two schemes, both with deterministic behavior and an error-feedback
residual so compression error does not accumulate:

* ``int8`` — per-tensor symmetric quantization of gradients before the DP
  all-reduce (4x fewer bytes on the wire; the roofline collective term of
  the train cells drops proportionally — see EXPERIMENTS.md §Perf).
* ``topk`` — keep the largest ``ratio`` fraction of entries per tensor
  (magnitude sparsification), the rest carried in the residual.

These are hooks: ``train_lib`` applies compress→(psum)→decompress around the
gradient reduction when enabled.  On the dry-run they change the lowered
collective byte counts, which is how their effect is measured here.
"""

from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp

__all__ = ["CompressionConfig", "compress_gradients", "decompress_gradients"]


@dataclasses.dataclass(frozen=True)
class CompressionConfig:
    scheme: str = "none"          # "none" | "int8" | "topk"
    topk_ratio: float = 0.01
    error_feedback: bool = True


def compress_gradients(grads, residual, cfg: CompressionConfig):
    """→ (payload, new_residual).  payload is what crosses the DP axis."""
    if cfg.scheme == "none":
        return grads, residual

    def one(g, r):
        g32 = g.astype(jnp.float32)
        if r is not None and cfg.error_feedback:
            g32 = g32 + r.astype(jnp.float32)
        if cfg.scheme == "int8":
            scale = jnp.maximum(jnp.abs(g32).max(), 1e-12) / 127.0
            q = jnp.clip(jnp.round(g32 / scale), -127, 127).astype(jnp.int8)
            approx = q.astype(jnp.float32) * scale
            return (q, scale), g32 - approx
        if cfg.scheme == "topk":
            flat = g32.reshape(-1)
            k = max(1, int(flat.size * cfg.topk_ratio))
            vals, idx = jax.lax.top_k(jnp.abs(flat), k)
            kept = flat[idx]
            approx = jnp.zeros_like(flat).at[idx].set(kept).reshape(g32.shape)
            return (kept, idx, g32.shape), g32 - approx
        raise ValueError(cfg.scheme)

    if residual is None:
        residual = jax.tree.map(lambda _: None, grads,
                                is_leaf=lambda x: x is None)
    flat_g, treedef = jax.tree.flatten(grads)
    flat_r = jax.tree.leaves(residual) if jax.tree.leaves(residual) \
        else [None] * len(flat_g)
    pairs = [one(g, r) for g, r in zip(flat_g, flat_r)]
    payload = treedef.unflatten([p for p, _ in pairs])
    new_res = treedef.unflatten([r for _, r in pairs])
    return payload, new_res


def decompress_gradients(payload, cfg: CompressionConfig):
    if cfg.scheme == "none":
        return payload

    def one(p):
        if cfg.scheme == "int8":
            q, scale = p
            return q.astype(jnp.float32) * scale
        kept, idx, shape = p
        flat = jnp.zeros(int(jnp.prod(jnp.array(shape))), jnp.float32)
        return flat.at[idx].set(kept).reshape(shape)

    is_leaf = lambda x: isinstance(x, tuple) and not isinstance(x, dict)
    return jax.tree.map(one, payload, is_leaf=is_leaf)
