"""DPlan CLI — ``python -m repro.plan``.

Builds the static :class:`~repro.core.plan.WorkflowPlan` for workflow
documents and/or built-in workloads: critical path, per-function slack +
prewarm schedule, per-key eviction schedule, transfer-cost matrix, peak
resident bytes per node, and the DF016/DF017 stream-feasibility
diagnostics.

Usage::

    python -m repro.plan examples/workflows/wordcount.yaml
    python -m repro.plan --builtin all --nodes 4
    python -m repro.plan --builtin Srv --format json

Exit status is 1 when any plan fails to build, fails its internal
self-check, or (with ``--strict``) carries warning-severity diagnostics —
so the command gates CI directly.
"""

from __future__ import annotations

import argparse
import functools
import json
import sys
from typing import Callable

from repro.core.dag import Workflow, parse_workflow
from repro.core.plan import WorkflowPlan, build_plan

__all__ = ["main"]


def _load_builtin(name: str) -> Workflow:
    from repro.core.workloads import BENCHMARKS

    return BENCHMARKS[name]()


def _load_file(path: str) -> Workflow:
    with open(path, "r", encoding="utf-8") as fh:
        return parse_workflow(fh.read())


def _print_plan(target: str, plan: WorkflowPlan) -> None:
    cp = plan.critical_path
    n_crit = sum(1 for f in plan.functions.values() if f.critical)
    print(f"{target}: workflow {plan.workflow!r} — "
          f"{len(plan.functions)} fn(s), critical path {cp:.3f}s "
          f"({n_crit} critical)")
    print("  prewarm schedule (boot_at, function, cold_start):")
    for fn, boot_at, cold in plan.prewarm_schedule:
        slack = plan.functions[fn].slack
        print(f"    t={boot_at:8.3f}  {fn:24s} cold={cold:.3f} "
              f"slack={slack:.3f}")
    order = plan.eviction_order()
    print(f"  eviction schedule ({len(order)} key(s), earliest-safe "
          "order):")
    for k in order:
        kp = plan.keys[k]
        print(f"    {k:24s} after {kp.reads} read(s) "
              f"[step {kp.last_step}] {kp.size} B")
    cross = [t for t in plan.transfers if t.local is False]
    print(f"  transfers: {len(plan.transfers)} edge(s), "
          f"{len(cross)} cross-node, {plan.cross_node_bytes:.0f} B cut, "
          f"{plan.predicted_pull_bytes()} B predicted pulls")
    if plan.peak_resident:
        peaks = ", ".join(f"{n}={b}" for n, b in
                          sorted(plan.peak_resident.items()))
        print(f"  peak resident bytes: {peaks}")
    for d in plan.diagnostics:
        print(f"  {d.format()}")


def main(argv: list[str] | None = None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m repro.plan",
        description="DPlan static workflow planner (liveness/eviction, "
        "slack/prewarm, transfer costs)")
    ap.add_argument("paths", nargs="*", help="workflow.yaml files to plan")
    ap.add_argument("--builtin", action="append", default=[],
                    metavar="NAME",
                    help="plan a built-in workload (repeatable; 'all' "
                    "plans every BENCHMARKS entry)")
    ap.add_argument("--nodes", type=int, default=2, metavar="N",
                    help="partition onto N nodes for placement-aware "
                    "analyses (0 = placement-agnostic plan)")
    ap.add_argument("--strict", action="store_true",
                    help="also fail on warning-severity diagnostics "
                    "(DF016)")
    ap.add_argument("--format", choices=("text", "json"), default="text")
    args = ap.parse_args(argv)

    targets: list[tuple[str, Callable[[], Workflow]]] = []
    builtins = args.builtin
    if "all" in builtins:
        from repro.core.workloads import BENCHMARKS

        builtins = sorted(BENCHMARKS)
    for name in builtins:
        targets.append((f"builtin:{name}",
                        functools.partial(_load_builtin, name)))
    for path in args.paths:
        targets.append((path, functools.partial(_load_file, path)))
    if not targets:
        ap.error("nothing to plan: pass paths and/or --builtin")

    nodes = [f"node{i}" for i in range(args.nodes)] if args.nodes else None
    failed = 0
    docs = []
    for target, load in targets:
        try:
            plan = build_plan(load(), nodes=nodes)
        except Exception as exc:        # noqa: BLE001 - reported, gates CI
            failed += 1
            if args.format == "text":
                print(f"{target}: PLAN FAILED — "
                      f"{type(exc).__name__}: {exc}")
            else:
                docs.append({"target": target, "error": str(exc)})
            continue
        problems = plan.self_check()
        if problems:
            failed += 1
        if args.strict and any(d.severity in ("warning", "error")
                               for d in plan.diagnostics):
            failed += 1
        if args.format == "json":
            doc = plan.to_doc()
            doc["target"] = target
            doc["self_check"] = problems
            docs.append(doc)
        else:
            _print_plan(target, plan)
            for p in problems:
                print(f"  SELF-CHECK FAILED: {p}")
    if args.format == "json":
        json.dump(docs, sys.stdout, indent=2)
        print()
    else:
        print(f"# planned {len(targets)} workflow(s), {failed} failed")
    return 1 if failed else 0


if __name__ == "__main__":
    raise SystemExit(main())
