"""Distributed runtime: train/serve step builders + DFlow orchestration."""

from .train_lib import TrainState, build_train_step, make_train_state_specs
from .serve_lib import build_decode_step, build_prefill_step, cache_specs

__all__ = ["TrainState", "build_train_step", "make_train_state_specs",
           "build_decode_step", "build_prefill_step", "cache_specs"]
