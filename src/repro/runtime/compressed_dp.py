"""Compressed data-parallel gradient exchange (beyond-paper, §Perf).

A fp32 ring all-reduce moves ``8·(n-1)/n`` bytes per gradient byte pair
(2 passes × 4 B).  This module expresses the same reduction as an explicit
**int8 reduce-scatter + int8 all-gather** under ``shard_map``:

1. each rank quantizes its local gradient (per-tensor symmetric scale),
2. ``all_to_all`` distributes int8 chunks to their owner ranks
   (reduce-scatter's communication, 1 B/elem on the wire),
3. the owner dequantizes and sums its chunk in fp32, requantizes,
4. ``all_gather`` of int8 chunks (again 1 B/elem),
5. every rank dequantizes the full tensor.

Wire bytes: ``2·(n-1)/n`` per element vs ``8·(n-1)/n`` fp32 — **4×** less
on the DP axis, at int8 rounding error (bounded by the per-round scale;
combine with the error-feedback residual of :mod:`repro.optim.compress`
for accumulation-free training).

This is the DFlow fine-grained exchange idea (§3.3.3) applied to gradient
traffic: the monolithic all-reduce is decomposed into per-chunk
receiver-owned reductions.  Used by ``build_train_step(...,
grad_wire="int8")``; measured on the dry-run as a collective-term drop in
EXPERIMENTS.md §Perf.
"""

from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, PartitionSpec as P

from ..sharding.compat import shard_map
from ..sharding.context import data_axes

__all__ = ["compressed_dp_mean"]


def _quant(x: jax.Array):
    scale = jnp.maximum(jnp.max(jnp.abs(x)), 1e-12) / 127.0
    q = jnp.clip(jnp.round(x / scale), -127, 127).astype(jnp.int8)
    return q, scale


def _dequant(q: jax.Array, scale: jax.Array) -> jax.Array:
    return q.astype(jnp.float32) * scale


def _ring_mean_int8(g: jax.Array, axis: str, n: int) -> jax.Array:
    """int8 reduce-scatter + all-gather mean over one named axis.

    g: local fp32 gradient (identical shape on every rank, different
    values).  Returns the mean over the axis, fp32."""
    flat = g.reshape(-1)
    pad = (-flat.size) % n
    if pad:
        flat = jnp.concatenate([flat, jnp.zeros((pad,), flat.dtype)])
    chunks = flat.reshape(n, -1)                       # (n, chunk)

    q, scale = _quant(chunks)                          # int8 + ()
    # reduce-scatter comm: chunk j of every rank goes to rank j.
    q_rs = jax.lax.all_to_all(q[None], axis, split_axis=1,
                              concat_axis=0)[:, 0]     # (n, chunk) on owner
    scales = jax.lax.all_gather(scale, axis)           # (n,)
    part = jnp.sum(_dequant(q_rs, scales[:, None]), axis=0) / n  # (chunk,)

    q2, scale2 = _quant(part)
    q_full = jax.lax.all_gather(q2, axis)              # (n, chunk) int8
    scales2 = jax.lax.all_gather(scale2, axis)         # (n,)
    full = _dequant(q_full, scales2[:, None]).reshape(-1)
    if pad:
        full = full[:-pad]
    return full.reshape(g.shape)


def compressed_dp_mean(grads, mesh: Mesh):
    """Mean unreduced per-shard gradients over the data axes with int8 wire.

    ``grads`` leaves must be *unreduced* (per-data-shard) fp32 values that
    are replicated across the model axis.  Leaves smaller than 16 KiB skip
    compression (scales/norm vectors — wire savings are noise there).
    """
    d = data_axes(mesh)
    if not d:
        return grads
    axis = d[-1] if len(d) == 1 else d   # tuple handled by lax collectives
    n = 1
    for a in (d if isinstance(axis, tuple) else (axis,)):
        n *= mesh.shape[a]

    def one(g):
        g32 = g.astype(jnp.float32)
        if g.size < 4096:
            return jax.lax.pmean(g32, axis)
        return _ring_mean_int8(g32, axis, n)

    def wrapped(gs):
        return jax.tree.map(one, gs)

    specs = jax.tree.map(lambda g: P(*([None] * g.ndim)), grads)
    return shard_map(
        wrapped, mesh=mesh,
        in_specs=(specs,),
        out_specs=specs,
        check_vma=False,
    )(grads)
