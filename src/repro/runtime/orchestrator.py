"""DFlow-orchestrated training: the paper's engine driving a JAX job.

The training job is expressed as a *workflow DAG* and executed by the real
threaded DFlow engine (:mod:`repro.core.dscheduler`):

* ``batch.i``   — data-pipeline fetch for step *i* (no precursors);
* ``step.i``    — train step: consumes ``state.{i-1}`` + ``batch.i``,
  produces ``state.i`` (+ ``metrics.i``);
* ``ckpt.k``    — checkpoint save consuming ``state.k`` (off the critical
  path: runs whenever its datum is ready, the paper's async-Put pattern).

Under the **dataflow** invocation pattern, ``step.i`` is launched while
``step.{i-1}`` still runs; its container "prewarms" and its ``batch.i``
fetch proceeds concurrently — the exact Figure-6 overlap, realized as
host-side input staging that hides data latency behind device compute.
Under the **controlflow** pattern (ablation), each step's fetch starts only
after the previous step completes, putting data movement on the critical
path.  ``test_orchestrator`` measures the difference with a throttled
Transport.
"""

from __future__ import annotations

import dataclasses
import time
from typing import Any, Callable

from ..core.dag import FunctionSpec, Workflow
from ..core.dscheduler import DFlowEngine, RunReport
from ..core.dstore import Transport

__all__ = ["OrchestratorConfig", "build_training_workflow", "run_training"]


@dataclasses.dataclass(frozen=True)
class OrchestratorConfig:
    n_steps: int = 4
    ckpt_every: int = 0               # 0 = no checkpoints
    pattern: str = "dataflow"         # "dataflow" | "controlflow" ablation
    n_nodes: int = 2
    transport_bandwidth: float | None = None
    straggler_factor: float | None = None


def build_training_workflow(n_steps: int, *, fetch: Callable[[int], Any],
                            train: Callable[[Any, Any], tuple],
                            save: Callable[[int, Any], Any] | None = None,
                            ckpt_every: int = 0,
                            fetch_time: float = 0.05,
                            step_time: float = 0.2) -> Workflow:
    fns = []
    for i in range(n_steps):
        def mk_fetch(i=i):
            def f():
                return {f"batch.{i}": fetch(i)}
            return f

        fns.append(FunctionSpec(
            name=f"fetch.{i}", inputs=(), outputs=(f"batch.{i}",),
            fn=mk_fetch(), exec_time=fetch_time,
            output_sizes={f"batch.{i}": 4 << 20}))

        def mk_step(i=i):
            def f(**kw):
                state = kw[f"state.{i - 1}"] if i else kw["state.init"]
                batch = kw[f"batch.{i}"]
                new_state, metrics = train(state, batch)
                return {f"state.{i}": new_state, f"metrics.{i}": metrics}
            return f

        prev = f"state.{i - 1}" if i else "state.init"
        fns.append(FunctionSpec(
            name=f"step.{i}", inputs=(prev, f"batch.{i}"),
            outputs=(f"state.{i}", f"metrics.{i}"), fn=mk_step(),
            exec_time=step_time,
            output_sizes={f"state.{i}": 16 << 20,
                          f"metrics.{i}": 1 << 10}))

        if save is not None and ckpt_every and (i + 1) % ckpt_every == 0:
            def mk_save(i=i):
                def f(**kw):
                    return {f"ckpt.{i}": save(i, kw[f"state.{i}"])}
                return f
            fns.append(FunctionSpec(
                name=f"ckpt.{i}", inputs=(f"state.{i}",),
                outputs=(f"ckpt.{i}",), fn=mk_save(), exec_time=0.05,
                output_sizes={f"ckpt.{i}": 1 << 10}))

    last = f"state.{n_steps - 1}"
    fns.append(FunctionSpec(
        name="emit", inputs=(last,), outputs=("final_state",),
        fn=lambda **kw: {"final_state": kw[last]}, exec_time=0.0,
        output_sizes={"final_state": 16 << 20}))
    return Workflow("training", fns)


def run_training(cfg: OrchestratorConfig, *, init_state: Any,
                 fetch: Callable[[int], Any],
                 train: Callable[[Any, Any], tuple],
                 save: Callable[[int, Any], Any] | None = None) -> RunReport:
    wf = build_training_workflow(cfg.n_steps, fetch=fetch, train=train,
                                 save=save, ckpt_every=cfg.ckpt_every)
    transport = Transport(bandwidth=cfg.transport_bandwidth)
    engine = DFlowEngine(n_nodes=cfg.n_nodes, pattern=cfg.pattern,
                         transport=transport,
                         straggler_factor=cfg.straggler_factor)
    return engine.run(wf, {"state.init": init_state})
