"""Serve-step builders: prefill + decode with sharded caches.

Cache sharding (the serving analogue of DStore's locality design): batch
over the data axes when divisible, the KV *sequence* axis over the model
axis (each model-rank owns a contiguous KV span — XLA turns the softmax
into the distributed flash-decode split-K pattern: local partial max/sum +
tiny all-reduce of the stats, never an all-gather of the cache).  For
long_500k (batch=1) the sequence axis takes *all* mesh axes.
SSM states shard heads over the model axis (O(1) per sequence — why the
long_500k cells are SSM/hybrid-only, see DESIGN.md).
"""

from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from ..models.config import ModelConfig
from ..sharding.context import data_axes, mesh_context, model_axis
from ..sharding.rules import make_rules, spec_tree

__all__ = ["cache_specs", "build_prefill_step", "build_decode_step",
           "abstract_cache"]


def _lead(axes):
    return tuple(axes) if len(axes) > 1 else (axes[0] if axes else None)


def cache_specs(model, mesh: Mesh, batch: int, max_len: int):
    """PartitionSpec tree matching model.init_cache's structure."""
    cfg: ModelConfig = model.cfg
    d = data_axes(mesh)
    m = model_axis(mesh)
    nd = 1
    for a in d:
        nd *= mesh.shape[a]
    nm = mesh.shape[m] if m else 1

    batch_ok = d and batch % nd == 0
    b_ax = _lead(d) if batch_ok else None
    if batch_ok:
        seq_ax = m if (m and max_len % nm == 0) else None
    else:
        # batch unshardable (long_500k): give the sequence every axis.
        all_ax = tuple(d) + ((m,) if m else ())
        size = nd * nm
        seq_ax = all_ax if (all_ax and max_len % size == 0) else (
            m if (m and max_len % nm == 0) else None)

    def kv_spec(shape_len: int):
        # (L, B, S, Hk, D)
        return P(None, b_ax, seq_ax, None, None)

    def ssm_state_spec():
        # (L, B, H, P, N) — heads over model
        h_ax = m if (m and cfg.ssm_heads % nm == 0) else None
        return P(None, b_ax, h_ax, None, None)

    def conv_spec():
        # (L, B, K-1, C)
        return P(None, b_ax, None, None)

    fam = cfg.family
    from ..models.attention import KVCache
    from ..models.lm import Cache
    from ..models.ssm import SSMCache
    if fam in ("dense", "vlm", "moe"):
        return Cache(kv=KVCache(k=kv_spec(5), v=kv_spec(5), length=P(None)))
    if fam == "ssm":
        return Cache(ssm=SSMCache(state=ssm_state_spec(),
                                  conv_x=conv_spec(), conv_B=conv_spec(),
                                  conv_C=conv_spec()))
    if fam == "hybrid":
        # kv: (nb, B, S, Hk, D); ssm leaves: (nb, nm, B, ...)
        h_ax = m if (m and cfg.ssm_heads % nm == 0) else None
        return Cache(
            kv=KVCache(k=kv_spec(5), v=kv_spec(5), length=P(None)),
            ssm=SSMCache(state=P(None, None, b_ax, h_ax, None, None),
                         conv_x=P(None, None, b_ax, None, None),
                         conv_B=P(None, None, b_ax, None, None),
                         conv_C=P(None, None, b_ax, None, None)))
    if fam == "encdec":
        from ..models.encdec import EncDecCache
        return EncDecCache(
            self_kv=KVCache(k=kv_spec(5), v=kv_spec(5), length=P(None)),
            cross_k=P(None, b_ax, None, None, None),
            cross_v=P(None, b_ax, None, None, None))
    raise ValueError(fam)


def abstract_cache(model, batch: int, max_len: int, *, filled: bool,
                   memory_len: int | None = None):
    """ShapeDtypeStruct cache tree (dry-run: no allocation)."""
    if model.cfg.family == "encdec":
        concrete = jax.eval_shape(
            lambda: model.init_cache(batch, max_len, memory_len or 128))
    else:
        concrete = jax.eval_shape(lambda: model.init_cache(batch, max_len))
    return concrete


def build_prefill_step(model, mesh: Mesh, batch: int, seq: int,
                       max_len: int | None = None, *, zero3: bool = False):
    cfg = model.cfg
    max_len = max_len or seq
    rules = make_rules(mesh, zero3=zero3)
    pspecs = spec_tree(model.param_decls(), mesh, rules)
    cspecs = cache_specs(model, mesh, batch, max_len)
    ns = lambda tree: jax.tree.map(lambda s: NamedSharding(mesh, s), tree,
                                   is_leaf=lambda x: isinstance(x, P))

    if cfg.family == "encdec":
        def prefill(params, frames, tokens, cache):
            with mesh_context(mesh):
                return model.prefill(params, frames, tokens, cache)
        return jax.jit(prefill,
                       in_shardings=(ns(pspecs), None, None, ns(cspecs)),
                       out_shardings=(None, ns(cspecs)),
                       donate_argnums=(3,))

    def prefill(params, tokens, cache):
        with mesh_context(mesh):
            return model.prefill(params, tokens, cache)
    return jax.jit(prefill,
                   in_shardings=(ns(pspecs), None, ns(cspecs)),
                   out_shardings=(None, ns(cspecs)),
                   donate_argnums=(2,))


def build_decode_step(model, mesh: Mesh, batch: int, max_len: int, *,
                      zero3: bool = False):
    cfg = model.cfg
    rules = make_rules(mesh, zero3=zero3)
    pspecs = spec_tree(model.param_decls(), mesh, rules)
    cspecs = cache_specs(model, mesh, batch, max_len)
    ns = lambda tree: jax.tree.map(lambda s: NamedSharding(mesh, s), tree,
                                   is_leaf=lambda x: isinstance(x, P))

    def decode(params, token, cache):
        with mesh_context(mesh):
            return model.decode_step(params, token, cache)
    return jax.jit(decode,
                   in_shardings=(ns(pspecs), None, ns(cspecs)),
                   out_shardings=(None, ns(cspecs)),
                   donate_argnums=(2,))
