"""Train-step builder: microbatched grad accumulation + sharded AdamW.

The step is a single XLA program (pjit); inside it:

* microbatches scan with fp32 gradient accumulation (memory: one
  microbatch of activations live at a time — required by the 1T configs);
* AdamW update with moments sharded like the params (optionally further
  sharded over the data axes — ZeRO-1);
* donation of params + optimizer state (in-place update, no double
  buffering in HBM).

The *dataflow* character (DESIGN.md §3): XLA's latency-hiding scheduler
overlaps the backward's gradient all-reduces with remaining compute exactly
because the program is expressed as one dependency graph, not a sequence of
barriers — the per-datum blocking that DStore's block/wake gives the paper's
workflows, applied at tensor granularity.
"""

from __future__ import annotations

import dataclasses
from functools import partial
from typing import Any, NamedTuple

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from ..models.config import ModelConfig
from ..models.param import abstract_params, init_params
from ..optim.adamw import AdamWConfig, OptState, adamw_init, adamw_update
from ..sharding.context import data_axes, mesh_context
from ..sharding.rules import batch_spec, make_rules, spec_tree

__all__ = ["TrainState", "build_train_step", "make_train_state_specs",
           "batch_sharding"]


class TrainState(NamedTuple):
    params: Any
    opt: OptState


def make_train_state_specs(model, mesh: Mesh, *, zero1: bool = False,
                           zero3: bool = False):
    """PartitionSpec trees for TrainState."""
    decls = model.param_decls()
    dp_only = getattr(model.cfg, "dp_only", False)
    rules = make_rules(mesh, zero3=zero3, dp_only=dp_only)
    pspecs = spec_tree(decls, mesh, rules)
    if zero1:
        opt_rules = make_rules(mesh, zero3=True, dp_only=dp_only)
        mspecs = spec_tree(decls, mesh, opt_rules)  # + data-axis sharding
    else:
        mspecs = pspecs
    opt_specs = OptState(step=P(), m=mspecs, v=mspecs)
    return TrainState(params=pspecs, opt=opt_specs)


def batch_sharding(mesh: Mesh, batch_tree, dp_only: bool = False):
    """Batch leaves: leading dim over the data axes (mrope positions have
    the batch second: (3, B, S)).  With dp_only the model axis joins in."""
    d = data_axes(mesh)
    if dp_only and "model" in mesh.axis_names:
        d = d + ("model",)
    lead = tuple(d) if len(d) > 1 else (d[0] if d else None)

    def spec_for(x):
        ndim = len(x.shape)
        if ndim >= 2 and x.shape[0] == 3 and "int" in str(x.dtype):
            # mrope positions (3, B, S)
            return P(None, lead, *([None] * (ndim - 2)))
        return P(lead, *([None] * (ndim - 1)))
    return jax.tree.map(spec_for, batch_tree)


def build_train_step(model, mesh: Mesh, opt_cfg: AdamWConfig, *,
                     zero1: bool = False, zero3: bool = False,
                     donate: bool = True, batch_tree=None):
    """Returns (train_step_jitted, state_specs).

    train_step(state, batch) -> (state, metrics); batch leaves' leading dim
    is the global batch, divisible by cfg.microbatches.  ``batch_tree`` (a
    ShapeDtypeStruct tree) pins the batch input shardings explicitly.
    """
    cfg: ModelConfig = model.cfg
    specs = make_train_state_specs(model, mesh, zero1=zero1, zero3=zero3)
    mu = max(cfg.microbatches, 1)

    def loss_fn(params, mb):
        return model.loss_fn(params, mb)

    def train_step(state: TrainState, batch):
        with mesh_context(mesh):
            params = state.params
            if mu == 1:
                loss, grads = jax.value_and_grad(loss_fn)(params, batch)
            else:
                mbs = jax.tree.map(
                    lambda x: x.reshape((mu, x.shape[0] // mu)
                                        + x.shape[1:])
                    if x.shape[0] != 3 else
                    x.reshape((x.shape[0], mu, x.shape[1] // mu)
                              + x.shape[2:]).swapaxes(0, 1),
                    batch)
                # The accumulator carry MUST be pinned to the parameter
                # shardings: an unconstrained zeros-init lets SPMD pick a
                # replicated carry, which turns every sharded weight-grad
                # add into a masked all-reduce over the model axis (measured
                # 3.9 TB/step on kimi-k2 — §Perf iteration 4).
                zero_g = jax.tree.map(
                    lambda p, s: jax.lax.with_sharding_constraint(
                        jnp.zeros(p.shape, jnp.float32),
                        NamedSharding(mesh, s)),
                    params, specs.params)

                def acc(carry, mb):
                    l_sum, g_sum = carry
                    l, g = jax.value_and_grad(loss_fn)(params, mb)
                    g_sum = jax.tree.map(
                        lambda a, b, s: jax.lax.with_sharding_constraint(
                            a + b.astype(jnp.float32),
                            NamedSharding(mesh, s)),
                        g_sum, g, specs.params)
                    return (l_sum + l, g_sum), None

                (loss, grads), _ = jax.lax.scan(
                    acc, (jnp.zeros((), jnp.float32), zero_g), mbs)
                loss = loss / mu
                grads = jax.tree.map(lambda g: g / mu, grads)
            new_params, new_opt, metrics = adamw_update(
                params, grads, state.opt, opt_cfg)
            metrics["loss"] = loss
            return TrainState(new_params, new_opt), metrics

    ns = lambda tree: jax.tree.map(lambda s: NamedSharding(mesh, s), tree,
                                   is_leaf=lambda x: isinstance(x, P))
    bshard = ns(batch_sharding(mesh, batch_tree,
                               dp_only=getattr(cfg, "dp_only", False))) \
        if batch_tree is not None else None
    in_shardings = (ns(specs), bshard)
    out_shardings = (ns(specs), None)
    step = jax.jit(train_step,
                   in_shardings=in_shardings,
                   out_shardings=out_shardings,
                   donate_argnums=(0,) if donate else ())
    return step, specs


def init_train_state(model, mesh: Mesh, opt_cfg: AdamWConfig,
                     seed: int = 0, *, zero1: bool = False) -> TrainState:
    """Materialize a sharded TrainState (small/reduced configs only)."""
    decls = model.param_decls()
    specs = make_train_state_specs(model, mesh, zero1=zero1)

    with mesh_context(mesh):
        params = init_params(decls, jax.random.key(seed))
        opt = adamw_init(params, opt_cfg)
        state = TrainState(params, opt)
        shardings = jax.tree.map(lambda s: NamedSharding(mesh, s), specs,
                                 is_leaf=lambda x: isinstance(x, P))
        return jax.device_put(state, shardings)


def abstract_train_state(model, mesh: Mesh,
                         opt_cfg: AdamWConfig) -> TrainState:
    """ShapeDtypeStruct TrainState for dry-run lowering (no allocation)."""
    decls = model.param_decls()
    params = abstract_params(decls)
    sd = opt_cfg.state_dtype
    mom = jax.tree.map(lambda p: jax.ShapeDtypeStruct(p.shape, sd), params)
    opt = OptState(step=jax.ShapeDtypeStruct((), jnp.int32),
                   m=mom, v=jax.tree.map(lambda x: x, mom))
    return TrainState(params=params, opt=opt)
