"""Sharding: logical-axis rules, mesh context, partition specs."""

from .compat import shard_map
from .context import (current_mesh, data_axes, mesh_context, model_axis,
                      set_current_mesh)
from .rules import (logical_to_spec, make_rules, spec_tree)

__all__ = ["shard_map", "current_mesh", "set_current_mesh", "mesh_context",
           "data_axes", "model_axis", "logical_to_spec", "make_rules",
           "spec_tree"]
