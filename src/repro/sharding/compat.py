"""JAX version compatibility shims for sharding primitives.

``shard_map`` graduated from ``jax.experimental.shard_map.shard_map`` to
top-level ``jax.shard_map`` (and its ``check_rep`` kwarg was renamed to
``check_vma``) across JAX releases.  This module resolves whichever spelling
the installed JAX provides and normalises the kwarg, so model/runtime code
can call :func:`shard_map` with the modern signature everywhere.
"""

from __future__ import annotations

from typing import Any, Callable

import jax

__all__ = ["shard_map"]

_NATIVE = getattr(jax, "shard_map", None)

if _NATIVE is None:
    from jax.experimental.shard_map import shard_map as _experimental


def shard_map(f: Callable[..., Any], *, mesh, in_specs, out_specs,
              check_vma: bool = True) -> Callable[..., Any]:
    """``jax.shard_map`` if available, else the experimental fallback
    (which spells ``check_vma`` as ``check_rep``)."""
    if _NATIVE is not None:
        return _NATIVE(f, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
                       check_vma=check_vma)
    return _experimental(f, mesh=mesh, in_specs=in_specs,
                         out_specs=out_specs, check_rep=check_vma)
