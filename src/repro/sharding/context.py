"""Current-mesh context — the "sharding directory" of the system.

DESIGN.md maps DStore's *data directory service* (metadata describing where
bytes live, separated from the bytes) onto this module plus
:mod:`repro.sharding.rules`: a single process-wide source of truth that the
model code (shard_map islands), the launcher, the checkpointer and the
dry-run all consult to learn where every tensor lives.
"""

from __future__ import annotations

import contextlib
import threading

import jax
from jax.sharding import Mesh

__all__ = ["set_current_mesh", "current_mesh", "mesh_context", "data_axes",
           "model_axis", "axis_size"]

_state = threading.local()


def set_current_mesh(mesh: Mesh | None) -> None:
    _state.mesh = mesh


def current_mesh() -> Mesh:
    mesh = getattr(_state, "mesh", None)
    if mesh is None:
        # Default: a 1x1 mesh over the first device so single-device smoke
        # tests execute the exact distributed code path.
        dev = jax.devices()[0]
        import numpy as np

        mesh = Mesh(np.array([dev]).reshape(1, 1), ("data", "model"))
        _state.mesh = mesh
    return mesh


@contextlib.contextmanager
def mesh_context(mesh: Mesh):
    prev = getattr(_state, "mesh", None)
    set_current_mesh(mesh)
    try:
        yield mesh
    finally:
        set_current_mesh(prev)


def data_axes(mesh: Mesh | None = None) -> tuple[str, ...]:
    """Mesh axes that shard the batch: ('pod', 'data') when present."""
    mesh = mesh or current_mesh()
    return tuple(a for a in ("pod", "data") if a in mesh.axis_names)


def model_axis(mesh: Mesh | None = None) -> str | None:
    mesh = mesh or current_mesh()
    return "model" if "model" in mesh.axis_names else None


def axis_size(name: str, mesh: Mesh | None = None) -> int:
    mesh = mesh or current_mesh()
    if name not in mesh.axis_names:
        return 1
    return mesh.shape[name]
