"""Logical-axis → mesh-axis rules (the sharding "directory").

Parameters and activations carry *logical* axis names (see
:mod:`repro.models.param`).  This module maps them onto the production mesh:

====================  =======================================
logical axis          mesh axes
====================  =======================================
batch                 ("pod", "data")  — whichever exist
heads / kv_heads      "model"   (tensor parallel attention)
mlp / expert_mlp      "model"   (tensor parallel FFN)
experts               "model"   (expert parallel)
vocab                 "model"   (sharded embedding + logits)
ssm_heads             "model"   (Mamba head parallel)
embed / seq / others  replicated (unless zero3/seq-parallel)
====================  =======================================

Every mapping is **divisibility-checked against the concrete dim**: a 40-head
config on a 16-way model axis falls back to replicated heads (the attention
einsums then shard on the contracting ``embed`` side instead), and a vocab of
50280 stays unsharded.  This is what lets one rule set drive all 10
architectures through the same dry-run.

``zero3=True`` additionally shards each parameter's largest remaining axis
over the data axes (FSDP-style) — a §Perf hillclimb lever.
"""

from __future__ import annotations

from typing import Mapping

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from ..models.param import ArrayDecl
from .context import data_axes, model_axis

__all__ = ["make_rules", "logical_to_spec", "spec_tree", "sharding_tree",
           "batch_spec"]

_MODEL_AXES = ("heads", "kv_heads", "mlp", "expert_mlp", "experts", "vocab",
               "ssm_heads", "ssm_inner")


def make_rules(mesh: Mesh, *, zero3: bool = False,
               seq_parallel: bool = False, dp_only: bool = False) -> dict:
    d = data_axes(mesh)
    m = model_axis(mesh)
    if dp_only:
        # Small-arch remap: the model axis becomes extra data parallelism;
        # parameters are fully replicated (§Perf lever).
        rules: dict[str, tuple[str, ...] | None] = {a: None
                                                    for a in _MODEL_AXES}
        rules["heads"] = None
        rules["batch"] = (d + ((m,) if m else ())) or None
        rules["seq"] = None
        rules["_zero3"] = (d + ((m,) if m else ())) if zero3 else None
        return rules
    rules = {a: (m,) if m else None for a in _MODEL_AXES}
    rules["batch"] = d or None
    rules["seq"] = (m,) if (seq_parallel and m) else None
    rules["_zero3"] = d if zero3 else None
    return rules


def _fits(dim: int, axes: tuple[str, ...] | None, mesh: Mesh) -> bool:
    if not axes:
        return False
    size = 1
    for a in axes:
        size *= mesh.shape[a]
    return dim % size == 0 and dim >= size


def logical_to_spec(axes: tuple[str | None, ...], shape: tuple[int, ...],
                    rules: Mapping, mesh: Mesh) -> P:
    """One array's logical axes + shape → PartitionSpec."""
    parts: list = []
    used: set[str] = set()
    for dim, name in zip(shape, axes):
        target = rules.get(name) if name else None
        if target and not any(t in used for t in target) \
                and _fits(dim, tuple(target), mesh):
            parts.append(tuple(target) if len(target) > 1 else target[0])
            used.update(target)
        else:
            parts.append(None)
    # Fallback for arrays with a *q-heads* axis only (wq/wo): if the model
    # axis could not be used, shard head_dim instead.  Never applied to
    # K/V projections — those stay model-replicated (GQA KV is small), so
    # the expand-to-H broadcast remains local.
    m = rules.get("heads")
    if m and "heads" in axes \
            and not any((set(m) & ({p} if isinstance(p, str)
                                   else set(p or ()))) for p in parts):
        for i, name in enumerate(axes):
            if name == "head_dim" and parts[i] is None \
                    and _fits(shape[i], tuple(m), mesh):
                parts[i] = tuple(m) if len(m) > 1 else m[0]
                break
    # ZeRO-3: shard the largest still-replicated axis over the data axes.
    zaxes = rules.get("_zero3")
    if zaxes and not any(set(zaxes) & ({p} if isinstance(p, str)
                                       else set(p or ())) for p in parts):
        order = sorted(range(len(shape)), key=lambda i: -shape[i])
        for i in order:
            if parts[i] is None and _fits(shape[i], tuple(zaxes), mesh) \
                    and axes[i] != "layers":
                parts[i] = tuple(zaxes) if len(zaxes) > 1 else zaxes[0]
                break
    return P(*parts)


def spec_tree(decls, mesh: Mesh, rules: Mapping):
    """Pytree of PartitionSpec matching a pytree of ArrayDecl."""
    return jax.tree.map(
        lambda d: logical_to_spec(d.axes, d.shape, rules, mesh),
        decls, is_leaf=lambda x: isinstance(x, ArrayDecl))


def sharding_tree(decls, mesh: Mesh, rules: Mapping):
    return jax.tree.map(lambda s: NamedSharding(mesh, s),
                        spec_tree(decls, mesh, rules),
                        is_leaf=lambda x: isinstance(x, P))


def batch_spec(mesh: Mesh, ndim: int = 2) -> P:
    """Inputs (batch, seq, ...): batch over the data axes."""
    d = data_axes(mesh)
    lead = tuple(d) if len(d) > 1 else (d[0] if d else None)
    return P(lead, *([None] * (ndim - 1)))
