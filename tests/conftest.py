"""Shared test fixtures/shims.

``hypothesis`` is an optional dev dependency (requirements-dev.txt): when it
is missing, property-based tests skip while the rest of their modules run.
Test modules import the shim via ``from conftest import given, settings, st``.

DCheck trace validation (opt-in): ``DFLOW_TRACE_CHECK=1`` attaches a
:class:`repro.core.check.TraceRecorder` to every DStore a test constructs
and replays the trace through :class:`TraceChecker` at teardown — any
happens-before / immutability / eviction / chunk-sequence violation fails
the test.  ``DFLOW_TRACE_STRESS=<seed>`` additionally injects seeded
random sleeps at every instrumentation point so thread interleavings are
actually explored.  Tests that *deliberately* violate invariants opt out
with ``@pytest.mark.notracecheck``.
"""

import os

import pytest


def pytest_configure(config):
    config.addinivalue_line(
        "markers",
        "slow: long-running sweeps (the 200-seed differential run); "
        'CI quick tier runs -m "not slow"')
    config.addinivalue_line(
        "markers",
        "notracecheck: skip DFLOW_TRACE_CHECK validation (test seeds "
        "deliberate invariant violations)")


if os.environ.get("DFLOW_TRACE_CHECK") == "1":
    @pytest.fixture(autouse=True)
    def _dflow_trace_check(request, monkeypatch):
        if request.node.get_closest_marker("notracecheck"):
            yield
            return
        from repro.core.check import TraceChecker, TraceRecorder
        from repro.core.dstore import DStore

        stress_env = os.environ.get("DFLOW_TRACE_STRESS")
        stress = int(stress_env) if stress_env else None
        recorders: list[TraceRecorder] = []
        orig_init = DStore.__init__

        def init(self, *args, **kwargs):
            orig_init(self, *args, **kwargs)
            rec = TraceRecorder(stress=stress)
            recorders.append(rec)
            self.attach_tracer(rec)

        monkeypatch.setattr(DStore, "__init__", init)
        yield
        checker = TraceChecker()
        for rec in recorders:
            checker.check_or_raise(rec.events())


try:
    from hypothesis import given, settings
    from hypothesis import strategies as st
except ModuleNotFoundError:
    def settings(**kw):
        return lambda fn: fn

    def given(*a, **kw):
        def deco(fn):
            @pytest.mark.skip(reason="hypothesis not installed")
            def wrapper():
                pass                  # pragma: no cover
            wrapper.__name__ = fn.__name__
            return wrapper
        return deco

    class _StStub:
        def __getattr__(self, name):
            return lambda *a, **kw: None

    st = _StStub()
