"""Shared test fixtures/shims.

``hypothesis`` is an optional dev dependency (requirements-dev.txt): when it
is missing, property-based tests skip while the rest of their modules run.
Test modules import the shim via ``from conftest import given, settings, st``.
"""

import pytest


def pytest_configure(config):
    config.addinivalue_line(
        "markers",
        "slow: long-running sweeps (the 200-seed differential run); "
        'CI quick tier runs -m "not slow"')


try:
    from hypothesis import given, settings
    from hypothesis import strategies as st
except ModuleNotFoundError:
    def settings(**kw):
        return lambda fn: fn

    def given(*a, **kw):
        def deco(fn):
            @pytest.mark.skip(reason="hypothesis not installed")
            def wrapper():
                pass                  # pragma: no cover
            wrapper.__name__ = fn.__name__
            return wrapper
        return deco

    class _StStub:
        def __getattr__(self, name):
            return lambda *a, **kw: None

    st = _StStub()
