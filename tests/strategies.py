"""Random valid Workflow DAGs for property-based / differential testing.

Two entry points over one generator:

* :func:`random_workflow` — fully deterministic: an LCG seeded by an int
  draws the DAG shape (fan-in/out, diamonds, multi-output functions,
  stream edges, external inputs).  Usable without hypothesis, so the
  200-seed differential sweep runs in every environment.
* :func:`workflows` — a hypothesis strategy wrapping the same generator
  (draws the seed + size bounds), so shrinking works when hypothesis *is*
  installed.

Every function gets a real callable producing a deterministic digest of
its (sorted) inputs, so a sequential topological oracle
(:func:`oracle_run`) predicts the exact output bytes of any engine
execution — the conformance contract for the threaded DFlowEngine in both
invocation patterns.
"""

from __future__ import annotations

import hashlib

from repro.core.dag import FunctionSpec, Workflow

try:
    from hypothesis import strategies as st
    HAVE_HYPOTHESIS = True
except ModuleNotFoundError:          # deterministic path still works
    st = None
    HAVE_HYPOTHESIS = False


class _Rng:
    """LCG (same family as workloads._Det) — no global RNG, ever."""

    def __init__(self, seed: int):
        self.s = (seed * 2654435761 + 0x9E3779B9) & 0xFFFFFFFF

    def next(self) -> float:
        self.s = (1103515245 * self.s + 12345) & 0x7FFFFFFF
        return self.s / 0x7FFFFFFF

    def randint(self, lo: int, hi: int) -> int:
        return lo + int(self.next() * (hi - lo + 1)) % (hi - lo + 1)

    def chance(self, p: float) -> bool:
        return self.next() < p

    def sample(self, items: list, k: int) -> list:
        pool = list(items)
        out = []
        for _ in range(min(k, len(pool))):
            out.append(pool.pop(self.randint(0, len(pool) - 1)))
        return out


def _normalize(kw: dict) -> dict:
    """Drain StreamReaders ONCE up front — a reader is an iterator, so
    per-output re-reads would observe an already-drained stream."""
    out = {}
    for k, v in kw.items():
        if hasattr(v, "read_all"):            # StreamReader (engine path)
            v = v.read_all()
        elif not isinstance(v, (bytes, bytearray)):
            v = repr(v).encode()
        out[k] = bytes(v)
    return out


def _value_bytes(tag: str, kw: dict) -> bytes:
    """Deterministic digest of a function's (normalized) inputs — the
    oracle contract."""
    h = hashlib.sha256(tag.encode())
    for k in sorted(kw):
        h.update(k.encode())
        h.update(kw[k])
    d = h.digest()
    return (d * 40)[:1280]                    # ~1.3 KB payloads


def _make_fn(outputs: tuple[str, ...], stream_outputs: tuple[str, ...],
             as_generator: bool, calls: dict[str, int] | None, name: str):
    def fn(**kw):
        if calls is not None:
            calls[name] = calls.get(name, 0) + 1
        kw = _normalize(kw)
        out = {}
        for o in outputs:
            v = _value_bytes(o, kw)
            if o in stream_outputs and as_generator:
                out[o] = (v[i:i + 256] for i in range(0, len(v), 256))
            else:
                out[o] = v
        return out
    return fn


def random_workflow(seed: int, *, max_functions: int = 8,
                    stream_prob: float = 0.15,
                    calls: dict[str, int] | None = None) -> Workflow:
    """Deterministic random DAG: linear chains, diamonds, fan-in/out and
    multi-consumer outputs all arise from the edge draw.  ``calls``, when
    given, is filled with per-function execution counts (exactly-once
    assertions)."""
    rng = _Rng(seed)
    n = rng.randint(2, max_functions)
    produced: list[str] = []                 # keys available to later fns
    specs: list[FunctionSpec] = []
    for i in range(n):
        # Draw 0-3 inputs from earlier outputs; fns that drew none take
        # the external "x" (keys never produced are external by contract),
        # so every function has a data edge — generated DAGs lint clean
        # (no error/warning diagnostics; see lint_clean below).
        k = rng.randint(0, min(3, len(produced)))
        inputs = tuple(sorted(rng.sample(produced, k)))
        if not inputs:
            inputs = ("x",)
        n_out = 2 if rng.chance(0.25) else 1
        outputs = tuple(f"o{i}" if j == 0 else f"o{i}.{j}"
                        for j in range(n_out))
        stream = rng.chance(stream_prob)
        stream_inputs = tuple(k for k in inputs if k != "x"
                              and rng.chance(0.5)) if stream else ()
        stream_outputs = outputs if stream and rng.chance(0.5) else ()
        specs.append(FunctionSpec(
            name=f"f{i}", inputs=inputs, outputs=outputs,
            fn=_make_fn(outputs, stream_outputs,
                        as_generator=rng.chance(0.5), calls=calls,
                        name=f"f{i}"),
            exec_time=0.001, cold_start=0.001,
            stream_inputs=stream_inputs, stream_outputs=stream_outputs,
            chunk_size=256,
            output_sizes={o: 1280 for o in outputs}))
        produced.extend(outputs)
    return Workflow(f"fuzz{seed}", specs)


def lint_clean(wf: Workflow) -> list:
    """Generator contract: a random workflow may carry *info*-level
    diagnostics (unconsumed by-products and stream fallbacks arise from
    random shapes and are by-design byte-exact) but never a warning or
    error.  Returns the offending diagnostics (empty = clean)."""
    from repro.core.lint import lint_workflow

    return [d for d in lint_workflow(wf, require_fns=True)
            if d.severity in ("warning", "error")]


def oracle_run(wf: Workflow, inputs: dict) -> dict:
    """Sequential topological-order execution — the ground truth every
    engine schedule must match.  Returns the sink outputs exactly as
    RunReport.outputs collects them (produced-but-unconsumed keys plus
    exit functions' outputs)."""
    data = dict(inputs)
    for fname in wf.topo_order:
        f = wf.functions[fname]
        result = f.fn(**{k: data[k] for k in f.inputs})
        for o in f.outputs:
            v = result[o]
            if not isinstance(v, (bytes, bytearray)):
                v = b"".join(v)              # drain generator outputs
            data[o] = bytes(v)
    consumed = {k for f in wf.functions.values() for k in f.inputs}
    out = {}
    for f in wf.functions.values():
        for k in f.outputs:
            if k not in consumed or f.name in wf.exit_points:
                out[k] = data[k]
    return out


def external_inputs(wf: Workflow) -> dict:
    return {k: b"ext:" + k.encode() for k in wf.external_inputs}


def sharded_run(seed: int, n_nodes: int, *, pattern: str = "dataflow",
                stress: int | None = None):
    """One engine run of ``random_workflow(seed)`` over a DShard
    :class:`~repro.core.router.ShardedDStore` with its own trace recorder
    (attached explicitly so the routing invariant is exercised even when
    the conftest DFLOW_TRACE_CHECK fixture is off).  Returns
    ``(outputs, store, events)`` — the caller asserts byte-equality
    against the oracle/baseline and runs the TraceChecker."""
    from repro.core.check import TraceRecorder
    from repro.core.dscheduler import DFlowEngine
    from repro.core.router import ShardedDStore

    wf = random_workflow(seed)
    eng = DFlowEngine(n_nodes=n_nodes, pattern=pattern, get_timeout=30.0,
                      sharded=True)
    store = ShardedDStore(eng.nodes, eng.transport)
    rec = TraceRecorder(stress=stress)
    store.attach_tracer(rec)
    rep = eng.start(wf, external_inputs(wf), store=store).wait()
    outputs = {k: bytes(v) for k, v in rep.outputs.items()}
    return outputs, store, rec.events()


if HAVE_HYPOTHESIS:
    @st.composite
    def workflows(draw, max_functions: int = 8):
        seed = draw(st.integers(min_value=0, max_value=2**32 - 1))
        return random_workflow(seed, max_functions=max_functions)
else:                                        # pragma: no cover - shim env
    def workflows(max_functions: int = 8):
        return None
