"""Analysis-layer tests: HLO parsing, analytic FLOPs, roofline records."""

import jax
import jax.numpy as jnp
import pytest

from repro.analysis.flops import model_flops, param_counts
from repro.analysis.hlo import (collective_summary, count_scan_trips,
                                hbm_bytes, matmul_flops, parse_collectives)
from repro.analysis.roofline import analyze_record
from repro.configs import get_config
from repro.launch.input_specs import Cell, is_skipped, live_cells


# -------------------------------------------------------------- HLO parsing
SYNTH_HLO = """
HloModule test

%body.1 (p: (s32[], f32[64,128])) -> (s32[], f32[64,128]) {
  %p = (s32[], f32[64,128]) parameter(0)
  %i = s32[] get-tuple-element(%p), index=0
  %x = f32[64,128]{1,0} get-tuple-element(%p), index=1
  %w = f32[128,128]{1,0} constant(0)
  %dot.5 = f32[64,128]{1,0} dot(%x, %w), lhs_contracting_dims={1}, rhs_contracting_dims={0}
  %ar = f32[64,128]{1,0} all-reduce(%dot.5), channel_id=1, replica_groups=[16,16]<=[256], to_apply=%add.1
  %one = s32[] constant(1)
  %next = s32[] add(%i, %one)
  ROOT %t = (s32[], f32[64,128]) tuple(%next, %ar)
}

%cond.1 (p: (s32[], f32[64,128])) -> pred[] {
  %p = (s32[], f32[64,128]) parameter(0)
  %i = s32[] get-tuple-element(%p), index=0
  %n = s32[] constant(10)
  ROOT %lt = pred[] compare(%i, %n), direction=LT
}

ENTRY %main (a: f32[64,128]) -> f32[64,128] {
  %a = f32[64,128]{1,0} parameter(0)
  %zero = s32[] constant(0)
  %init = (s32[], f32[64,128]) tuple(%zero, %a)
  %loop = (s32[], f32[64,128]) while(%init), condition=%cond.1, body=%body.1
  %ag = f32[64,2048]{1,0} all-gather(%a), channel_id=2, replica_groups={{0,1,2,3}}, dimensions={1}
  ROOT %out = f32[64,128]{1,0} get-tuple-element(%loop), index=1
}
"""


def test_scan_trip_detection():
    trips = count_scan_trips(SYNTH_HLO)
    assert trips == {"body.1": 10}


def test_matmul_flops_loop_scaled():
    # dot: 2*64*128*128 flops, executed 10 times in the while body.
    assert matmul_flops(SYNTH_HLO) == pytest.approx(2 * 64 * 128 * 128 * 10)


def test_collective_wire_bytes():
    ops = parse_collectives(SYNTH_HLO)
    kinds = {o["kind"] for o in ops}
    assert kinds == {"all-reduce", "all-gather"}
    ar = next(o for o in ops if o["kind"] == "all-reduce")
    # ring all-reduce in a 16-group, x10 loop trips
    expect = 2 * (64 * 128 * 4) * 15 / 16 * 10
    assert ar["wire_bytes"] == pytest.approx(expect)
    ag = next(o for o in ops if o["kind"] == "all-gather")
    assert ag["group"] == 4
    assert ag["wire_bytes"] == pytest.approx(64 * 2048 * 4 * 3 / 4)


def test_hbm_bytes_counts_loop_body():
    b = hbm_bytes(SYNTH_HLO)
    assert b > 2 * 64 * 128 * 4 * 10     # at least the dot results x10


def test_collective_summary_totals():
    s = collective_summary(SYNTH_HLO)
    assert s["n_ops"] == 2
    assert s["total_bytes"] > 0


# -------------------------------------------------------------- FLOPs model
def test_param_counts_match_declared_params():
    """Analytic totals track the actual ArrayDecl sizes within ~2%."""
    from repro.analysis.flops import param_counts
    from repro.models import build_model
    from repro.models.param import param_count
    for arch in ("tinyllama-1.1b", "qwen3-moe-235b-a22b", "mamba2-370m",
                 "jamba-1.5-large-398b", "seamless-m4t-large-v2"):
        cfg = get_config(arch)
        declared = param_count(build_model(cfg).param_decls())
        analytic = param_counts(cfg)["total"]
        assert abs(declared - analytic) / declared < 0.05, arch


def test_known_scale_sanity():
    assert 14e9 < param_counts(get_config("starcoder2-15b"))["total"] < 17e9
    assert 0.9e9 < param_counts(get_config("tinyllama-1.1b"))["total"] < 1.3e9
    kimi = param_counts(get_config("kimi-k2-1t-a32b"))
    assert kimi["total"] > 0.8e12           # ~1T total
    assert kimi["active"] < 0.05 * kimi["total"]   # sparse activation


def test_model_flops_train_vs_prefill():
    cfg = get_config("tinyllama-1.1b")
    tr = model_flops(cfg, Cell("tinyllama-1.1b", "train_4k"))
    pf = model_flops(cfg, Cell("tinyllama-1.1b", "prefill_32k"))
    assert tr["matmul_6nd"] == pytest.approx(3 * 2 *
                                             tr["params_active"] *
                                             tr["tokens"], rel=1e-6)
    assert pf["matmul_6nd"] == pytest.approx(2 * pf["params_active"] *
                                             pf["tokens"], rel=1e-6)


# -------------------------------------------------------------- cells
def test_live_cells_and_skips():
    cells = live_cells()
    assert len(cells) == 32                      # 10*3 + 2 long_500k
    assert is_skipped("starcoder2-15b", "long_500k")
    assert not is_skipped("mamba2-370m", "long_500k")
    assert not is_skipped("jamba-1.5-large-398b", "long_500k")


def test_analyze_record_terms():
    rec = {
        "arch": "x", "shape": "train_4k", "mesh": "single",
        "kind": "train", "n_chips": 256,
        "dot_flops_per_device": 197e12,          # exactly 1s compute
        "hbm_bytes_per_device": 819e9 / 2,       # 0.5s memory
        "hlo_flops": 1.0, "hlo_bytes": 1.0,
        "collectives": {"total_bytes": 50e9 * 2},  # 2s collective
        "model_flops": {"model_flops": 197e12 * 256 * 0.5},
        "memory_analysis": {},
    }
    out = analyze_record(rec)
    assert out["compute_s"] == pytest.approx(1.0)
    assert out["memory_s"] == pytest.approx(0.5)
    assert out["collective_s"] == pytest.approx(2.0)
    assert out["dominant"] == "collective"
    assert out["roofline_fraction"] == pytest.approx(0.25)
