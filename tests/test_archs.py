"""Per-architecture smoke tests (assignment requirement): reduced config of
the same family, one forward/train step on CPU, shape + finiteness asserts,
and serving-path consistency."""

import dataclasses

import jax
import jax.numpy as jnp
import pytest

from repro.configs import get_config, list_archs
from repro.models import build_model, init_params, param_count

B, S = 2, 32


def _batch(cfg, key=2, seq=S):
    ks = jax.random.split(jax.random.key(key), 4)
    if cfg.family == "encdec":
        return {"frames": jax.random.normal(ks[0], (B, 16, cfg.d_model)),
                "tokens": jax.random.randint(ks[1], (B, seq + 1), 0,
                                             cfg.vocab)}
    batch = {"tokens": jax.random.randint(ks[1], (B, seq + 1), 0, cfg.vocab)}
    if cfg.family == "vlm":
        batch["vision_embeds"] = jax.random.normal(ks[2],
                                                   (B, 8, cfg.d_model))
        batch["mrope_positions"] = jnp.broadcast_to(jnp.arange(seq),
                                                    (3, B, seq))
    return batch


def test_all_archs_registered():
    assert len(list_archs()) == 10


@pytest.mark.parametrize("arch", list_archs())
def test_arch_full_config_matches_table(arch):
    """Exact table numbers (the full configs are only lowered, never run)."""
    cfg = get_config(arch)
    table = {
        "starcoder2-15b": (40, 6144, 48, 4, 24576, 49152),
        "qwen3-14b": (40, 5120, 40, 8, 17408, 151936),
        "tinyllama-1.1b": (22, 2048, 32, 4, 5632, 32000),
        "nemotron-4-15b": (32, 6144, 48, 8, 24576, 256000),
        "kimi-k2-1t-a32b": (61, 7168, 64, 8, 2048, 163840),
        "qwen3-moe-235b-a22b": (94, 4096, 64, 4, 1536, 151936),
        "qwen2-vl-72b": (80, 8192, 64, 8, 29568, 152064),
        "mamba2-370m": (48, 1024, 0, 0, 0, 50280),
        "jamba-1.5-large-398b": (72, 8192, 64, 8, 24576, 65536),
        "seamless-m4t-large-v2": (24, 1024, 16, 16, 8192, 256206),
    }
    L, d, h, kv, ff, v = table[arch]
    assert (cfg.n_layers, cfg.d_model, cfg.n_heads, cfg.n_kv_heads,
            cfg.d_ff, cfg.vocab) == (L, d, h, kv, ff, v)
    if arch == "kimi-k2-1t-a32b":
        assert (cfg.n_experts, cfg.top_k) == (384, 8)
    if arch == "qwen3-moe-235b-a22b":
        assert (cfg.n_experts, cfg.top_k) == (128, 8)
    if arch == "jamba-1.5-large-398b":
        assert (cfg.n_experts, cfg.top_k) == (16, 2)
        assert cfg.hybrid_period == 8          # 1 attn : 7 mamba
    if arch == "mamba2-370m":
        assert cfg.ssm_state == 128


@pytest.mark.parametrize("arch", list_archs())
def test_arch_smoke_train_step(arch):
    """Reduced config: one loss+grad step, finite, right shapes."""
    cfg = get_config(arch, reduced=True)
    model = build_model(cfg)
    params = init_params(model.param_decls(), jax.random.key(0))
    assert param_count(model.param_decls()) > 0
    batch = _batch(cfg)
    loss, grads = jax.jit(jax.value_and_grad(model.loss_fn))(params, batch)
    assert jnp.isfinite(loss), arch
    assert 0.0 < float(loss) < 20.0
    for leaf in jax.tree.leaves(grads):
        assert bool(jnp.all(jnp.isfinite(leaf))), arch


@pytest.mark.parametrize("arch", ["tinyllama-1.1b", "qwen3-moe-235b-a22b",
                                  "mamba2-370m", "jamba-1.5-large-398b",
                                  "seamless-m4t-large-v2", "qwen2-vl-72b"])
def test_arch_serving_consistency(arch):
    """prefill(S) + decode(1) ≍ full forward(S+1) — with a no-drop MoE
    capacity so capacity-based routing cannot couple token sets."""
    cfg = get_config(arch, reduced=True)
    if cfg.n_experts:
        cfg = dataclasses.replace(cfg, capacity_factor=8.0)
    model = build_model(cfg)
    params = init_params(model.param_decls(), jax.random.key(0))
    Sx = 16
    batch = _batch(cfg, seq=Sx)
    toks = batch["tokens"]
    if cfg.family == "encdec":
        cache = model.init_cache(B, max_len=cfg.max_cache_len, memory_len=16)
        pre, cache = jax.jit(model.prefill)(params, batch["frames"],
                                            toks[:, :Sx], cache)
        dec, _ = jax.jit(model.decode_step)(params, toks[:, Sx:Sx + 1], cache)
        full, _ = model.forward(params, batch["frames"], toks)
    else:
        kw = {}
        cache = model.init_cache(B, max_len=cfg.max_cache_len)
        pre, cache = jax.jit(model.prefill)(params, toks[:, :Sx], cache)
        dec, _ = jax.jit(model.decode_step)(params, toks[:, Sx:Sx + 1], cache)
        full, _ = model.forward(params, toks)
    ref = full[:, Sx].astype(jnp.float32)
    got = dec[:, 0].astype(jnp.float32)
    rel = float(jnp.abs(got - ref).max() / (jnp.abs(ref).max() + 1e-9))
    assert rel < 0.06, (arch, rel)
    assert bool((got.argmax(-1) == ref.argmax(-1)).all()), arch


def test_reduced_configs_stay_in_family():
    for arch in list_archs():
        full = get_config(arch)
        red = get_config(arch, reduced=True)
        assert red.family == full.family
        assert red.n_layers <= 8
        assert red.d_model <= 128
