"""DCheck dynamic half: trace recording + invariant checking.

Two layers of evidence per invariant class:

* **hand-built traces** pin the checker's judgment precisely (a trace
  that violates exactly one invariant yields exactly that violation);
* **live seeded violations** break the real DStore in a way its public
  API forbids (bypassing Put, evicting under an in-flight remote pull,
  lying to the stream directory) and assert the recorded trace convicts.

Plus the negative contract: real engine/serve runs — including under the
schedule-perturbing stress mode — produce clean traces.

The module is marked ``notracecheck``: it seeds violations on purpose, so
the conftest's global DFLOW_TRACE_CHECK teardown must not re-judge them.
"""

import threading
import time

import pytest

from repro.core.check import (TraceChecker, TraceEvent, TraceRecorder,
                              content_digest)
from repro.core.dstore import DStore, Transport

pytestmark = pytest.mark.notracecheck


def ev(clock, kind, key="", node="", **kw):
    return TraceEvent(clock, kind, key, node, **kw)


def violations(events, invariant=None):
    out = TraceChecker().check(events)
    if invariant is not None:
        out = [v for v in out if v.invariant == invariant]
    return out


D1 = content_digest(b"one")
D2 = content_digest(b"two")


# ----------------------------------------------------------------------
# content_digest
# ----------------------------------------------------------------------

def test_digest_stable_across_representations():
    assert content_digest(b"abc") == content_digest(bytearray(b"abc"))
    assert content_digest(b"abc") == content_digest(memoryview(b"abc"))
    assert content_digest(b"abc") != content_digest(b"abd")
    assert content_digest({"v": 7}) == content_digest({"v": 7})
    assert content_digest([1, "a"]) == content_digest([1, "a"])


def test_digest_opaque_is_none():
    class Opaque:
        pass

    assert content_digest(Opaque()) is None
    # A list containing an opaque element is opaque as a whole.
    assert content_digest([1, Opaque()]) is None


def test_digest_arrays():
    np = pytest.importorskip("numpy")
    a = np.arange(6, dtype=np.int32)
    assert content_digest(a) == content_digest(a.copy())
    assert content_digest(a) != content_digest(a.reshape(2, 3))
    assert content_digest(a) != content_digest(a.astype(np.int64))


# ----------------------------------------------------------------------
# Hand-built traces: one violation class each.
# ----------------------------------------------------------------------

def test_clean_trace_passes():
    trace = [
        ev(1, "put", "k", "n0", digest=D1),
        ev(2, "get_block", "k", "n1"),
        ev(3, "replica", "k", "n1", digest=D1),
        ev(4, "get_return", "k", "n1", digest=D1),
        ev(5, "evict", "k"),
    ]
    assert violations(trace) == []


def test_ordering_get_before_any_publish():
    trace = [
        ev(1, "get_block", "k", "n1"),
        ev(2, "get_return", "k", "n1", digest=D1),
        ev(3, "put", "k", "n0", digest=D1),
    ]
    (v,) = violations(trace)
    assert v.invariant == "ordering"


def test_ordering_stale_read_wrong_bytes():
    trace = [
        ev(1, "put", "k", "n0", digest=D1),
        ev(2, "get_block", "k", "n1"),
        ev(3, "get_return", "k", "n1", digest=D2),
    ]
    (v,) = violations(trace)
    assert v.invariant == "ordering" and "stale" in v.message


def test_immutability_divergent_writes():
    trace = [
        ev(1, "put", "k", "n0", digest=D1),
        ev(2, "put", "k", "n1", digest=D2),
    ]
    (v,) = violations(trace)
    assert v.invariant == "immutability"


def test_immutability_identical_cowrite_clean():
    trace = [
        ev(1, "put", "k", "n0", digest=D1),
        ev(2, "put", "k", "n1", digest=D1),
        ev(3, "put", "k", "n2", digest=None),   # opaque: no judgment
    ]
    assert violations(trace) == []


def test_eviction_with_inflight_reader():
    trace = [
        ev(1, "put", "k", "n0", digest=D1),
        ev(2, "get_block", "k", "n1"),
        ev(3, "evict", "k"),
        ev(4, "get_return", "k", "n1", digest=D1),
    ]
    vs = violations(trace, "eviction")
    assert len(vs) == 1 and "in flight" in vs[0].message


def test_eviction_after_reader_finished_clean():
    trace = [
        ev(1, "put", "k", "n0", digest=D1),
        ev(2, "get_block", "k", "n1"),
        ev(3, "get_return", "k", "n1", digest=D1),
        ev(4, "evict", "k"),
    ]
    assert violations(trace) == []


def test_chunk_sequence_missing_chunk():
    trace = [
        ev(1, "put_chunk", "s", "n0", idx=0, digest=D1),
        ev(2, "stream_close", "s", size=2),
    ]
    (v,) = violations(trace)
    assert v.invariant == "chunk_sequence" and "never published" in v.message


def test_chunk_sequence_chunk_beyond_close():
    trace = [
        ev(1, "put_chunk", "s", "n0", idx=0, digest=D1),
        ev(2, "put_chunk", "s", "n0", idx=1, digest=D1),
        ev(3, "put_chunk", "s", "n0", idx=5, digest=D1),
        ev(4, "stream_close", "s", size=2),
    ]
    vs = violations(trace, "chunk_sequence")
    assert len(vs) == 1 and "[5]" in str(vs[0].message)


def test_chunk_sequence_divergent_totals():
    trace = [
        ev(1, "put_chunk", "s", "n0", idx=0, digest=D1),
        ev(2, "stream_close", "s", size=1),
        ev(3, "stream_close", "s", size=3),
    ]
    vs = violations(trace, "chunk_sequence")
    assert len(vs) == 1 and "divergent totals" in vs[0].message


def test_chunk_sequence_divergent_cowrite():
    trace = [
        ev(1, "put_chunk", "s", "n0", idx=0, digest=D1),
        ev(2, "put_chunk", "s", "n1", idx=0, digest=D2),
        ev(3, "stream_close", "s", size=1),
    ]
    vs = violations(trace, "chunk_sequence")
    assert len(vs) == 1 and "divergent bytes" in vs[0].message


def test_chunk_sequence_leaked_stream():
    trace = [ev(1, "put_chunk", "s", "n0", idx=0, digest=D1)]
    vs = violations(trace, "chunk_sequence")
    assert len(vs) == 1 and "never" in vs[0].message


def test_key_reuse_after_evict_is_clean():
    # Serving restarts instance numbering per run(): after an eviction
    # the same key name legitimately carries different content.
    trace = [
        ev(1, "put", "k", "n0", digest=D1),
        ev(2, "evict", "k"),
        ev(3, "put", "k", "n0", digest=D2),
        ev(4, "get_block", "k", "n1"),
        ev(5, "replica", "k", "n1", digest=D2),
        ev(6, "get_return", "k", "n1", digest=D2),
    ]
    assert violations(trace) == []


def test_stream_reuse_after_evict_judged_per_generation():
    trace = [
        ev(1, "put_chunk", "s", "n0", idx=0, digest=D1),
        ev(2, "stream_close", "s", size=1),
        ev(3, "evict", "s"),
        ev(4, "put_chunk", "s", "n0", idx=0, digest=D2),
        ev(5, "stream_close", "s", size=2),    # generation 2 lies
    ]
    vs = violations(trace, "chunk_sequence")
    assert len(vs) == 1 and "never published" in vs[0].message


def test_aborted_stream_not_judged():
    trace = [
        ev(1, "put_chunk", "s", "n0", idx=0, digest=D1),
        ev(2, "stream_abort", "s", "n0"),
    ]
    assert violations(trace) == []


# ----------------------------------------------------------------------
# Live seeded violations against the real DStore.
# ----------------------------------------------------------------------

def traced_store(nodes, stress=None, transport=None):
    ds = DStore(nodes, transport)
    rec = TraceRecorder(stress=stress)
    ds.attach_tracer(rec)
    return ds, rec


def test_live_ordering_violation_backdoor_write():
    # Bytes smuggled into a LocalStore behind Put's back: the Get's
    # fast path returns them although no availability event exists.
    ds, rec = traced_store(["n0"])
    ds.stores["n0"].write("k", b"smuggled")
    assert ds.get("n0", "k") == b"smuggled"
    vs = violations(rec.events(), "ordering")
    assert len(vs) == 1


def test_live_eviction_violation_under_inflight_pull():
    # A slow remote pull is mid-flight when the instance is evicted:
    # exactly the reader-starvation hazard eviction safety forbids.
    ds, rec = traced_store(["n0", "n1"],
                           transport=Transport(bandwidth=4096.0))
    ds.put("n0", "i1:k", b"x" * 4096)          # ~1 s pull at 4 KB/s
    got = []
    t = threading.Thread(target=lambda: got.append(ds.get("n1", "i1:k")))
    t.start()
    time.sleep(0.3)                            # reader inside transport.move
    ds.evict_instance("i1:")
    t.join()
    vs = violations(rec.events(), "eviction")
    assert len(vs) == 1 and "i1:k" in vs[0].message


def test_live_chunk_sequence_violation_lying_close():
    # A producer that closes the stream directory at a total it never
    # published (the engine never does this; the directory trusts it).
    ds, rec = traced_store(["n0"])
    ds.streams.claim("s", "n0")
    ds.put_chunk("n0", "s", 0, b"c0")
    ds.streams.close("s", 3)
    vs = violations(rec.events(), "chunk_sequence")
    assert len(vs) == 1 and "never published" in vs[0].message


def test_live_immutability_enforced_and_traceable():
    # The directory rejects a divergent co-write outright; a trace that
    # somehow contains one (recorder events injected here) is convicted
    # by the same digest evidence.
    from repro.core.dstore import ImmutabilityError

    ds, rec = traced_store(["n0", "n1"])
    ds.put("n0", "k", b"one")
    with pytest.raises(ImmutabilityError):
        ds.put("n1", "k", b"two")
    rec.record("put", "k", "n1", digest=content_digest(b"two"))
    vs = violations(rec.events(), "immutability")
    assert len(vs) == 1


# ----------------------------------------------------------------------
# Negative contract: real runs trace clean (stress mode on).
# ----------------------------------------------------------------------

def _engine_run_traced(seed, stress):
    from strategies import external_inputs, oracle_run, random_workflow

    from repro.core.dscheduler import DFlowEngine

    wf = random_workflow(seed)
    eng = DFlowEngine(n_nodes=3)
    ds = DStore(eng.nodes, eng.transport)
    rec = TraceRecorder(stress=stress)
    ds.attach_tracer(rec)
    rep = eng.start(wf, external_inputs(wf), store=ds).wait()
    assert rep.outputs == oracle_run(wf, external_inputs(wf))
    return rec


@pytest.mark.parametrize("seed", [3, 11, 42])
def test_engine_runs_trace_clean_under_stress(seed):
    rec = _engine_run_traced(seed, stress=seed)
    assert len(rec) > 0
    TraceChecker().check_or_raise(rec.events())


def test_serve_run_traces_clean_under_stress():
    from repro.core.serve import DServe
    from repro.core.workloads import BENCHMARKS

    rec = TraceRecorder(stress=7)
    srv = DServe(BENCHMARKS["Srv"](), n_nodes=2, cold_start=0.01,
                 tracer=rec)
    rep = srv.run([0.0, 0.05, 0.1, 0.15],
                  inputs=lambda i: {"request": b"r%d" % i})
    assert rep.failures == 0 and len(rep.stats) == 4
    assert len(rec) > 0
    TraceChecker().check_or_raise(rec.events())


def test_recorder_thread_safety_and_clocks():
    rec = TraceRecorder()
    threads = [threading.Thread(
        target=lambda i=i: [rec.record("put", f"k{i}.{j}", "n0")
                            for j in range(50)]) for i in range(8)]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    events = rec.events()
    assert len(events) == 400
    assert sorted(e.clock for e in events) == list(range(1, 401))


def test_stress_mode_is_deterministically_seeded():
    a = TraceRecorder(stress=5)
    b = TraceRecorder(stress=5)
    for _ in range(20):
        a.record("put", "k", "n")
        b.record("put", "k", "n")
    assert a._stress == b._stress
