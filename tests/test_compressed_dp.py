"""int8 compressed DP gradient exchange (beyond-paper)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.launch.mesh import make_local_mesh
from repro.runtime.compressed_dp import compressed_dp_mean
from repro.sharding.context import mesh_context


def test_compressed_mean_roundtrip():
    mesh = make_local_mesh()
    with mesh_context(mesh):
        rng = np.random.default_rng(0)
        g = {"w": jnp.asarray(rng.normal(size=(64, 128)).astype(np.float32)),
             "ln": jnp.ones((8,), jnp.float32)}
        out = jax.jit(lambda gs: compressed_dp_mean(gs, mesh))(g)
        # identical per-shard values → mean == value up to int8 rounding
        rel = float(jnp.abs(out["w"] - g["w"]).max() / jnp.abs(g["w"]).max())
        assert rel < 2e-2
        # small leaves skip quantization entirely → exact
        assert jnp.allclose(out["ln"], g["ln"])


def test_compressed_mean_handles_padding():
    mesh = make_local_mesh()
    with mesh_context(mesh):
        g = {"odd": jnp.arange(7, dtype=jnp.float32) * 100.0}
        out = jax.jit(lambda gs: compressed_dp_mean(gs, mesh))(g)
        assert out["odd"].shape == (7,)
        rel = float(jnp.abs(out["odd"] - g["odd"]).max() / 600.0)
        assert rel < 2e-2
