"""Workflow DAG model + parser tests."""

import pytest

from repro.core.dag import FunctionSpec, Workflow, parse_size, parse_workflow


def test_parse_size():
    assert parse_size("8MB") == 8 << 20
    assert parse_size("2KB") == 2048
    assert parse_size("1.5GB") == int(1.5 * (1 << 30))
    assert parse_size(123) == 123
    with pytest.raises(ValueError):
        parse_size("eight megs")


def _diamond():
    return Workflow("d", [
        FunctionSpec("a", inputs=("x",), outputs=("a1", "a2")),
        FunctionSpec("b", inputs=("a1",), outputs=("b1",)),
        FunctionSpec("c", inputs=("a2",), outputs=("c1",)),
        FunctionSpec("d", inputs=("b1", "c1"), outputs=("y",)),
    ])


def test_dag_derivations():
    wf = _diamond()
    assert wf.entry_points == ("a",)
    assert wf.exit_points == ("d",)
    assert set(wf.successors["a"]) == {"b", "c"}
    assert set(wf.predecessors["d"]) == {"b", "c"}
    assert wf.topo_order.index("a") < wf.topo_order.index("d")
    assert wf.external_inputs == {"x": 1 << 20}


def test_cycle_detection():
    with pytest.raises(ValueError, match="cycle"):
        Workflow("bad", [
            FunctionSpec("a", inputs=("u",), outputs=("v",)),
            FunctionSpec("b", inputs=("v",), outputs=("u",)),
        ])


def test_duplicate_producer_rejected():
    with pytest.raises(ValueError, match="immutable"):
        Workflow("bad", [
            FunctionSpec("a", outputs=("k",)),
            FunctionSpec("b", outputs=("k",)),
        ])


def test_parse_workflow_foreach_and_glob():
    doc = {
        "name": "wc",
        "functions": {
            "split": {"inputs": ["corpus"], "outputs": ["shard.0", "shard.1"],
                      "exec_time": 0.5,
                      "output_sizes": {"shard.0": "8MB", "shard.1": "8MB"}},
            "count": {"foreach": 2, "inputs": ["shard.$i"],
                      "outputs": ["wc.$i"], "exec_time": 1.0},
            "merge": {"inputs": ["wc.*"], "outputs": ["result"]},
        },
    }
    wf = parse_workflow(doc)
    assert set(wf.functions) == {"split", "count.0", "count.1", "merge"}
    assert wf.functions["merge"].inputs == ("wc.0", "wc.1")
    assert wf.functions["split"].size_of("shard.0") == 8 << 20
    assert wf.entry_points == ("split",)


def test_parse_workflow_yaml_text():
    text = """
name: tiny
functions:
  a:
    inputs: [x]
    outputs: [y]
    exec_time: 0.1
  b:
    inputs: [y]
    outputs: [z]
"""
    wf = parse_workflow(text)
    assert wf.topo_order == ("a", "b")


def test_critical_path():
    wf = _diamond()
    wf2 = Workflow("d", [
        FunctionSpec("a", inputs=("x",), outputs=("a1", "a2"), exec_time=1.0),
        FunctionSpec("b", inputs=("a1",), outputs=("b1",), exec_time=5.0),
        FunctionSpec("c", inputs=("a2",), outputs=("c1",), exec_time=1.0),
        FunctionSpec("d", inputs=("b1", "c1"), outputs=("y",), exec_time=1.0),
    ])
    assert wf2.critical_path_time() == pytest.approx(7.0)
    assert wf2.total_exec_time() == pytest.approx(8.0)
