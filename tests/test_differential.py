"""Differential conformance + Algorithm 1 frontier invariants on fuzzed DAGs.

Ground truth is a sequential topological-order oracle (strategies.oracle_run)
over deterministic digest callables: any schedule the threaded DFlowEngine
produces — dataflow or controlflow, streams included — must emit identical
sink bytes and run every function exactly once.  The simulator must complete
the same DAGs deterministically (identical transfer counts across runs).

Two layers: hypothesis-driven bounded tests (skip when hypothesis is
absent) and a deterministic 200-seed sweep (marked ``slow``; CI's quick
tier skips it, the full tier and local tier-1 runs execute it).
"""

import pytest
from conftest import given, settings, st                      # noqa: F401
from strategies import external_inputs, oracle_run, random_workflow, workflows

from repro.core.dscheduler import (DFlowEngine, dataflow_initial_frontier,
                                   dataflow_next_frontier)
from repro.core.sim import Env
from repro.core.sim_systems import make_system
from repro.core.simcluster import Cluster, SimConfig

N_SEEDS = 200


# ----------------------------------------------------------------------
# Algorithm 1 frontier invariants
# ----------------------------------------------------------------------

def check_frontier_invariants(wf):
    initial = dataflow_initial_frontier(wf)
    # Never launch twice: the frontier lists themselves carry no duplicates.
    assert len(initial) == len(set(initial))
    assert set(wf.entry_points) <= set(initial)
    # Soundness: initial = entries + their direct successors, nothing else.
    allowed = set(wf.entry_points)
    for e in wf.entry_points:
        allowed.update(wf.successors[e])
    assert set(initial) <= allowed
    launched = set(initial)
    for fname in wf.topo_order:                 # completions in topo order
        nxt = dataflow_next_frontier(wf, fname)
        assert len(nxt) == len(set(nxt))
        grand = {t for s in wf.successors[fname] for t in wf.successors[s]}
        assert set(nxt) == grand                # exactly the +2 frontier
        launched.update(nxt)
    # Never skip: every function is launched by the time its
    # grandparent-or-earlier completed.
    assert launched == set(wf.functions)


@pytest.mark.parametrize("seed", range(60))
def test_frontier_invariants_fuzzed(seed):
    check_frontier_invariants(random_workflow(seed * 7919 + 13))


@settings(max_examples=40, deadline=None)
@given(wf=workflows())
def test_frontier_invariants_hypothesis(wf):
    check_frontier_invariants(wf)


# ----------------------------------------------------------------------
# Threaded engine vs sequential oracle
# ----------------------------------------------------------------------

def check_engine_matches_oracle(seed, pattern):
    oracle_wf = random_workflow(seed)
    ext = external_inputs(oracle_wf)
    expected = oracle_run(oracle_wf, ext)

    calls: dict[str, int] = {}
    wf = random_workflow(seed, calls=calls)
    rep = DFlowEngine(n_nodes=2, pattern=pattern,
                      get_timeout=30.0).run(wf, ext)
    got = {k: bytes(v) for k, v in rep.outputs.items()}
    assert got == expected, f"seed {seed} pattern {pattern}"
    # Exactly-once execution (Algorithm 1's launch guard, no duplicates).
    assert calls == {f: 1 for f in wf.functions}, (seed, pattern, calls)


@pytest.mark.slow
@pytest.mark.parametrize("seed", range(N_SEEDS))
def test_differential_dataflow_200(seed):
    check_engine_matches_oracle(seed, "dataflow")


@pytest.mark.slow
@pytest.mark.parametrize("seed", range(N_SEEDS))
def test_differential_controlflow_200(seed):
    check_engine_matches_oracle(seed, "controlflow")


@settings(max_examples=25, deadline=None)
@given(seed=st.integers(min_value=0, max_value=2**32 - 1),
       pattern=st.sampled_from(["dataflow", "controlflow"]))
def test_differential_hypothesis(seed, pattern):
    check_engine_matches_oracle(seed, pattern)


# ----------------------------------------------------------------------
# Simulator: completion + deterministic transfer counts
# ----------------------------------------------------------------------

def _sim_run(system, wf, cfg):
    env = Env()
    cluster = Cluster(env, cfg)
    sys_ = make_system(system, env, cluster, wf)
    res = sys_.invoke()
    env.run(until=cfg.timeout * 2)
    assert res.done.triggered and not res.cancelled, system
    assert len(res.completed) == len(wf.functions), system
    return len(cluster.network.log), cluster.internode_bytes()


@pytest.mark.parametrize("seed", range(0, N_SEEDS, 5))
def test_sim_differential_deterministic(seed):
    """dflow and cflow both complete every fuzzed DAG, and two identical
    dflow runs move identical transfer counts/bytes (pure determinism)."""
    wf = random_workflow(seed, stream_prob=0.0)
    cfg = SimConfig(n_workers=3)
    a = _sim_run("dflow", wf, cfg)
    b = _sim_run("dflow", wf, cfg)
    assert a == b
    _sim_run("cflow", wf, cfg)


def test_strategy_reproducible():
    """Same seed -> same DAG shape (strategy is deterministic)."""
    a = random_workflow(1234)
    b = random_workflow(1234)
    assert list(a.functions) == list(b.functions)
    assert a.successors == b.successors
    assert a.topo_order == b.topo_order
