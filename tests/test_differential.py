"""Differential conformance + Algorithm 1 frontier invariants on fuzzed DAGs.

Ground truth is a sequential topological-order oracle (strategies.oracle_run)
over deterministic digest callables: any schedule the threaded DFlowEngine
produces — dataflow or controlflow, streams included — must emit identical
sink bytes and run every function exactly once.  The simulator must complete
the same DAGs deterministically (identical transfer counts across runs).

Two layers: hypothesis-driven bounded tests (skip when hypothesis is
absent) and a deterministic 200-seed sweep (marked ``slow``; CI's quick
tier skips it, the full tier and local tier-1 runs execute it).
"""

import os

import pytest
from conftest import given, settings, st                      # noqa: F401
from strategies import (external_inputs, oracle_run, random_workflow,
                        sharded_run, workflows)

from repro.core.dscheduler import (DFlowEngine, dataflow_initial_frontier,
                                   dataflow_next_frontier)
from repro.core.sim import Env
from repro.core.sim_systems import make_system
from repro.core.simcluster import Cluster, SimConfig

N_SEEDS = 200


# ----------------------------------------------------------------------
# Algorithm 1 frontier invariants
# ----------------------------------------------------------------------

def check_frontier_invariants(wf):
    initial = dataflow_initial_frontier(wf)
    # Never launch twice: the frontier lists themselves carry no duplicates.
    assert len(initial) == len(set(initial))
    assert set(wf.entry_points) <= set(initial)
    # Soundness: initial = entries + their direct successors, nothing else.
    allowed = set(wf.entry_points)
    for e in wf.entry_points:
        allowed.update(wf.successors[e])
    assert set(initial) <= allowed
    launched = set(initial)
    for fname in wf.topo_order:                 # completions in topo order
        nxt = dataflow_next_frontier(wf, fname)
        assert len(nxt) == len(set(nxt))
        grand = {t for s in wf.successors[fname] for t in wf.successors[s]}
        assert set(nxt) == grand                # exactly the +2 frontier
        launched.update(nxt)
    # Never skip: every function is launched by the time its
    # grandparent-or-earlier completed.
    assert launched == set(wf.functions)


@pytest.mark.parametrize("seed", range(60))
def test_frontier_invariants_fuzzed(seed):
    check_frontier_invariants(random_workflow(seed * 7919 + 13))


@settings(max_examples=40, deadline=None)
@given(wf=workflows())
def test_frontier_invariants_hypothesis(wf):
    check_frontier_invariants(wf)


# ----------------------------------------------------------------------
# Threaded engine vs sequential oracle
# ----------------------------------------------------------------------

def check_engine_matches_oracle(seed, pattern):
    oracle_wf = random_workflow(seed)
    ext = external_inputs(oracle_wf)
    expected = oracle_run(oracle_wf, ext)

    calls: dict[str, int] = {}
    wf = random_workflow(seed, calls=calls)
    rep = DFlowEngine(n_nodes=2, pattern=pattern,
                      get_timeout=30.0).run(wf, ext)
    got = {k: bytes(v) for k, v in rep.outputs.items()}
    assert got == expected, f"seed {seed} pattern {pattern}"
    # Exactly-once execution (Algorithm 1's launch guard, no duplicates).
    assert calls == {f: 1 for f in wf.functions}, (seed, pattern, calls)


@pytest.mark.slow
@pytest.mark.parametrize("seed", range(N_SEEDS))
def test_differential_dataflow_200(seed):
    check_engine_matches_oracle(seed, "dataflow")


@pytest.mark.slow
@pytest.mark.parametrize("seed", range(N_SEEDS))
def test_differential_controlflow_200(seed):
    check_engine_matches_oracle(seed, "controlflow")


@settings(max_examples=25, deadline=None)
@given(seed=st.integers(min_value=0, max_value=2**32 - 1),
       pattern=st.sampled_from(["dataflow", "controlflow"]))
def test_differential_hypothesis(seed, pattern):
    check_engine_matches_oracle(seed, pattern)


# ----------------------------------------------------------------------
# DShard: sharded store vs oracle AND vs the single-store baseline
# ----------------------------------------------------------------------

SHARD_NODES = (1, 2, 4)

# Satellite contract: trace-clean under schedule stress — honour the same
# env knob the conftest fixture uses so CI's DFLOW_TRACE_STRESS=7 pass
# stresses the sharded runs too.
_STRESS = int(os.environ.get("DFLOW_TRACE_STRESS", "0") or 0) or None


def check_sharded_matches_baseline(seed, n_nodes):
    """ShardedDStore run == oracle == single-store baseline, byte-exact;
    the trace (incl. the 1-hop routing invariant) must be clean and no
    Get may ever resolve in 2 hops."""
    from repro.core.check import TraceChecker

    oracle_wf = random_workflow(seed)
    ext = external_inputs(oracle_wf)
    expected = oracle_run(oracle_wf, ext)

    baseline = DFlowEngine(n_nodes=2, get_timeout=30.0).run(
        random_workflow(seed), ext)
    base_out = {k: bytes(v) for k, v in baseline.outputs.items()}
    assert base_out == expected, f"seed {seed} baseline vs oracle"

    got, store, events = sharded_run(seed, n_nodes, stress=_STRESS)
    assert got == expected, f"seed {seed} nodes {n_nodes} vs oracle"
    assert got == base_out, f"seed {seed} nodes {n_nodes} vs single-store"
    TraceChecker().check_or_raise(events)
    bounces = sum(v for h, v in store.hop_hist.items() if h >= 2)
    assert bounces == 0, (seed, n_nodes, dict(store.hop_hist))


@pytest.mark.parametrize("n_nodes", SHARD_NODES)
@pytest.mark.parametrize("seed", range(0, N_SEEDS, 20))
def test_sharded_differential_quick(seed, n_nodes):
    check_sharded_matches_baseline(seed, n_nodes)


@pytest.mark.slow
@pytest.mark.parametrize("n_nodes", SHARD_NODES)
@pytest.mark.parametrize("seed", range(N_SEEDS))
def test_sharded_differential_200(seed, n_nodes):
    check_sharded_matches_baseline(seed, n_nodes)


@pytest.mark.slow
@pytest.mark.parametrize("seed", range(0, N_SEEDS, 4))
def test_sharded_controlflow_differential(seed):
    """The sharded store is pattern-agnostic: controlflow invocation over
    DShard is byte-exact too (routing never depends on launch order)."""
    from repro.core.check import TraceChecker

    oracle_wf = random_workflow(seed)
    expected = oracle_run(oracle_wf, external_inputs(oracle_wf))
    got, store, events = sharded_run(seed, 2, pattern="controlflow",
                                     stress=_STRESS)
    assert got == expected, seed
    TraceChecker().check_or_raise(events)


# ----------------------------------------------------------------------
# Simulator: completion + deterministic transfer counts
# ----------------------------------------------------------------------

def _sim_run(system, wf, cfg):
    env = Env()
    cluster = Cluster(env, cfg)
    sys_ = make_system(system, env, cluster, wf)
    res = sys_.invoke()
    env.run(until=cfg.timeout * 2)
    assert res.done.triggered and not res.cancelled, system
    assert len(res.completed) == len(wf.functions), system
    return len(cluster.network.log), cluster.internode_bytes()


@pytest.mark.parametrize("seed", range(0, N_SEEDS, 5))
def test_sim_differential_deterministic(seed):
    """dflow and cflow both complete every fuzzed DAG, and two identical
    dflow runs move identical transfer counts/bytes (pure determinism)."""
    wf = random_workflow(seed, stream_prob=0.0)
    cfg = SimConfig(n_workers=3)
    a = _sim_run("dflow", wf, cfg)
    b = _sim_run("dflow", wf, cfg)
    assert a == b
    _sim_run("cflow", wf, cfg)


def test_strategy_reproducible():
    """Same seed -> same DAG shape (strategy is deterministic)."""
    a = random_workflow(1234)
    b = random_workflow(1234)
    assert list(a.functions) == list(b.functions)
    assert a.successors == b.successors
    assert a.topo_order == b.topo_order
