"""Real (threaded) DStore tests: Table 1 API, block/wake, replicas, faults."""

import threading
import time

import pytest

from repro.core.dstore import (DStore, GetTimeout, ImmutabilityError,
                               Transport)


def test_put_get_local():
    ds = DStore(["n0", "n1"])
    ds.put("n0", "k", b"hello")
    assert ds.get("n0", "k") == b"hello"
    assert ds.transport.transfers == 0      # local hit: no network


def test_get_remote_receiver_driven():
    ds = DStore(["n0", "n1"])
    ds.put("n0", "k", b"payload")
    assert ds.get("n1", "k") == b"payload"
    assert ds.transport.transfers == 1
    # After the pull the consumer node holds a replica; next get is local.
    assert ds.get("n1", "k") == b"payload"
    assert ds.transport.transfers == 1


def test_auto_block_wake():
    """Get blocks until the producer publishes (paper §3.3.2)."""
    ds = DStore(["n0", "n1"])
    got = {}

    def consumer():
        got["v"] = ds.get("n1", "late")
    th = threading.Thread(target=consumer)
    th.start()
    time.sleep(0.05)
    assert "v" not in got                    # still blocked
    ds.put("n0", "late", 42)
    th.join(timeout=5)
    assert got["v"] == 42


def test_get_timeout():
    ds = DStore(["n0"])
    with pytest.raises(GetTimeout):
        ds.get("n0", "never", timeout=0.05)


def test_replica_least_access_frequency():
    """With replicas on two nodes, concurrent fetches spread across them."""
    ds = DStore(["n0", "n1", "n2", "n3"])
    ds.put("n0", "k", b"x" * 1000)
    ds.get("n1", "k")                         # replica now on n0 + n1
    # choose_replica alternates by in-flight count.
    first = ds.directory.choose_replica("k")
    second = ds.directory.choose_replica("k")
    assert {first, second} == {"n0", "n1"}
    ds.directory.release_replica("k", first)
    ds.directory.release_replica("k", second)


def test_immutability_first_writer_wins():
    ds = DStore(["n0"])
    ds.put("n0", "k", "first")
    ds.put("n0", "k", "first")                # identical co-write: no-op
    assert ds.get("n0", "k") == "first"


def test_immutability_divergent_cowrite_rejected():
    # A straggler re-execution must produce the same bytes; anything else
    # breaks the determinism premise first-writer-wins rests on — from
    # any node, same or different.
    ds = DStore(["n0", "n1"])
    ds.put("n0", "k", "first")
    with pytest.raises(ImmutabilityError):
        ds.put("n0", "k", "second")
    with pytest.raises(ImmutabilityError):
        ds.put("n1", "k", "second")
    assert ds.get("n1", "k") == "first"


def test_immutability_opaque_cowrite_tolerated():
    # Values with no reliable byte representation can't be compared;
    # the check stays conservative (first-writer-wins, no rejection).
    class Opaque:
        pass

    ds = DStore(["n0"])
    ds.put("n0", "k", Opaque())
    ds.put("n0", "k", Opaque())
    ds.get("n0", "k")


def test_fail_node_drops_replicas():
    ds = DStore(["n0", "n1"])
    ds.put("n0", "only_here", 1)
    ds.put("n0", "replicated", 2)
    ds.get("n1", "replicated")                # replica on n1
    lost = ds.fail_node("n0")
    assert lost == ["only_here"]              # replicated survives on n1
    assert ds.get("n1", "replicated") == 2


def test_transport_accounting():
    tr = Transport()
    ds = DStore(["n0", "n1"], tr)
    import numpy as np
    arr = np.zeros(1024, dtype=np.uint8)
    ds.put("n0", "arr", arr)
    ds.get("n1", "arr")
    assert tr.bytes_moved == 1024
