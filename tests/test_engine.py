"""Threaded DFlowEngine tests: real callables, out-of-order correctness,
straggler duplication, incremental fault recovery."""

import time

import numpy as np
import pytest

from repro.core.dag import FunctionSpec, Workflow
from repro.core.dscheduler import (DFlowEngine, dataflow_initial_frontier,
                                   dataflow_next_frontier)
from repro.core.dstore import Transport


def _sum_workflow():
    """x -> a: +1 ; a -> b: *2 ; a -> c: *3 ; (b,c) -> d: add."""
    return Workflow("sum", [
        FunctionSpec("a", inputs=("x",), outputs=("a_out",),
                     fn=lambda x: {"a_out": x + 1}, exec_time=0.01),
        FunctionSpec("b", inputs=("a_out",), outputs=("b_out",),
                     fn=lambda a_out: {"b_out": a_out * 2}, exec_time=0.01),
        FunctionSpec("c", inputs=("a_out",), outputs=("c_out",),
                     fn=lambda a_out: {"c_out": a_out * 3}, exec_time=0.01),
        FunctionSpec("d", inputs=("b_out", "c_out"), outputs=("y",),
                     fn=lambda b_out, c_out: {"y": b_out + c_out},
                     exec_time=0.01),
    ])


def test_frontier_policies():
    wf = _sum_workflow()
    assert dataflow_initial_frontier(wf) == ["a", "b", "c"]
    assert set(dataflow_next_frontier(wf, "a")) == {"d"}
    assert dataflow_next_frontier(wf, "d") == []


@pytest.mark.parametrize("pattern", ["dataflow", "controlflow"])
def test_engine_correct_result(pattern):
    eng = DFlowEngine(n_nodes=2, pattern=pattern)
    rep = eng.run(_sum_workflow(), {"x": 10})
    assert rep.outputs["y"] == (11 * 2) + (11 * 3)


def test_engine_numpy_payloads():
    def make(n):
        return {"m": np.eye(n)}

    def double(m):
        return {"d": m * 2}

    def trace(d):
        return {"t": float(np.trace(d))}
    wf = Workflow("np", [
        FunctionSpec("make", inputs=(), outputs=("m",), fn=lambda: make(4)),
        FunctionSpec("double", inputs=("m",), outputs=("d",), fn=double),
        FunctionSpec("trace", inputs=("d",), outputs=("t",), fn=trace),
    ])
    rep = DFlowEngine(n_nodes=3).run(wf)
    assert rep.outputs["t"] == 8.0


def test_dataflow_overlap_beats_controlflow():
    """With a slow producer and a slow network, dataflow invocation lets the
    consumer's *other* work overlap — wall-time should not regress and the
    result must match."""
    def slow_src():
        time.sleep(0.15)
        return {"s": np.ones(8)}

    def other():
        time.sleep(0.15)
        return {"o": np.ones(8) * 2}

    def join(s, o):
        return {"y": float((s + o).sum())}
    wf = Workflow("ovl", [
        FunctionSpec("src", inputs=(), outputs=("s",), fn=slow_src,
                     exec_time=0.15),
        FunctionSpec("oth", inputs=(), outputs=("o",), fn=other,
                     exec_time=0.15),
        FunctionSpec("join", inputs=("s", "o"), outputs=("y",), fn=join,
                     exec_time=0.01),
    ])
    rep_df = DFlowEngine(n_nodes=2, pattern="dataflow").run(wf)
    rep_cf = DFlowEngine(n_nodes=2, pattern="controlflow").run(wf)
    assert rep_df.outputs["y"] == rep_cf.outputs["y"] == 24.0


def test_engine_error_propagates():
    def boom():
        raise ValueError("kaput")
    wf = Workflow("err", [
        FunctionSpec("boom", inputs=(), outputs=("z",), fn=boom),
    ])
    with pytest.raises(RuntimeError, match="boom"):
        DFlowEngine(n_nodes=1).run(wf)


def test_straggler_duplicate_issue():
    """A function that sleeps far beyond its spec time gets duplicated on
    another node; first writer wins and the result stays correct."""
    calls = []

    def sometimes_slow():
        calls.append(threading_ident())
        if len(calls) == 1:
            time.sleep(1.0)      # straggler on first attempt
        return {"v": 7}

    def threading_ident():
        import threading
        return threading.get_ident()

    wf = Workflow("strag", [
        FunctionSpec("s", inputs=(), outputs=("v",), fn=sometimes_slow,
                     exec_time=0.02),
        FunctionSpec("use", inputs=("v",), outputs=("y",),
                     fn=lambda v: {"y": v * 2}, exec_time=0.01),
    ])
    eng = DFlowEngine(n_nodes=2, straggler_factor=3.0)
    rep = eng.run(wf)
    assert rep.outputs["y"] == 14
    assert len(calls) >= 2                   # duplicate actually issued


def test_incremental_fault_recovery():
    """Losing a node re-executes only the functions whose outputs died
    (beyond-paper: §3.3.5 would restart everything)."""
    runs = {"a": 0, "b": 0}

    def fa():
        runs["a"] += 1
        return {"ka": 5}

    def fb(ka):
        runs["b"] += 1
        return {"kb": ka + 1}
    wf = Workflow("ft", [
        FunctionSpec("a", inputs=(), outputs=("ka",), fn=fa, exec_time=0.01),
        FunctionSpec("b", inputs=("ka",), outputs=("kb",), fn=fb,
                     exec_time=0.01),
    ])
    eng = DFlowEngine(n_nodes=2)
    placement = eng.gs.assign(wf)
    rep = eng.run(wf, inject_failure=placement["a"])
    assert rep.outputs["kb"] == 6
    assert rep.reexecuted            # something was re-run
    assert runs["a"] >= 2 or runs["b"] >= 2
