"""Pallas kernel validation: shape/dtype sweeps vs pure-jnp oracles
(interpret mode executes the kernel bodies on CPU)."""

import jax
import jax.numpy as jnp
import pytest

from repro.kernels.decode_attention import (decode_attention,
                                            decode_attention_ref)
from repro.kernels.flash_attention import flash_attention
from repro.kernels.ssd import ssd

TOL = {jnp.float32: 2e-5, jnp.bfloat16: 2e-2}


def _tol(dtype):
    return TOL[jnp.bfloat16 if dtype == jnp.bfloat16 else jnp.float32]


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("causal", [True, False])
@pytest.mark.parametrize("B,S,H,Hk,D,bq,bk", [
    (1, 32, 2, 2, 16, 16, 16),      # MHA
    (2, 64, 4, 2, 32, 32, 32),      # GQA group 2
    (1, 128, 8, 2, 64, 64, 32),     # GQA group 4, rectangular blocks
    (1, 64, 6, 1, 32, 16, 64),      # MQA-ish, bk > bq
])
def test_flash_attention_sweep(dtype, causal, B, S, H, Hk, D, bq, bk):
    ks = jax.random.split(jax.random.key(0), 3)
    q = jax.random.normal(ks[0], (B, S, H, D)).astype(dtype)
    k = jax.random.normal(ks[1], (B, S, Hk, D)).astype(dtype)
    v = jax.random.normal(ks[2], (B, S, Hk, D)).astype(dtype)
    got = flash_attention(q, k, v, causal=causal, block_q=bq, block_k=bk,
                          interpret=True)
    ref = flash_attention(q, k, v, causal=causal, use_kernel=False)
    err = float(jnp.abs(got.astype(jnp.float32)
                        - ref.astype(jnp.float32)).max())
    assert err < _tol(dtype), err


def test_flash_attention_rejects_bad_blocks():
    q = jnp.zeros((1, 30, 2, 16))
    k = v = jnp.zeros((1, 30, 2, 16))
    with pytest.raises(ValueError, match="divisible"):
        flash_attention(q, k, v, block_q=16, block_k=16, interpret=True)


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("used", [1, 63, 128, 256])
@pytest.mark.parametrize("B,H,Hk,D,L,bk", [
    (2, 8, 2, 32, 256, 64),
    (1, 4, 4, 16, 256, 128),        # MHA
    (3, 6, 1, 64, 256, 256),        # MQA, single block
])
def test_decode_attention_sweep(dtype, used, B, H, Hk, D, L, bk):
    ks = jax.random.split(jax.random.key(1), 3)
    q = jax.random.normal(ks[0], (B, 1, H, D)).astype(dtype)
    k = jax.random.normal(ks[1], (B, L, Hk, D)).astype(dtype)
    v = jax.random.normal(ks[2], (B, L, Hk, D)).astype(dtype)
    got = decode_attention(q, k, v, jnp.int32(used), block_k=bk,
                           interpret=True)
    ref = decode_attention(q, k, v, jnp.int32(used), use_kernel=False)
    err = float(jnp.abs(got.astype(jnp.float32)
                        - ref.astype(jnp.float32)).max())
    assert err < _tol(dtype), (used, err)


def test_decode_attention_ignores_stale_tail():
    """Garbage beyond `length` must not leak into the output."""
    ks = jax.random.split(jax.random.key(2), 3)
    q = jax.random.normal(ks[0], (1, 1, 4, 16), jnp.float32)
    k = jax.random.normal(ks[1], (1, 64, 2, 16), jnp.float32)
    v = jax.random.normal(ks[2], (1, 64, 2, 16), jnp.float32)
    k_dirty = k.at[:, 40:].set(1e4)
    v_dirty = v.at[:, 40:].set(-1e4)
    a = decode_attention(q, k, v, jnp.int32(40), block_k=32, interpret=True)
    b = decode_attention(q, k_dirty, v_dirty, jnp.int32(40), block_k=32,
                         interpret=True)
    assert jnp.allclose(a, b, atol=1e-5)


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("B,S,H,P,N,chunk", [
    (1, 32, 2, 8, 16, 16),
    (2, 64, 4, 16, 32, 32),
    (1, 128, 8, 64, 128, 64),       # full-size head dims (mamba2-370m)
    (2, 64, 4, 16, 32, 64),         # one chunk == S? no: 64
])
def test_ssd_sweep(dtype, B, S, H, P, N, chunk):
    ks = jax.random.split(jax.random.key(3), 5)
    x = jax.random.normal(ks[0], (B, S, H, P)).astype(dtype)
    dt = jax.nn.softplus(jax.random.normal(ks[1], (B, S, H))).astype(dtype)
    A = -jnp.exp(jax.random.normal(ks[2], (H,)))
    Bm = jax.random.normal(ks[3], (B, S, N)).astype(dtype)
    Cm = jax.random.normal(ks[4], (B, S, N)).astype(dtype)
    got = ssd(x, dt, A, Bm, Cm, chunk=chunk, interpret=True)
    ref = ssd(x, dt, A, Bm, Cm, chunk=chunk, use_kernel=False)
    rel = float(jnp.abs(got.astype(jnp.float32) - ref.astype(jnp.float32))
                .max() / (jnp.abs(ref.astype(jnp.float32)).max() + 1e-9))
    assert rel < _tol(dtype) * 5, rel


def test_ssd_long_context_stability():
    """Decaying state over many chunks: no NaN/Inf, bounded output."""
    B, S, H, P, N = 1, 512, 2, 8, 16
    ks = jax.random.split(jax.random.key(4), 5)
    x = jax.random.normal(ks[0], (B, S, H, P), jnp.float32)
    dt = jax.nn.softplus(jax.random.normal(ks[1], (B, S, H)))
    A = -jnp.exp(jax.random.normal(ks[2], (H,)))
    Bm = jax.random.normal(ks[3], (B, S, N), jnp.float32)
    Cm = jax.random.normal(ks[4], (B, S, N), jnp.float32)
    y = ssd(x, dt, A, Bm, Cm, chunk=64, interpret=True)
    assert bool(jnp.all(jnp.isfinite(y)))
