"""DCheck static linter: per-diagnostic mutation tests + CLI.

Strategy: start from a known-clean workflow, inject exactly one defect
class, and assert the exact DF code fires — so every diagnostic is pinned
to the defect it exists for, and a refactor that silently stops detecting
one fails its dedicated test.
"""

import json

import pytest

from repro.core.dag import FunctionSpec, Workflow
from repro.core.lint import (CODES, WorkflowLintError, check_workflow, lint,
                             lint_workflow, max_severity)
from repro.core.workloads import BENCHMARKS
from repro.lint import main as lint_main


def _fn(**kw):
    return {}


def _spec(name, inputs=(), outputs=(), **kw):
    kw.setdefault("fn", _fn)
    return FunctionSpec(name, inputs=tuple(inputs), outputs=tuple(outputs),
                        **kw)


def clean_wf():
    return Workflow("t", [
        _spec("a", inputs=("x",), outputs=("k1",)),
        _spec("b", inputs=("k1",), outputs=("r",)),
    ])


def codes_of(diags):
    return {d.code for d in diags}


# ----------------------------------------------------------------------
# Baseline: the clean workflow and every built-in workload are clean.
# ----------------------------------------------------------------------

def test_clean_workflow_lints_clean():
    assert lint_workflow(clean_wf(), require_fns=True) == []


@pytest.mark.parametrize("name", sorted(BENCHMARKS))
def test_builtin_workloads_lint_clean(name):
    assert lint_workflow(BENCHMARKS[name]()) == []


# ----------------------------------------------------------------------
# Workflow-level mutations, one code each.
# ----------------------------------------------------------------------

def test_df001_by_product_output():
    wf = Workflow("t", [
        _spec("a", inputs=("x",), outputs=("k1", "junk")),
        _spec("b", inputs=("k1",), outputs=("r",)),
    ])
    diags = lint_workflow(wf)
    assert codes_of(diags) == {"DF001"}
    (d,) = diags
    assert d.key == "junk" and d.severity == "info"


def test_df001_not_raised_for_exit_outputs():
    # Exit-function outputs are the workflow's results, not by-products.
    assert lint_workflow(clean_wf()) == []


def test_df002_disconnected_function():
    wf = Workflow("t", [
        _spec("a", inputs=("x",), outputs=("k1",)),
        _spec("b", inputs=("k1",), outputs=("r",)),
        _spec("island", inputs=(), outputs=("z",)),
    ])
    assert "DF002" in codes_of(lint_workflow(wf))


def test_df003_self_consumed_key():
    wf = Workflow("t", [
        _spec("a", inputs=("x",), outputs=("k1",)),
        _spec("b", inputs=("k1", "r"), outputs=("r",)),
    ])
    diags = [d for d in lint_workflow(wf) if d.code == "DF003"]
    assert diags and diags[0].key == "r" and diags[0].severity == "error"


def test_df004_stream_output_consumed_monolithically():
    wf = Workflow("t", [
        _spec("a", inputs=("x",), outputs=("k1",), stream_outputs=("k1",)),
        _spec("b", inputs=("k1",), outputs=("r",)),
    ])
    diags = [d for d in lint_workflow(wf) if d.code == "DF004"]
    assert diags and diags[0].severity == "info"


def test_df005_stream_input_from_monolithic_producer():
    wf = Workflow("t", [
        _spec("a", inputs=("x",), outputs=("k1",)),
        _spec("b", inputs=("k1",), outputs=("r",), stream_inputs=("k1",)),
    ])
    assert "DF005" in codes_of(lint_workflow(wf))


def test_df006_chunk_size_mismatch():
    wf = Workflow("t", [
        _spec("a", inputs=("x",), outputs=("k1",), stream_outputs=("k1",),
              chunk_size=512),
        _spec("b", inputs=("k1",), outputs=("r",), stream_inputs=("k1",),
              chunk_size=1024),
    ])
    diags = [d for d in lint_workflow(wf) if d.code == "DF006"]
    assert diags and diags[0].severity == "warning"


def test_df006_silent_when_sizes_agree():
    wf = Workflow("t", [
        _spec("a", inputs=("x",), outputs=("k1",), stream_outputs=("k1",),
              chunk_size=512),
        _spec("b", inputs=("k1",), outputs=("r",), stream_inputs=("k1",),
              chunk_size=512),
    ])
    assert "DF006" not in codes_of(lint_workflow(wf))


def test_df008_reserved_separator_in_key():
    wf = Workflow("t", [
        _spec("a", inputs=("x",), outputs=("k:1",)),
        _spec("b", inputs=("k:1",), outputs=("r#2",)),
    ])
    diags = [d for d in lint_workflow(wf) if d.code == "DF008"]
    assert {d.key for d in diags} == {"k:1", "r#2"}
    assert all(d.severity == "error" for d in diags)


def test_df010_missing_fn_binding_for_engine_run():
    wf = Workflow("t", [
        _spec("a", inputs=("x",), outputs=("k1",)),
        FunctionSpec("b", inputs=("k1",), outputs=("r",)),   # fn=None
    ])
    diags = [d for d in lint_workflow(wf, require_fns=True)
             if d.code == "DF010"]
    assert diags and diags[0].severity == "error"
    # Without an engine-run request, a mixed workflow is only a warning.
    diags = [d for d in lint_workflow(wf) if d.code == "DF010"]
    assert diags and diags[0].severity == "warning"
    # Fully unbound (simulator-style) workflows are fine.
    sim = Workflow("t", [
        FunctionSpec("a", inputs=("x",), outputs=("k1",)),
        FunctionSpec("b", inputs=("k1",), outputs=("r",)),
    ])
    assert lint_workflow(sim) == []


def test_df014_undeclared_external_input():
    wf = Workflow("t", [
        _spec("a", inputs=("x", "corpsu"), outputs=("k1",)),   # typo'd key
        _spec("b", inputs=("k1",), outputs=("r",)),
    ], external_inputs={"x": 64, "corpus": 64})
    diags = [d for d in lint_workflow(wf) if d.code == "DF014"]
    assert diags and diags[0].key == "corpsu"


def test_df014_silent_without_declared_externals():
    # No declared set to check against: inferred externals are the normal
    # contract (keys never produced are workflow inputs).
    assert "DF014" not in codes_of(lint_workflow(clean_wf()))


def test_df015_invalid_resources():
    wf = Workflow("t", [
        _spec("a", inputs=("x",), outputs=("k1",), exec_time=-0.5),
        _spec("b", inputs=("k1",), outputs=("r",), cpu=0.0),
    ])
    diags = [d for d in lint_workflow(wf) if d.code == "DF015"]
    assert {d.function for d in diags} == {"a", "b"}


# ----------------------------------------------------------------------
# Doc-level mutations (defects construction would reject still get codes).
# ----------------------------------------------------------------------

def _doc(functions, **extra):
    return {"name": "t", "functions": functions, **extra}


def test_df000_unparseable_yaml():
    assert codes_of(lint("{:::")) == {"DF000"}
    assert codes_of(lint({"no_functions": True})) == {"DF000"}


def test_df007_output_sizes_unknown_key():
    doc = _doc({
        "a": {"inputs": ["x"], "outputs": ["k1"],
              "output_sizes": {"k2": "8MB"}},
        "b": {"inputs": ["k1"], "outputs": ["r"]},
    })
    diags = lint(doc)
    assert "DF007" in codes_of(diags)
    # Construction would raise (FunctionSpec validates now); the linter
    # still reports the precise code, not a bare DF000 traceback.
    assert "DF000" not in codes_of(diags)


def test_df009_glob_matches_nothing():
    doc = _doc({
        "a": {"inputs": ["x"], "outputs": ["k1"]},
        "b": {"inputs": ["wc.*"], "outputs": ["r"]},
    })
    diags = [d for d in lint(doc) if d.code == "DF009"]
    assert diags and diags[0].severity == "error"


def test_df009_glob_over_matches_families():
    doc = _doc({
        "a": {"inputs": ["x"], "outputs": ["out.1"]},
        "b": {"inputs": ["x"], "outputs": ["out.2"]},
        "c": {"inputs": ["out.*"], "outputs": ["r"]},
    })
    diags = [d for d in lint(doc) if d.code == "DF009"]
    assert diags and diags[0].severity == "warning"


def test_df011_duplicate_producer():
    doc = _doc({
        "a": {"inputs": ["x"], "outputs": ["k1"]},
        "b": {"inputs": ["x"], "outputs": ["k1"]},
    })
    diags = [d for d in lint(doc) if d.code == "DF011"]
    assert diags and diags[0].key == "k1"


def test_df012_foreach_collision():
    doc = _doc({
        "count": {"foreach": 2, "inputs": ["x"], "outputs": ["wc.$i"]},
        "count.1": {"inputs": ["x"], "outputs": ["other"]},
    })
    assert "DF012" in codes_of(lint(doc))


def test_df013_cycle():
    doc = _doc({
        "a": {"inputs": ["k2"], "outputs": ["k1"]},
        "b": {"inputs": ["k1"], "outputs": ["k2"]},
    })
    diags = [d for d in lint(doc) if d.code == "DF013"]
    assert diags and diags[0].severity == "error"


def test_clean_doc_lints_clean():
    doc = _doc({
        "split": {"inputs": ["corpus"],
                  "outputs": ["shard.0", "shard.1"],
                  "output_sizes": {"shard.0": "1KB", "shard.1": "1KB"}},
        "count": {"foreach": 2, "inputs": ["shard.$i"],
                  "outputs": ["wc.$i"]},
        "merge": {"inputs": ["wc.*"], "outputs": ["result"]},
    }, external_inputs={"corpus": "2KB"})
    assert lint(doc) == []


def test_registry_exercises_ten_plus_codes():
    """Acceptance floor: the linter detects >= 10 distinct codes (every
    registry entry has a dedicated mutation test above; this is the
    aggregate guard)."""
    fired = set()
    fired |= codes_of(lint("{:::"))
    fired |= codes_of(lint_workflow(Workflow("t", [
        _spec("a", inputs=("x",), outputs=("k1", "junk", "s:d")),
        _spec("island"),
        _spec("b", inputs=("k1", "r"), outputs=("r",), exec_time=-1.0,
              stream_inputs=("k1",)),
        FunctionSpec("c", inputs=("k1",), outputs=("q",)),
    ]), require_fns=True))
    fired |= codes_of(lint(_doc({
        "a": {"inputs": ["x"], "outputs": ["k1"],
              "output_sizes": {"nope": 1}},
        "b": {"inputs": ["x"], "outputs": ["k1"]},
        "count": {"foreach": 2, "inputs": ["zz.*"], "outputs": ["wc.$i"]},
        "count.1": {"inputs": ["x"], "outputs": ["o"]},
    })))
    fired |= codes_of(lint(_doc({
        "a": {"inputs": ["k2"], "outputs": ["k1"]},
        "b": {"inputs": ["k1"], "outputs": ["k2"]},
    })))
    assert len(fired) >= 10, sorted(fired)
    assert fired <= set(CODES)


# ----------------------------------------------------------------------
# check_workflow: the engine/serve pre-flight gate.
# ----------------------------------------------------------------------

def test_check_workflow_raises_on_errors():
    wf = Workflow("t", [
        _spec("a", inputs=("x",), outputs=("k1",)),
        _spec("b", inputs=("k1", "r"), outputs=("r",)),
    ])
    with pytest.raises(WorkflowLintError) as ei:
        check_workflow(wf)
    assert any(d.code == "DF003" for d in ei.value.diagnostics)
    check_workflow(clean_wf(), require_fns=True)     # clean: no raise


def test_engine_preflight_rejects_unbound_run():
    from repro.core.dscheduler import DFlowEngine

    wf = Workflow("t", [
        FunctionSpec("a", inputs=("x",), outputs=("k1",)),
    ])
    with pytest.raises(WorkflowLintError):
        DFlowEngine(n_nodes=1).run(wf, {"x": b"v"})
    # Opt-out for callers that manage binding themselves.
    eng = DFlowEngine(n_nodes=1, lint=False)
    assert eng.lint is False


def test_serve_preflight_rejects_bad_workflow():
    from repro.core.serve import DServe

    wf = Workflow("t", [
        _spec("a", inputs=("x",), outputs=("k:bad",)),
    ])
    with pytest.raises(WorkflowLintError):
        DServe(wf, n_nodes=1)


def test_max_severity():
    assert max_severity([]) is None
    assert max_severity(lint("{:::")) == "error"


# ----------------------------------------------------------------------
# Fuzz contract: every generated random DAG lints clean.
# ----------------------------------------------------------------------

def test_random_workflows_lint_clean():
    from strategies import lint_clean, random_workflow

    for seed in range(200):
        bad = lint_clean(random_workflow(seed))
        assert not bad, (seed, [d.format() for d in bad])


# ----------------------------------------------------------------------
# CLI (python -m repro.lint)
# ----------------------------------------------------------------------

CLEAN_YAML = """
name: wc
functions:
  split:
    inputs: [corpus]
    outputs: [shard.0, shard.1]
  count:
    foreach: 2
    inputs: [shard.$i]
    outputs: [wc.$i]
  merge:
    inputs: [wc.*]
    outputs: [result]
external_inputs:
  corpus: 2KB
"""

BROKEN_YAML = """
name: broken
functions:
  a:
    inputs: [x, r]
    outputs: [r]
"""


def test_cli_clean_file(tmp_path, capsys):
    p = tmp_path / "wc.yaml"
    p.write_text(CLEAN_YAML)
    assert lint_main([str(p)]) == 0
    assert "clean" in capsys.readouterr().out


def test_cli_broken_file_fails(tmp_path, capsys):
    p = tmp_path / "broken.yaml"
    p.write_text(BROKEN_YAML)
    assert lint_main([str(p)]) == 1
    assert "DF003" in capsys.readouterr().out


def test_cli_builtins_all_clean(capsys):
    assert lint_main(["--builtin", "all"]) == 0
    out = capsys.readouterr().out
    assert "builtin:WC" in out and "0 failed" in out


def test_cli_strict_fails_on_warning(tmp_path):
    p = tmp_path / "warn.yaml"
    # Mixed bound/unbound can't happen via YAML; use a partial external
    # declaration (DF014 warning) instead.
    p.write_text("""
name: warn
functions:
  a:
    inputs: [x, y]
    outputs: [r]
external_inputs:
  x: 1KB
""")
    assert lint_main([str(p)]) == 0
    assert lint_main([str(p), "--strict"]) == 1


def test_cli_json_format(tmp_path, capsys):
    p = tmp_path / "broken.yaml"
    p.write_text(BROKEN_YAML)
    assert lint_main([str(p), "--format", "json"]) == 1
    doc = json.loads(capsys.readouterr().out)
    assert doc[0]["target"] == str(p)
    assert any(d["code"] == "DF003" for d in doc[0]["diagnostics"])


def test_cli_list_codes(capsys):
    assert lint_main(["--list-codes"]) == 0
    out = capsys.readouterr().out
    for code in CODES:
        assert code in out
