"""Layer-level numerics: blockwise attention, SSD, MoE vs naive oracles."""

import dataclasses
import math

import jax
import jax.numpy as jnp
import pytest
from conftest import given, settings, st  # hypothesis or skip-shim

from repro.models.attention import (blockwise_attention, decode_attention,
                                    KVCache)
from repro.models.common import (apply_mrope, apply_rope, cross_entropy_loss,
                                 rms_norm, rope_table, squared_relu)
from repro.models.config import ModelConfig
from repro.models.moe import moe, moe_decls
from repro.models.param import init_params
from repro.models.ssm import ssd_chunked, ssd_decode_step


def naive_attention(q, k, v, causal):
    B, Sq, H, D = q.shape
    _, Skv, Hk, _ = k.shape
    G = H // Hk
    qr = q.reshape(B, Sq, Hk, G, D)
    s = jnp.einsum("bqhgd,bkhd->bqhgk", qr, k) / math.sqrt(D)
    if causal:
        mask = jnp.arange(Sq)[:, None] >= jnp.arange(Skv)[None, :]
        s = jnp.where(mask[None, :, None, None, :], s, -1e30)
    p = jax.nn.softmax(s.astype(jnp.float32), axis=-1)
    out = jnp.einsum("bqhgk,bkhd->bqhgd", p.astype(v.dtype), v)
    return out.reshape(B, Sq, H, D)


@pytest.mark.parametrize("causal", [True, False])
@pytest.mark.parametrize("qc,kc", [(4, 4), (8, 16), (16, 8), (32, 32)])
def test_blockwise_attention_matches_naive(causal, qc, kc):
    key = jax.random.key(0)
    B, S, H, Hk, D = 2, 32, 4, 2, 16
    q, k, v = (jax.random.normal(kk, shp, jnp.float32) for kk, shp in zip(
        jax.random.split(key, 3),
        [(B, S, H, D), (B, S, Hk, D), (B, S, Hk, D)]))
    got = blockwise_attention(q, k, v, causal=causal, q_chunk=qc, kv_chunk=kc)
    ref = naive_attention(q, k, v, causal)
    assert jnp.allclose(got, ref, atol=2e-5), float(jnp.abs(got - ref).max())


def test_decode_attention_matches_naive_masked():
    key = jax.random.key(1)
    B, H, Hk, D, L, used = 2, 4, 2, 16, 24, 17
    ks = jax.random.split(key, 3)
    q = jax.random.normal(ks[0], (B, 1, H, D), jnp.float32)
    kc = jax.random.normal(ks[1], (B, L, Hk, D), jnp.float32)
    vc = jax.random.normal(ks[2], (B, L, Hk, D), jnp.float32)
    cache = KVCache(kc, vc, jnp.array(used, jnp.int32))
    got = decode_attention(q, cache)
    ref = naive_attention(q, kc[:, :used], vc[:, :used], causal=False)
    assert jnp.allclose(got, ref, atol=2e-5)


@settings(max_examples=20, deadline=None)
@given(st.integers(0, 2**31 - 1))
def test_blockwise_attention_causality_property(seed):
    """Future KV must not influence past outputs (hypothesis fuzz)."""
    key = jax.random.key(seed)
    B, S, H, D = 1, 16, 2, 8
    ks = jax.random.split(key, 4)
    q = jax.random.normal(ks[0], (B, S, H, D), jnp.float32)
    k = jax.random.normal(ks[1], (B, S, H, D), jnp.float32)
    v = jax.random.normal(ks[2], (B, S, H, D), jnp.float32)
    out1 = blockwise_attention(q, k, v, causal=True, q_chunk=8, kv_chunk=8)
    # perturb the last key/value: outputs at positions < S-1 must not change
    k2 = k.at[:, -1].add(100.0)
    v2 = v.at[:, -1].add(-50.0)
    out2 = blockwise_attention(q, k2, v2, causal=True, q_chunk=8, kv_chunk=8)
    assert jnp.allclose(out1[:, :-1], out2[:, :-1], atol=1e-5)


def test_rope_preserves_norm_and_relative_phase():
    pos = jnp.arange(8)
    cos, sin = rope_table(pos, 16)
    x = jax.random.normal(jax.random.key(0), (1, 8, 2, 16), jnp.float32)
    y = apply_rope(x, cos, sin)
    assert jnp.allclose(jnp.linalg.norm(y, axis=-1),
                        jnp.linalg.norm(x, axis=-1), atol=1e-4)
    # position 0 is identity
    assert jnp.allclose(y[:, 0], x[:, 0], atol=1e-6)


def test_mrope_reduces_to_rope_when_positions_equal():
    B, S, H, D = 1, 6, 2, 16
    x = jax.random.normal(jax.random.key(0), (B, S, H, D), jnp.float32)
    p = jnp.broadcast_to(jnp.arange(S), (3, B, S))
    got = apply_mrope(x, p, D, theta=10000.0)
    cos, sin = rope_table(jnp.arange(S), D, 10000.0)
    ref = apply_rope(x, cos, sin)
    assert jnp.allclose(got, ref, atol=1e-5)


def test_rms_norm_scale_invariance():
    x = jax.random.normal(jax.random.key(0), (4, 8), jnp.float32)
    s = jnp.ones(8)
    assert jnp.allclose(rms_norm(3.0 * x, s), rms_norm(x, s), atol=1e-5)


def test_squared_relu():
    x = jnp.array([-2.0, 0.0, 3.0])
    assert jnp.allclose(squared_relu(x), jnp.array([0.0, 0.0, 9.0]))


def test_cross_entropy_matches_manual():
    logits = jax.random.normal(jax.random.key(0), (2, 4, 7), jnp.float32)
    labels = jax.random.randint(jax.random.key(1), (2, 4), 0, 7)
    got = cross_entropy_loss(logits, labels)
    p = jax.nn.log_softmax(logits, -1)
    ref = -jnp.take_along_axis(p, labels[..., None], -1).mean()
    assert jnp.allclose(got, ref, atol=1e-6)


def _moe_cfg(**kw):
    base = dict(name="t", family="moe", n_layers=2, d_model=32, n_heads=4,
                n_kv_heads=2, d_ff=64, vocab=128, n_experts=8, top_k=2,
                capacity_factor=4.0)
    base.update(kw)
    return ModelConfig(**base)


def test_moe_matches_dense_reference():
    cfg = _moe_cfg()
    params = init_params(moe_decls(cfg), jax.random.key(0))
    x = jax.random.normal(jax.random.key(1), (2, 16, 32), jnp.float32)
    y, aux = moe(params, x, cfg)

    t = x.reshape(-1, 32).astype(jnp.float32)
    probs = jax.nn.softmax(t @ params["router"], -1)
    topv, topi = jax.lax.top_k(probs, cfg.top_k)
    gates = topv / topv.sum(-1, keepdims=True)
    per_expert = []
    for e in range(cfg.n_experts):
        g = t @ params["w_gate"][e].astype(jnp.float32)
        u = t @ params["w_up"][e].astype(jnp.float32)
        per_expert.append(((g * jax.nn.sigmoid(g)) * u)
                          @ params["w_down"][e].astype(jnp.float32))
    stacked = jnp.stack(per_expert, 1)
    ref = jnp.zeros_like(t)
    for kk in range(cfg.top_k):
        sel = jnp.take_along_axis(
            stacked, topi[:, kk, None, None].repeat(32, -1), 1)[:, 0]
        ref = ref + gates[:, kk, None] * sel
    ref = ref.reshape(2, 16, 32)
    assert float(jnp.abs(y - ref).max() / jnp.abs(ref).max()) < 1e-4
    assert 0.5 < float(aux) < 4.0       # balanced-ish router at init


def test_moe_capacity_drops_tokens():
    """With capacity_factor << 1 most tokens are dropped → output shrinks."""
    cfg_full = _moe_cfg(capacity_factor=8.0)
    cfg_tight = _moe_cfg(capacity_factor=0.05)
    params = init_params(moe_decls(cfg_full), jax.random.key(0))
    x = jax.random.normal(jax.random.key(1), (2, 32, 32), jnp.float32)
    y_full, _ = moe(params, x, cfg_full)
    y_tight, _ = moe(params, x, cfg_tight)
    assert float(jnp.abs(y_tight).mean()) < float(jnp.abs(y_full).mean())


def test_ssd_chunked_matches_sequential():
    key = jax.random.key(0)
    B, S, H, P, N = 2, 32, 4, 8, 16
    ks = jax.random.split(key, 5)
    x = jax.random.normal(ks[0], (B, S, H, P))
    dt = jax.nn.softplus(jax.random.normal(ks[1], (B, S, H)))
    A = -jnp.exp(jax.random.normal(ks[2], (H,)))
    Bm = jax.random.normal(ks[3], (B, S, N))
    Cm = jax.random.normal(ks[4], (B, S, N))

    state = jnp.zeros((B, H, P, N))
    ys = []
    for t in range(S):
        yt, state = ssd_decode_step(x[:, t], dt[:, t], A, Bm[:, t], Cm[:, t],
                                    state)
        ys.append(yt)
    y_ref = jnp.stack(ys, 1)
    for chunk in (4, 8, 32):
        y, st = ssd_chunked(x, dt, A, Bm, Cm, chunk)
        assert float(jnp.abs(y - y_ref).max() / jnp.abs(y_ref).max()) < 1e-4
        assert float(jnp.abs(st - state).max() / jnp.abs(state).max()) < 1e-4


def test_ssd_state_decay_bounds():
    """With very negative A the state forgets; with A≈0 it accumulates."""
    B, S, H, P, N = 1, 16, 1, 4, 4
    x = jnp.ones((B, S, H, P))
    dt = jnp.ones((B, S, H))
    Bm = jnp.ones((B, S, N))
    Cm = jnp.ones((B, S, N))
    _, st_forget = ssd_chunked(x, dt, jnp.array([-20.0]), Bm, Cm, 8)
    _, st_keep = ssd_chunked(x, dt, jnp.array([-1e-4]), Bm, Cm, 8)
    assert float(jnp.abs(st_forget).max()) < 1.5      # only last token
    assert float(jnp.abs(st_keep).max()) > 10.0       # ~S accumulated
