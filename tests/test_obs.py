"""DScope observability: registry, span trees, exporters, attribution.

Layers under test (``repro.core.obs``):

* :class:`MetricsRegistry` — exact under concurrent increment, pull
  collectors, label typing.
* :class:`Tracer` — well-formed per-request span trees from real DServe
  runs (threaded, sharded) and simulator runs (virtual clock); JSONL and
  Chrome ``trace_event`` exporters round-trip.
* :func:`attribute` — hand-built spans against a hand-built plan doc
  give exactly the drifts we constructed.
* The registry dump reproduces ``ServeReport.row()`` — one source of
  truth for every counter the serving layer reports.
* The fuzzed differential corpus stays byte-exact with full
  observability attached (quick stride here; 200-seed sweep is `slow`).
"""

import json
import math
import threading
import time

import pytest
from strategies import external_inputs, oracle_run, random_workflow

from repro.core.dscheduler import DFlowEngine
from repro.core.dstore import DStore
from repro.core.obs import (MetricsRegistry, Span, Tracer, attribute,
                            bench_doc, bench_metric, compare_docs,
                            plan_attribution, read_spans_jsonl,
                            to_chrome_trace, write_spans_jsonl)
from repro.core.serve import DServe, poisson_arrivals
from repro.core.workloads import serving_chain

N_SEEDS = 200


# ----------------------------------------------------------------------
# MetricsRegistry
# ----------------------------------------------------------------------

def test_registry_basics():
    reg = MetricsRegistry()
    reg.counter("hits", node="n0").inc()
    reg.counter("hits", node="n0").inc(2)
    reg.counter("hits", node="n1").inc()
    assert reg.counter("hits", node="n0").value == 3
    assert reg.total("hits") == 4
    assert reg.label_values("hits", "node") == {"n0": 3.0, "n1": 1.0}
    reg.gauge("depth").set(7)
    reg.gauge("depth").add(-2)
    assert reg.gauge("depth").value == 5
    h = reg.histogram("lat")
    for v in (0.1, 0.2, 0.3, 0.4):
        h.observe(v)
    s = h.summary()
    assert s["count"] == 4 and math.isclose(s["sum"], 1.0)
    assert s["min"] == 0.1 and s["max"] == 0.4
    # Exact (interpolated) percentiles while the reservoir is complete.
    assert math.isclose(h.percentile(50.0), 0.25, rel_tol=1e-9)
    assert math.isclose(h.percentile(100.0), 0.4, rel_tol=1e-9)


def test_registry_type_conflict():
    reg = MetricsRegistry()
    reg.counter("x", node="n0")
    with pytest.raises(ValueError):
        reg.gauge("x", node="n0")
    with pytest.raises(ValueError):
        reg.histogram("x", node="n1")


def test_registry_concurrent_exact():
    """8 threads x 1000 increments + observations: exact totals, no lost
    updates (the counters sit on every hot path)."""
    reg = MetricsRegistry()
    n_threads, per = 8, 1000

    def worker(i):
        c = reg.counter("ops", worker=str(i % 2))
        h = reg.histogram("lat")
        for _ in range(per):
            c.inc()
            h.observe(0.001)

    ts = [threading.Thread(target=worker, args=(i,))
          for i in range(n_threads)]
    for t in ts:
        t.start()
    for t in ts:
        t.join()
    assert reg.total("ops") == n_threads * per
    assert reg.histogram("lat").count == n_threads * per


def test_registry_collector():
    """Pull collectors run at collect() and land in the same dump."""
    reg = MetricsRegistry()
    src = {"v": 0}
    reg.register_collector(
        lambda: reg.gauge("pulled", node="n0").set(src["v"]))
    src["v"] = 42
    dump = reg.collect()
    assert dump["gauges"]["pulled{node=n0}"] == 42.0
    src["v"] = 43
    assert reg.collect()["gauges"]["pulled{node=n0}"] == 43.0


# ----------------------------------------------------------------------
# Tracer: span trees from real runs
# ----------------------------------------------------------------------

def _serve_traced(*, sharded=False, n=6, nodes=2):
    wf = serving_chain(stages=3, exec_time=0.01, cold_start=0.05,
                       payload=8192)
    spans, reg = Tracer(), MetricsRegistry()
    srv = DServe(wf, n_nodes=nodes, pattern="dataflow", keepalive=5.0,
                 metrics=reg, spans=spans, plan=True, sharded=sharded)
    rep = srv.run(poisson_arrivals(20.0, n, seed=3),
                  inputs={"request": b"req"})
    assert rep.failures == 0
    return rep, srv, spans.finished(), reg


def check_well_formed(spans):
    """Every span ended; every parent exists, shares the trace, and
    (for non-evict spans) contains the child's interval."""
    by_id = {s.id: s for s in spans}
    assert len(by_id) == len(spans), "duplicate span ids"
    for s in spans:
        assert not math.isnan(s.end), (s.kind, s.name)
        assert s.end >= s.start or s.kind == "evict"
        if s.parent is not None:
            p = by_id[s.parent]
            assert p.trace == s.trace
            assert p.start - 1e-6 <= s.start and s.end <= p.end + 1e-6, (
                s.kind, s.name, p.kind, p.name)
            assert p.seq < s.seq, "parent must start before child"


def test_serve_span_tree_well_formed():
    rep, srv, spans, _ = _serve_traced()
    check_well_formed(spans)
    reqs = [s for s in spans if s.kind == "request"]
    assert len(reqs) == 6
    # Per-instance isolation: all spans of a trace belong to it, and
    # every instance got its own trace.
    assert len({r.trace for r in reqs}) == 6
    by_trace = {}
    for s in spans:
        by_trace.setdefault(s.trace, []).append(s)
    for trace, ss in by_trace.items():
        for s in ss:
            if s.kind in ("get", "put", "chunk", "chunk_put", "evict"):
                assert s.name.startswith(trace + ":"), (trace, s.name)
    # Gets/acquires nest under invokes, invokes under the request.
    by_id = {s.id: s for s in spans}
    for s in spans:
        if s.kind in ("get", "acquire"):
            parent = by_id.get(s.parent)
            if parent is not None and s.kind == "acquire":
                assert parent.kind == "invoke"
        if s.kind == "invoke":
            assert by_id[s.parent].kind == "request"
    # Request durations match the report's latencies (separate clock
    # reads of the same interval, so a few ms of slack).
    lat = sorted(r.duration for r in reqs)
    assert all(math.isclose(a, b, abs_tol=5e-3)
               for a, b in zip(lat, sorted(rep.latencies)))


def test_sharded_hop_spans_nested_under_gets():
    _, srv, spans, reg = _serve_traced(sharded=True, nodes=3)
    check_well_formed(spans)
    by_id = {s.id: s for s in spans}
    hops = [s for s in spans if s.kind == "hop"]
    assert hops, "cross-shard pulls should emit hop spans"
    for h in hops:
        assert by_id[h.parent].kind in ("get", "chunk")
        assert h.attrs["tier"] in ("ipc", "mem", "net")
    # The registry's routed-get count covers at least the hop spans.
    reg.collect()
    routed = sum(v for k, v in
                 reg.label_values("routing_gets", "hops").items()
                 if int(k) >= 1)
    assert routed >= len(hops)


def test_zero_cost_when_detached():
    """No hooks attached: the store carries None hooks and works."""
    store = DStore(["node0"])
    assert store._spans is None and store._metrics is None
    store.put("node0", "k", b"v")
    assert bytes(store.get("node0", "k")) == b"v"


# ----------------------------------------------------------------------
# Exporters
# ----------------------------------------------------------------------

def test_jsonl_roundtrip(tmp_path):
    _, srv, spans, _ = _serve_traced(n=3)
    path = tmp_path / "spans.jsonl"
    plan_doc = plan_attribution(srv.plan)
    write_spans_jsonl(spans, str(path), plan=plan_doc,
                      meta={"bench": "test"})
    back, meta = read_spans_jsonl(str(path))
    assert meta["bench"] == "test"
    assert meta["plan"]["workflow"] == plan_doc["workflow"]
    assert len(back) == len(spans)
    for a, b in zip(sorted(spans, key=lambda s: s.seq),
                    sorted(back, key=lambda s: s.seq)):
        assert (a.id, a.parent, a.trace, a.name, a.kind) == \
               (b.id, b.parent, b.trace, b.name, b.kind)
        assert math.isclose(a.start, b.start) and math.isclose(a.end, b.end)
        assert a.attrs == b.attrs


def test_chrome_trace_shape():
    _, _, spans, _ = _serve_traced(n=3)
    doc = to_chrome_trace(spans)
    evs = doc["traceEvents"]
    assert evs
    complete = [e for e in evs if e["ph"] == "X"]
    instants = [e for e in evs if e["ph"] == "i"]
    metadata = [e for e in evs if e["ph"] == "M"]
    assert len(complete) + len(instants) + len(metadata) == len(evs)
    assert metadata, "process/thread name metadata expected"
    t0 = min(e["ts"] for e in complete)
    assert t0 >= 0, "timestamps must be t0-relative microseconds"
    for e in complete:
        assert e["dur"] >= 0
        assert e["pid"] and "tid" in e
    # One lane (tid) per function invocation within a request's pid.
    pids = {e["pid"] for e in complete}
    assert len(pids) == 3, "one pid per request trace"


# ----------------------------------------------------------------------
# Plan-vs-actual attribution (hand-built ground truth)
# ----------------------------------------------------------------------

def _mk(id_, parent, trace, name, kind, start, end, seq, **attrs):
    return Span(id=id_, parent=parent, trace=trace, name=name, kind=kind,
                start=start, seq=seq, end=end, end_seq=seq + 100,
                attrs=attrs)


def test_attribution_hand_built():
    """A request whose stage starts 50 ms later than planned, with a
    30 ms cold acquire, must show exactly those drifts."""
    plan_doc = {
        "workflow": "W", "critical_path": 0.200,
        "functions": {
            "a": {"est": 0.0, "eft": 0.100, "slack": 0.0,
                  "boot_at": -0.150, "cold_start": 0.15},
            "b": {"est": 0.100, "eft": 0.200, "slack": 0.0,
                  "boot_at": 0.050, "cold_start": 0.05},
        },
    }
    t = 1000.0  # arbitrary wall origin
    spans = [
        _mk(1, None, "W#0", "W#0", "request", t, t + 0.300, 1, ok=True),
        _mk(2, 1, "W#0", "a", "invoke", t + 0.000, t + 0.130, 2),
        _mk(3, 2, "W#0", "a", "acquire", t + 0.000, t + 0.030, 3,
            cold=True),
        _mk(4, 1, "W#0", "b", "invoke", t + 0.150, t + 0.300, 4),
        _mk(5, 4, "W#0", "b", "acquire", t + 0.150, t + 0.150, 5,
            cold=False),
        _mk(6, 4, "W#0", "W#0:k", "get", t + 0.150, t + 0.160, 6),
        _mk(7, None, "W#0", "W#0:k", "evict", t + 0.170, t + 0.170, 7),
    ]
    rep = attribute(spans, plan_doc)
    assert rep["requests"] == 1
    assert math.isclose(rep["latency"]["mean"], 0.300)
    assert math.isclose(rep["cp_drift"]["mean"], 0.100)
    rows = {r["function"]: r for r in rep["functions"]}
    assert math.isclose(rows["a"]["start_drift"]["mean"], 0.0,
                        abs_tol=1e-12)
    assert math.isclose(rows["a"]["finish_drift"]["mean"], 0.030)
    assert math.isclose(rows["a"]["acquire_wait"]["mean"], 0.030)
    assert rows["a"]["cold_rate"] == 1.0
    # b launched 50 ms late; prewarm fired 100 ms ahead of actual start.
    assert math.isclose(rows["b"]["start_drift"]["mean"], 0.050)
    assert math.isclose(rows["b"]["prewarm_lead"]["mean"], 0.100)
    assert rows["b"]["cold_rate"] == 0.0
    # Evict 10 ms after the key's last Get returned.
    assert rep["eviction_lag"]["n"] == 1
    assert math.isclose(rep["eviction_lag"]["mean"], 0.010)


def test_attribution_real_run_sane():
    rep, srv, spans, _ = _serve_traced()
    out = attribute(spans, plan_attribution(srv.plan))
    assert out["requests"] == 6
    assert {r["function"] for r in out["functions"]} == \
           set(srv.plan.functions)
    # Latency agg must reproduce the report's mean (separate clock
    # reads of the same interval, so a few ms of slack).
    assert math.isclose(out["latency"]["mean"],
                        sum(rep.latencies) / len(rep.latencies),
                        abs_tol=5e-3)


# ----------------------------------------------------------------------
# Registry dump == ServeReport (one source of truth)
# ----------------------------------------------------------------------

def test_registry_reproduces_serve_report():
    rep, srv, _, reg = _serve_traced()
    reg.collect()
    row = rep.row()
    assert int(reg.total("container_cold_starts")) >= row["cold_starts"]
    # The report counts the run's *delta*; this registry was created for
    # the run, so totals and deltas coincide.
    assert int(reg.total("container_cold_starts")) == row["cold_starts"]
    assert int(reg.total("container_prewarm_boots")) == row["prewarm_boots"]
    assert int(reg.total("container_warm_hits")) == row["warm_hits"]
    assert int(reg.total("container_prewarm_hits")) == row["prewarm_hits"]
    peaks = reg.label_values("dstore_peak_resident_bytes", "node")
    assert int(max(peaks.values())) == row["peak_resident_bytes"]
    assert rep.peak_resident_per_node == {
        n: int(v) for n, v in peaks.items()}
    # Serving aggregates published back into the registry.
    assert int(reg.total("serve_requests_total")) == row["n"]
    assert reg.histogram("serve_latency_seconds",
                         workflow=row["workflow"],
                         pattern=row["pattern"]).count == row["n"]


# ----------------------------------------------------------------------
# Simulator spans (virtual clock)
# ----------------------------------------------------------------------

def test_sim_spans_virtual_clock():
    from repro.core import make_workflow, run_open_loop

    tr = Tracer()
    res = run_open_loop("dflow", make_workflow("WC"), rate_per_min=20,
                        n_invocations=4, spans=tr)
    spans = tr.finished()
    check_well_formed(spans)
    reqs = sorted((s for s in spans if s.kind == "request"),
                  key=lambda s: s.seq)
    assert len(reqs) == 4
    # Durations are virtual seconds == the collected latencies.
    for s, lat in zip(reqs, res.latencies):
        assert math.isclose(s.duration, lat, rel_tol=1e-9), (s, lat)
    kinds = {s.kind for s in spans}
    assert {"request", "invoke", "acquire"} <= kinds


# ----------------------------------------------------------------------
# dflow-bench/v1 schema + regression gate
# ----------------------------------------------------------------------

def test_bench_metric_validation():
    with pytest.raises(ValueError):
        bench_metric("s", "m", 1.0, direction="sideways")
    row = bench_metric("s", "m", 1.0, "x", direction="lower",
                       tolerance=0.05)
    assert row["tolerance"] == 0.05
    doc = bench_doc("b", {"n": 1}, [row], extra={"k": 2})
    assert doc["schema"] == "dflow-bench/v1"
    assert doc["extra"] == {"k": 2}
    json.dumps(doc)  # must be JSON-serialisable


def test_compare_docs_gating():
    old = bench_doc("b", {}, [
        bench_metric("s", "p99", 1.0, "s", direction="lower"),
        bench_metric("s", "hits", 0.9, "", direction="higher"),
        bench_metric("s", "noise", 5.0, "s"),  # report-only
        bench_metric("s", "zero", 0, "", direction="lower"),
    ])
    # Within tolerance: pass.
    new = bench_doc("b", {}, [
        bench_metric("s", "p99", 1.09), bench_metric("s", "hits", 0.85),
        bench_metric("s", "noise", 50.0), bench_metric("s", "zero", 0),
    ])
    rows, failures = compare_docs(old, new)
    assert not failures
    assert [r["gated"] for r in rows] == [True, True, False, True]
    # Beyond tolerance in the bad direction: fail (both directions);
    # report-only metrics never gate; zero-valued gates fail on ANY rise.
    worse = bench_doc("b", {}, [
        bench_metric("s", "p99", 1.11), bench_metric("s", "hits", 0.80),
        bench_metric("s", "noise", 500.0), bench_metric("s", "zero", 1),
    ])
    rows, failures = compare_docs(old, worse)
    assert len(failures) == 3
    assert sum(r["regressed"] for r in rows) == 3
    # A committed metric missing from the fresh run is a failure.
    rows, failures = compare_docs(old, bench_doc("b", {}, []))
    assert len(failures) == 4


# ----------------------------------------------------------------------
# Differential corpus with observability attached
# ----------------------------------------------------------------------

def check_obs_enabled_differential(seed):
    """Full DScope instrumentation must never change engine results:
    byte-exact vs the oracle, and the recorded span tree is well-formed
    with every function's invoke span present exactly once."""
    oracle_wf = random_workflow(seed)
    ext = external_inputs(oracle_wf)
    expected = oracle_run(oracle_wf, ext)

    wf = random_workflow(seed)
    tr, reg = Tracer(), MetricsRegistry()
    engine = DFlowEngine(n_nodes=2, get_timeout=30.0, spans=tr)
    store = DStore(engine.nodes, engine.transport)
    store.attach_metrics(reg)
    rep = engine.start(wf, ext, store=store).wait()
    got = {k: bytes(v) for k, v in rep.outputs.items()}
    assert got == expected, f"seed {seed}"
    # wait() unblocks at the last mark_done; the executing thread's
    # invoke-span end (its finally block) can land a beat later.  Poll
    # until the snapshot is parent-complete.
    spans = tr.finished()
    for _ in range(500):
        ids = {s.id for s in spans}
        if all(s.parent is None or s.parent in ids for s in spans):
            break
        time.sleep(0.002)
        spans = tr.finished()
    check_well_formed(spans)
    invokes = [s.name for s in spans if s.kind == "invoke"
               and not s.attrs.get("duplicate")]
    assert sorted(invokes) == sorted(wf.functions), seed
    assert reg.histogram("dstore_get_seconds").count > 0


@pytest.mark.parametrize("seed", range(0, N_SEEDS, 16))
def test_obs_differential_quick(seed):
    check_obs_enabled_differential(seed)


@pytest.mark.slow
@pytest.mark.parametrize("seed", range(N_SEEDS))
def test_obs_differential_200(seed):
    check_obs_enabled_differential(seed)
