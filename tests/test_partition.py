"""GS partitioner tests incl. hypothesis property tests on random DAGs."""

from conftest import given, settings, st  # hypothesis or skip-shim

from repro.core.dag import FunctionSpec, Workflow
from repro.core.partition import cut_bytes, partition_workflow
from repro.core.workloads import BENCHMARKS


def test_chain_collocates():
    """A pure chain should land entirely on one node (zero cut)."""
    fns = []
    prev = "x"
    for i in range(5):
        out = f"k{i}"
        fns.append(FunctionSpec(f"f{i}", inputs=(prev,), outputs=(out,),
                                exec_time=0.1,
                                output_sizes={out: 10 << 20}))
        prev = out
    wf = Workflow("chain", fns)
    pl = partition_workflow(wf, ["n1", "n2", "n3"])
    assert cut_bytes(wf, pl) == 0.0


def test_balance_cap_respected():
    """Load on any node should not exceed slack * total / n."""
    fns = [FunctionSpec(f"f{i}", inputs=(), outputs=(f"o{i}",),
                        exec_time=1.0) for i in range(12)]
    wf = Workflow("wide", fns)
    nodes = ["a", "b", "c"]
    pl = partition_workflow(wf, nodes, balance_slack=1.35)
    load = {n: 0.0 for n in nodes}
    for f, n in pl.items():
        load[n] += wf.functions[f].exec_time
    cap = 1.35 * 12 / 3
    assert all(v <= cap + 1e-9 for v in load.values())


def _random_layered_dag(draw):
    n_layers = draw(st.integers(2, 4))
    width = draw(st.integers(1, 4))
    fns = []
    prev_keys: list[str] = []
    for layer in range(n_layers):
        keys = []
        for j in range(width):
            name = f"f{layer}_{j}"
            out = f"k{layer}_{j}"
            if layer == 0:
                ins = ("src",)
            else:
                picks = draw(st.lists(
                    st.sampled_from(prev_keys), min_size=1,
                    max_size=min(3, len(prev_keys)), unique=True))
                ins = tuple(picks)
            sz = draw(st.integers(1, 32)) << 20
            fns.append(FunctionSpec(name, inputs=ins, outputs=(out,),
                                    exec_time=draw(st.floats(0.01, 2.0)),
                                    output_sizes={out: sz}))
            keys.append(out)
        prev_keys = keys
    return Workflow("rand", fns)


@settings(max_examples=40, deadline=None)
@given(st.data())
def test_partition_properties_random_dags(data):
    wf = _random_layered_dag(data.draw)
    nodes = [f"n{i}" for i in range(data.draw(st.integers(1, 5)))]
    pl = partition_workflow(wf, nodes)
    # Every function placed, onto a known node.
    assert set(pl) == set(wf.functions)
    assert set(pl.values()) <= set(nodes)
    # Refinement never does worse than all-on-one-node for a single node.
    if len(nodes) == 1:
        assert cut_bytes(wf, pl) == 0.0


def test_benchmarks_cut_below_total():
    nodes = [f"node{i+1}" for i in range(7)]
    for name, gen in BENCHMARKS.items():
        wf = gen()
        pl = partition_workflow(wf, nodes)
        total = sum(wf.functions[p].size_of(k)
                    for f in wf.functions.values() for k in f.inputs
                    for p in [wf.producer.get(k)] if p and p != f.name)
        assert cut_bytes(wf, pl) < total, name
