"""DPlan: static planner properties + trace cross-validation.

Three layers of teeth:

* **Analytical properties** — plan critical path is bit-for-bit equal to
  ``Workflow.critical_path_time()`` and ``cross_node_bytes`` equals the
  partitioner's ``cut_bytes`` on every fuzz seed (the shared
  ``Workflow.key_bytes`` sizing helper makes disagreement impossible by
  construction; these tests keep it that way).
* **Trace conformance** — real plan-driven engine runs are recorded and
  replayed through :class:`PlanConformance`: the observed Gets of every
  planned key must match the statically-claimed read count exactly, the
  last observed read must precede the eviction, and outputs stay
  byte-identical to the sequential oracle (eviction never destroyed
  data anyone still needed).  Runs over the same 200-seed corpus as the
  differential suite (fast subset unmarked, full sweep ``slow``).
* **Precision** — hand-built traces that contradict a plan must be
  flagged (read-after-evict, undercounted reads, avoidable cold boot),
  and the DF016/DF017 stream-feasibility diagnostics must fire on the
  degenerate shapes and stay silent on healthy ones.
"""

import json

import pytest
from conftest import given, settings, st                      # noqa: F401
from strategies import external_inputs, oracle_run, random_workflow

from repro.core.check import PlanConformance, TraceEvent, TraceRecorder
from repro.core.dag import FunctionSpec, Workflow
from repro.core.dscheduler import DFlowEngine
from repro.core.dstore import DStore
from repro.core.partition import cut_bytes, partition_workflow
from repro.core.plan import build_plan
from repro.core.workloads import BENCHMARKS, serving_chain

N_SEEDS = 200


# ----------------------------------------------------------------------
# Analytical properties over the fuzz corpus
# ----------------------------------------------------------------------

def check_plan_static(seed):
    wf = random_workflow(seed)
    nodes = ["node0", "node1"]
    placement = partition_workflow(wf, nodes)
    plan = build_plan(wf, placement)
    assert not plan.self_check(), plan.self_check()
    # (b) critical path: exactly the Workflow DP, not approximately.
    assert plan.critical_path == wf.critical_path_time()
    # Transfer matrix and cut model agree (shared key_bytes helper).
    assert plan.cross_node_bytes == cut_bytes(wf, placement)
    # Slack/prewarm sanity: nonneg slack, critical path nonempty, boot_at
    # is est minus cold_start clamped at zero.
    crit = [f for f in plan.functions.values() if f.critical]
    assert crit, "every DAG has a critical path"
    for fp in plan.functions.values():
        assert fp.slack >= 0.0
        assert fp.eft == fp.est + wf.functions[fp.function].exec_time
        assert fp.boot_at == max(0.0, fp.est - fp.cold_start)
    boots = [b for _, b, _ in plan.prewarm_schedule]
    assert boots == sorted(boots)
    # Liveness: evictable keys are consumed, non-streamed, non-sink, and
    # their read count is the number of distinct consumers.
    for k, kp in plan.keys.items():
        if kp.sink:
            assert not kp.consumers
        if k in plan.eviction_reads:
            assert plan.eviction_reads[k] == len(kp.consumers) > 0
    # The placement-agnostic plan agrees on everything non-placement.
    logical = build_plan(wf)
    assert logical.critical_path == plan.critical_path
    assert logical.eviction_reads == plan.eviction_reads


@pytest.mark.parametrize("seed", range(0, N_SEEDS, 8))
def test_plan_static_fuzzed(seed):
    check_plan_static(seed)


@pytest.mark.slow
@pytest.mark.parametrize("seed", range(N_SEEDS))
def test_plan_static_200(seed):
    check_plan_static(seed)


@settings(max_examples=25, deadline=None)
@given(seed=st.integers(min_value=0, max_value=2**32 - 1))
def test_plan_static_hypothesis(seed):
    check_plan_static(seed)


def test_plan_builtins_clean():
    for name, mk in BENCHMARKS.items():
        wf = mk()
        placement = partition_workflow(wf, ["n0", "n1", "n2"])
        plan = build_plan(wf, placement)
        assert not plan.self_check(), name
        assert plan.critical_path == wf.critical_path_time(), name
        assert plan.cross_node_bytes == cut_bytes(wf, placement), name
        json.dumps(plan.to_doc())         # serializable end to end


def test_key_bytes_is_the_single_sizing_authority():
    wf = serving_chain(stages=3, payload=4096)
    for f in wf.functions.values():
        for k in f.outputs:
            assert wf.key_bytes(k) == f.size_of(k)
    assert wf.key_bytes("request") == wf.external_inputs["request"]
    # Stream-declared keys contribute their full byte count (chunking
    # changes granularity, not volume): matrix cell == key size.
    plan = build_plan(wf)
    for t in plan.transfers:
        assert t.bytes == wf.key_bytes(t.key)
        assert t.chunks * t.chunk_bytes >= t.bytes


# ----------------------------------------------------------------------
# Plan-driven engine runs, cross-validated against the recorded trace
# ----------------------------------------------------------------------

def check_plan_run_conforms(seed, *, stream_prob=0.15):
    oracle_wf = random_workflow(seed, stream_prob=stream_prob)
    ext = external_inputs(oracle_wf)
    expected = oracle_run(oracle_wf, ext)

    calls: dict[str, int] = {}
    wf = random_workflow(seed, stream_prob=stream_prob, calls=calls)
    engine = DFlowEngine(n_nodes=2, pattern="dataflow", get_timeout=30.0)
    placement = engine.gs.assign(wf)
    plan = build_plan(wf, placement)
    store = DStore(engine.nodes, engine.transport)
    rec = TraceRecorder()
    store.attach_tracer(rec)
    rep = engine.start(wf, ext, store=store, placement=placement,
                       plan=plan).wait()
    # (1) eviction never destroyed bytes anyone needed: byte-exact vs
    # the sequential oracle, every function exactly once.
    assert {k: bytes(v) for k, v in rep.outputs.items()} == expected, seed
    assert calls == {f: 1 for f in wf.functions}, (seed, calls)
    # (2) the trace conforms to the plan's static claims.
    events = rec.events()
    PlanConformance(plan).check_or_raise(events)
    # (3) refinement, key by key: exactly the planned number of reads was
    # observed, the last read precedes the eviction, and every planned
    # key actually was evicted (earliest-eviction, not never-eviction).
    last_read: dict[str, int] = {}
    reads: dict[str, int] = {}
    evict_clock: dict[str, int] = {}
    for ev in events:
        if ev.kind == "get_return":
            reads[ev.key] = reads.get(ev.key, 0) + 1
            last_read[ev.key] = ev.clock
        elif ev.kind == "evict":
            evict_clock.setdefault(ev.key, ev.clock)
    for k, n in plan.eviction_reads.items():
        assert reads.get(k, 0) == n, (seed, k)
        assert k in evict_clock, (seed, k)
        assert last_read[k] < evict_clock[k], (seed, k)
    # (4) post-run store state: planned keys reclaimed, sinks intact.
    left = set(store.directory.keys())
    assert not (left & set(plan.eviction_reads)), (seed, left)
    for k, kp in plan.keys.items():
        if kp.sink and not kp.streamed:
            assert k in left, (seed, k)


@pytest.mark.parametrize("seed", range(0, N_SEEDS, 8))
def test_plan_run_conforms_fuzzed(seed):
    check_plan_run_conforms(seed)


@pytest.mark.slow
@pytest.mark.parametrize("seed", range(N_SEEDS))
def test_plan_run_conforms_200(seed):
    check_plan_run_conforms(seed)


@settings(max_examples=15, deadline=None)
@given(seed=st.integers(min_value=0, max_value=2**32 - 1))
def test_plan_run_conforms_hypothesis(seed):
    check_plan_run_conforms(seed)


def test_plan_rejects_straggler_and_failure_modes():
    wf = random_workflow(3)
    plan = build_plan(wf)
    engine = DFlowEngine(n_nodes=2, straggler_factor=50.0)
    with pytest.raises(ValueError, match="plan-driven"):
        engine.start(wf, external_inputs(wf), plan=plan)
    engine2 = DFlowEngine(n_nodes=2)
    with pytest.raises(ValueError, match="plan-driven"):
        engine2.start(wf, external_inputs(wf), plan=plan,
                      inject_failure="node0")


# ----------------------------------------------------------------------
# Plan-driven serving: bounded resident bytes + prewarm conformance
# ----------------------------------------------------------------------

def _serve(plan, tracer=None, n=6):
    from repro.core.serve import DServe

    wf = serving_chain(stages=4, exec_time=0.02, cold_start=0.08,
                       payload=16 * 1024)
    srv = DServe(wf, n_nodes=2, pattern="dataflow", keepalive=10.0,
                 max_per_node=16, plan=plan, tracer=tracer)
    arrivals = [i * 0.05 for i in range(n)]
    rep = srv.run(arrivals, inputs={"request": b"req"})
    assert rep.failures == 0, [s.error for s in rep.stats if not s.ok]
    return rep, srv


def test_serve_plan_bounds_resident_bytes():
    heur, _ = _serve(plan=False)
    planned, _ = _serve(plan=True)
    assert planned.peak_resident_bytes < heur.peak_resident_bytes, (
        planned.peak_resident_bytes, heur.peak_resident_bytes)
    for s in planned.stats:
        assert s.outputs, "plan-driven instances must still produce sinks"


def test_serve_plan_trace_conforms():
    rec = TraceRecorder()
    rep, srv = _serve(plan=True, tracer=rec, n=4)
    PlanConformance(srv.plan).check_or_raise(
        rec.events(), instances=[s.instance for s in rep.stats])
    kinds = {e.kind for e in rec.events()}
    # The container lifecycle actually landed in the trace.
    assert kinds & {"prewarm_boot", "warm_hit", "prewarm_hit",
                    "container_release"}, kinds


# ----------------------------------------------------------------------
# Conformance precision: contradicting traces must be flagged
# ----------------------------------------------------------------------

class _PlanStub:
    def __init__(self, reads):
        self.eviction_reads = reads


def _ev(clock, kind, key="", node="", **kw):
    return TraceEvent(clock, kind, key, node, **kw)


def test_conformance_flags_read_after_evict():
    trace = [
        _ev(1, "put", "k", "node0"),
        _ev(2, "get_return", "k", "node0"),
        _ev(3, "evict", "k"),
        _ev(4, "get_return", "k", "node1"),     # liveness undercounted
    ]
    out = PlanConformance(_PlanStub({"k": 1})).check(trace)
    assert any(v.invariant == "plan_eviction"
               and "after its planned eviction" in v.message for v in out)


def test_conformance_flags_undercounted_reads():
    trace = [
        _ev(1, "put", "k", "node0"),
        _ev(2, "get_return", "k", "node0"),
        _ev(3, "get_return", "k", "node1"),
    ]
    out = PlanConformance(_PlanStub({"k": 1})).check(trace)
    assert any(v.invariant == "plan_eviction"
               and "claims exactly 1" in v.message for v in out)


def test_conformance_early_evict_is_legal():
    # evict_instance mops up before every planned read happened (e.g. a
    # failed instance): not a conformance violation by itself.
    trace = [
        _ev(1, "put", "k", "node0"),
        _ev(2, "evict", "k"),
    ]
    assert PlanConformance(_PlanStub({"k": 2})).check(trace) == []


def test_conformance_flags_avoidable_cold_boot():
    trace = [
        _ev(1, "prewarm_boot", "Srv/f", "node0"),
        _ev(2, "cold_boot", "Srv/f", "node0"),   # idle container existed
    ]
    out = PlanConformance(_PlanStub({})).check(trace)
    assert any(v.invariant == "plan_prewarm" for v in out)


def test_conformance_consumed_prewarm_then_cold_is_legal():
    trace = [
        _ev(1, "prewarm_boot", "Srv/f", "node0"),
        _ev(2, "prewarm_hit", "Srv/f", "node0"),  # boot was consumed
        _ev(3, "cold_boot", "Srv/f", "node0"),    # genuinely unavoidable
        _ev(4, "cold_boot", "Srv/f", "node1"),    # other node: unaffected
    ]
    assert PlanConformance(_PlanStub({})).check(trace) == []


def test_conformance_namespaces_instances():
    trace = [
        _ev(1, "put", "Srv#0:k", "node0"),
        _ev(2, "evict", "Srv#0:k"),
        _ev(3, "get_return", "Srv#0:k", "node0"),
    ]
    pc = PlanConformance(_PlanStub({"k": 1}))
    assert pc.check(trace) == []                  # raw "" namespace: no hit
    out = pc.check(trace, instances=["Srv#0"])
    assert any(v.invariant == "plan_eviction" for v in out)


# ----------------------------------------------------------------------
# Stream-feasibility diagnostics (DF016 / DF017)
# ----------------------------------------------------------------------

def _consume(**kw):
    return {}


def test_df017_single_chunk_stream():
    wf = Workflow("W", [
        FunctionSpec(name="p", inputs=("x",), outputs=("s",),
                     stream_outputs=("s",), chunk_size=1 << 18,
                     output_sizes={"s": 100}),
        FunctionSpec(name="c", inputs=("s",), outputs=("y",),
                     stream_inputs=("s",)),
    ])
    plan = build_plan(wf)
    assert [d.code for d in plan.diagnostics] == ["DF017"]
    assert plan.diagnostics[0].severity == "info"


def test_df016_later_plain_output_blocks_overlap():
    wf = Workflow("W", [
        FunctionSpec(name="p", inputs=("x",), outputs=("s", "m"),
                     stream_outputs=("s",), chunk_size=256,
                     output_sizes={"s": 4096, "m": 256}),
        FunctionSpec(name="c", inputs=("m", "s"), outputs=("y",),
                     stream_inputs=("s",)),
    ])
    plan = build_plan(wf)
    codes = [d.code for d in plan.diagnostics]
    assert "DF016" in codes, codes


def test_df016_silent_when_plain_output_precedes_stream():
    wf = Workflow("W", [
        FunctionSpec(name="p", inputs=("x",), outputs=("m", "s"),
                     stream_outputs=("s",), chunk_size=256,
                     output_sizes={"s": 4096, "m": 256}),
        FunctionSpec(name="c", inputs=("m", "s"), outputs=("y",),
                     stream_inputs=("s",)),
    ])
    plan = build_plan(wf)
    assert "DF016" not in [d.code for d in plan.diagnostics]


def test_df016_diamond_through_sibling_consumer():
    wf = Workflow("W", [
        FunctionSpec(name="p", inputs=("x",), outputs=("s",),
                     stream_outputs=("s",), chunk_size=256,
                     output_sizes={"s": 4096}),
        FunctionSpec(name="c1", inputs=("s",), outputs=("m",)),
        FunctionSpec(name="c2", inputs=("s", "m"), outputs=("y",),
                     stream_inputs=("s",)),
    ])
    plan = build_plan(wf)
    diags = [d for d in plan.diagnostics if d.code == "DF016"]
    assert diags and diags[0].function == "c2"


def test_healthy_stream_chain_has_no_diagnostics():
    wf = Workflow("W", [
        FunctionSpec(name="p", inputs=("x",), outputs=("s",),
                     stream_outputs=("s",), chunk_size=256,
                     output_sizes={"s": 4096}),
        FunctionSpec(name="c", inputs=("s",), outputs=("y",),
                     stream_inputs=("s",)),
    ])
    assert build_plan(wf).diagnostics == ()


# ----------------------------------------------------------------------
# CLI
# ----------------------------------------------------------------------

def test_cli_builtins_clean(capsys):
    from repro.plan import main

    assert main(["--builtin", "all"]) == 0
    out = capsys.readouterr().out
    assert "prewarm schedule" in out and "0 failed" in out


def test_cli_json(capsys):
    from repro.plan import main

    assert main(["--builtin", "Srv", "--format", "json"]) == 0
    docs = json.loads(capsys.readouterr().out)
    assert docs[0]["workflow"] == "Srv"
    assert docs[0]["self_check"] == []
    assert docs[0]["eviction_order"]
    assert docs[0]["prewarm_schedule"]


def test_cli_examples(capsys, tmp_path):
    from repro.plan import main

    assert main(["examples/workflows/wordcount.yaml",
                 "examples/workflows/video_pipeline.yaml"]) == 0
    # A document that fails to parse fails the plan run.
    bad = tmp_path / "bad.yaml"
    bad.write_text("functions:\n  - name: a\n    outputs: [k]\n"
                   "  - name: b\n    outputs: [k]\n")
    assert main([str(bad)]) == 1
    assert "PLAN FAILED" in capsys.readouterr().out


def test_cli_requires_target():
    from repro.plan import main

    with pytest.raises(SystemExit):
        main([])
