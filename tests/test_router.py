"""DShard router unit tests (ISSUE 8 satellite).

Covers the four behaviours the issue names explicitly — routing-table
construction from the partitioner's placement, stale-table refresh after a
coordinator sync, the misroute fallback (exactly one extra hop, recorded
*and* flagged by the trace checker), and per-shard eviction isolation —
plus the tiered transport's pricing counters and DPlan capacity presizing.
"""

import pytest
from strategies import external_inputs, random_workflow

from repro.core.check import TraceChecker, TraceRecorder
from repro.core.dstore import DStore, GetTimeout
from repro.core.partition import partition_workflow, stage_node
from repro.core.plan import build_plan
from repro.core.router import (TIER_IPC, TIER_MEM, TIER_NET, Coordinator,
                               RoutingTable, ShardedDStore, TieredTransport,
                               routes_from_plan, static_routes)

NODES = ["n1", "n2", "n3"]


# ----------------------------------------------------------------------
# Routing-table construction from placement
# ----------------------------------------------------------------------

def test_static_routes_follow_placement():
    wf = random_workflow(11)
    placement = partition_workflow(wf, NODES)
    routes = static_routes(wf, placement, nodes=NODES)
    for f in wf.functions.values():
        for k in f.outputs:
            assert routes[k] == placement[f.name], k
    for k in wf.external_inputs:
        assert routes[k] == stage_node(wf, k, placement, NODES[0]), k


def test_routes_from_plan_agree_with_static():
    """DPlan's transfer matrix names the same homes the placement does —
    the plan is just the richer source (it also sizes per-node peaks)."""
    wf = random_workflow(23)
    placement = partition_workflow(wf, NODES)
    plan = build_plan(wf, placement)
    from_plan = routes_from_plan(plan)
    static = static_routes(wf, placement, nodes=NODES)
    for k, home in from_plan.items():
        assert static[k] == home, k


def test_register_instance_installs_prefixed_routes():
    wf = random_workflow(7)
    placement = partition_workflow(wf, NODES)
    store = ShardedDStore(NODES)
    store.register_instance("fuzz7#0:", wf, placement,
                            plan=build_plan(wf, placement))
    for f in wf.functions.values():
        for k in f.outputs:
            assert store.coordinator.route_of(
                "fuzz7#0:" + k) == placement[f.name]
    # Registration feeds the coordinator only — tables refresh lazily.
    assert all(len(t) == 0 for t in store.tables.values())


def test_presize_from_plan_takes_max_per_node():
    wf = random_workflow(7)
    plan = build_plan(wf, nodes=NODES)
    store = ShardedDStore(NODES)
    store.presize_from_plan(plan)
    for node, peak in plan.peak_resident.items():
        assert store.capacity_bytes[node] == int(peak)
    before = dict(store.capacity_bytes)
    store.presize_from_plan(build_plan(random_workflow(3), nodes=NODES))
    assert all(store.capacity_bytes[n] >= before[n] for n in NODES)


# ----------------------------------------------------------------------
# Stale-table refresh via coordinator sync
# ----------------------------------------------------------------------

def test_stale_table_refresh_after_sync():
    coord = Coordinator(NODES)
    table = RoutingTable("n2")
    assert table.version < coord.version and table.lookup("k") is None
    assert table.misses == 1

    coord.install({"k": "n1"})
    v1 = coord.version
    coord.sync(table)
    assert table.version == v1 and table.refreshes == 1
    assert table.lookup("k") == "n1" and table.hits == 1

    coord.install({"k2": "n3"})          # table is stale again
    assert table.version < coord.version
    assert table.lookup("k2") is None    # stale: doesn't know k2 yet
    coord.sync(table)
    assert table.version == coord.version and table.refreshes == 2
    assert table.lookup("k2") == "n3"
    assert coord.syncs == 2


def test_chunk_keys_route_via_base_key():
    from repro.core.stream import chunk_key

    coord = Coordinator(NODES)
    coord.install({"s": "n3"})
    table = RoutingTable("n1")
    coord.sync(table)
    assert table.lookup(chunk_key("s", 4)) == "n3"
    assert coord.route_of(chunk_key("s", 0)) == "n3"


def test_table_miss_resolves_in_one_hop():
    """First Get on a never-synced table: miss → one coordinator sync →
    the *correct* home shard.  That's the legal refresh path and still
    counts as a 1-hop resolution."""
    store = ShardedDStore(NODES)
    rec = TraceRecorder()
    store.attach_tracer(rec)
    store.put("n1", "k", b"v" * 100)          # dynamic home: n1
    assert store.get("n2", "k", timeout=5.0) == b"v" * 100
    assert store.hop_hist[1] == 1 and store.hop_hist[2] == 0
    assert store.tier_gets[TIER_NET] == 1
    assert store.tables["n2"].refreshes == 1
    TraceChecker().check_or_raise(rec.events())
    route = [e for e in rec.events() if e.kind == "route"]
    assert len(route) == 1 and route[0].hops == 1 and route[0].src == "n1"


# ----------------------------------------------------------------------
# Misroute fallback: one extra hop, recorded AND flagged
# ----------------------------------------------------------------------

@pytest.mark.notracecheck
def test_misroute_costs_one_extra_hop_and_is_flagged():
    store = ShardedDStore(NODES)
    rec = TraceRecorder()
    store.attach_tracer(rec)
    store.put("n1", "k", b"payload")          # home: n1
    # Poison the consumer's table: stale route pointing at an ALIVE shard
    # that is not the key's home.
    store.tables["n3"].install({"k": "n2"}, version=999)

    assert store.get("n3", "k", timeout=5.0) == b"payload"

    # The fallback: one wasted shard contact (n2), then the authoritative
    # route — 2 hops total, recorded in the histogram and the trace.
    assert store.hop_hist[2] == 1 and store.hop_hist[1] == 0
    route = [e for e in rec.events() if e.kind == "route"]
    assert len(route) == 1 and route[0].hops == 2
    # And the trace checker flags it as a routing-invariant violation.
    violations = TraceChecker().check(rec.events())
    assert any(v.invariant == "routing" for v in violations), violations
    # The fallback also re-synced the table, so the NEXT consumer on n3
    # resolves correctly.
    assert store.tables["n3"].peek("k") == "n1"


# ----------------------------------------------------------------------
# Per-shard eviction isolation
# ----------------------------------------------------------------------

def test_evict_instance_cannot_touch_other_shards_keys():
    wf = random_workflow(5)
    placement = partition_workflow(wf, NODES)
    store = ShardedDStore(NODES)
    for prefix in ("a#0:", "b#0:"):
        store.register_instance(prefix, wf, placement)
        for k, v in external_inputs(wf).items():
            home = stage_node(wf, k, placement, NODES[0])
            store.put(home, prefix + k, v)
        for f in wf.functions.values():
            for k in f.outputs:
                store.put(placement[f.name], prefix + k, b"out:" + k.encode())

    b_keys_before = sorted(k for k in store.directory.keys()
                           if k.startswith("b#0:"))
    b_shard_records = {n: sorted(k for k in sh.keys()
                                 if k.startswith("b#0:"))
                       for n, sh in store.shards.items()}
    store.evict_instance("a#0:")

    # a's keys are gone everywhere: shards, stores, coordinator routes.
    assert not any(k.startswith("a#0:") for k in store.directory.keys())
    assert all(not s.has("a#0:o0") for s in store.stores.values())
    assert store.coordinator.route_of("a#0:o0") is None
    # b's records are untouched on EVERY shard, and its bytes still serve.
    assert sorted(k for k in store.directory.keys()
                  if k.startswith("b#0:")) == b_keys_before
    for n, sh in store.shards.items():
        assert sorted(k for k in sh.keys()
                      if k.startswith("b#0:")) == b_shard_records[n], n
    assert store.get("n1", "b#0:o0", timeout=5.0) == b"out:o0"


def test_routes_survive_key_eviction():
    """Immutability makes a stale route harmless: after evict_key the
    route stays installed and a Get cleanly blocks (no stale bytes)."""
    store = ShardedDStore(NODES)
    store.put("n1", "k", b"v")
    store.evict_key("k")
    assert store.coordinator.route_of("k") == "n1"
    with pytest.raises(GetTimeout):
        store.get("n2", "k", timeout=0.15)


# ----------------------------------------------------------------------
# Tiered transport pricing
# ----------------------------------------------------------------------

def test_tiered_transport_counters():
    t = TieredTransport()
    t.move(100, TIER_NET)
    t.move(50, TIER_MEM)
    t.move(25, TIER_IPC)
    # Base counters keep their single-store (cross-node) meaning.
    assert t.bytes_moved == 100 and t.transfers == 1
    assert t.tier_bytes == {TIER_IPC: 25, TIER_MEM: 50, TIER_NET: 100}
    assert t.tier_transfers == {TIER_IPC: 1, TIER_MEM: 1, TIER_NET: 1}


def test_sharded_store_prices_cross_node_get_as_net():
    t = TieredTransport()
    store = ShardedDStore(NODES, t)
    store.put("n1", "k", b"x" * 64)
    store.get("n2", "k", timeout=5.0)         # cross-node pull
    store.get("n1", "k", timeout=5.0)         # local hit at the home: ipc
    store.get("n2", "k", timeout=5.0)         # local replica hit: mem
    assert t.tier_bytes[TIER_NET] == 64 and t.bytes_moved == 64
    assert store.tier_gets == {TIER_IPC: 1, TIER_MEM: 1, TIER_NET: 1}
    assert store.hop_hist[0] == 2 and store.hop_hist[1] == 1


def test_plain_transport_only_pays_cross_node():
    """With a plain Transport the sharded store charges only net-tier
    pulls, keeping bytes_moved comparable to the single-store baseline."""
    wf = random_workflow(9)
    ext = external_inputs(wf)
    from repro.core.dscheduler import DFlowEngine

    base_eng = DFlowEngine(n_nodes=2, get_timeout=30.0)
    base_rep = base_eng.run(random_workflow(9), ext)

    shard_eng = DFlowEngine(n_nodes=2, get_timeout=30.0, sharded=True)
    shard_store = ShardedDStore(shard_eng.nodes, shard_eng.transport)
    shard_rep = shard_eng.start(wf, ext, store=shard_store).wait()

    assert {k: bytes(v) for k, v in shard_rep.outputs.items()} == \
           {k: bytes(v) for k, v in base_rep.outputs.items()}
    assert isinstance(shard_eng.transport, type(base_eng.transport))


# ----------------------------------------------------------------------
# Failure re-home: coordinator moves routes, Gets follow
# ----------------------------------------------------------------------

def test_fail_node_migrates_surviving_records_and_rehomes():
    store = ShardedDStore(NODES)
    store.put("n1", "k", b"v" * 32)           # home n1, bytes on n1
    store.get("n2", "k", timeout=5.0)         # replica now also on n2
    store.put("n1", "solo", b"only-here")     # no surviving replica

    lost = store.fail_node("n1")
    assert lost == ["solo"]
    # k survived via its n2 replica: re-homed, still gettable, and the
    # resolution is still 1-hop (failure re-home is not a misroute).
    assert store.coordinator.route_of("k") == "n2"
    assert store.get("n3", "k", timeout=5.0) == b"v" * 32
    assert store.hop_hist[2] == 0
    assert not store.coordinator.is_failed("n1")   # node came back empty
