"""Runtime tests: sharded train step, serving builders, orchestrator,
checkpoint/restart fault tolerance, elastic restore."""

import dataclasses
import time

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.launch.mesh import make_local_mesh
from repro.models import build_model, init_params
from repro.optim.adamw import AdamWConfig
from repro.runtime.orchestrator import OrchestratorConfig, run_training
from repro.runtime.train_lib import (build_train_step, init_train_state,
                                     make_train_state_specs)
from repro.sharding.context import mesh_context
from repro.sharding.rules import make_rules, spec_tree


def _toy_model():
    cfg = get_config("tinyllama-1.1b", reduced=True)
    cfg = dataclasses.replace(cfg, q_chunk=16, kv_chunk=16)
    return build_model(cfg)


def test_train_step_runs_and_descends():
    model = _toy_model()
    mesh = make_local_mesh()
    opt = AdamWConfig(lr=5e-3, warmup_steps=2, total_steps=50)
    with mesh_context(mesh):
        step, _ = build_train_step(model, mesh, opt)
        state = init_train_state(model, mesh, opt)
        rng = np.random.default_rng(0)
        batch = {"tokens": jnp.asarray(
            rng.integers(0, model.cfg.vocab, (4, 33)), jnp.int32)}
        losses = []
        for _ in range(8):
            state, metrics = step(state, batch)
            losses.append(float(metrics["loss"]))
        assert losses[-1] < losses[0]          # memorizes a fixed batch
        assert int(state.opt.step) == 8


def test_train_step_microbatched_matches_full():
    """Grad accumulation must match the single-batch gradient step."""
    model = _toy_model()
    mesh = make_local_mesh()
    opt = AdamWConfig(lr=1e-3, warmup_steps=0, total_steps=10,
                      weight_decay=0.0)
    rng = np.random.default_rng(1)
    batch = {"tokens": jnp.asarray(
        rng.integers(0, model.cfg.vocab, (4, 33)), jnp.int32)}
    with mesh_context(mesh):
        state0 = init_train_state(model, mesh, opt)
        step1, _ = build_train_step(model, mesh, opt, donate=False)
        s1, m1 = step1(state0, batch)

        model2 = build_model(dataclasses.replace(model.cfg, microbatches=2))
        step2, _ = build_train_step(model2, mesh, opt, donate=False)
        s2, m2 = step2(state0, batch)
    # losses equal (mean over microbatches of a homogeneous batch split)
    assert float(m1["loss"]) == pytest.approx(float(m2["loss"]), rel=5e-2)
    w1 = jax.tree.leaves(s1.params)[0]
    w2 = jax.tree.leaves(s2.params)[0]
    assert jnp.allclose(w1.astype(jnp.float32), w2.astype(jnp.float32),
                        atol=1e-2)


def test_state_specs_cover_all_leaves():
    model = _toy_model()
    mesh = make_local_mesh()
    specs = make_train_state_specs(model, mesh)
    n_param_leaves = len(jax.tree.leaves(
        init_params(model.param_decls(), jax.random.key(0))))
    from jax.sharding import PartitionSpec
    n_spec_leaves = len(jax.tree.leaves(
        specs.params, is_leaf=lambda x: isinstance(x, PartitionSpec)))
    assert n_param_leaves == n_spec_leaves


def test_checkpoint_restart_reproduces_trajectory(tmp_path):
    """Crash + resume must land on the same losses (fault tolerance)."""
    from repro.launch.train import train_loop
    out_full = train_loop("tinyllama-1.1b", steps=6, batch=2, seq=32,
                          log_every=0, seed=3)
    with pytest.raises(RuntimeError, match="simulated"):
        train_loop("tinyllama-1.1b", steps=6, batch=2, seq=32,
                   ckpt_dir=str(tmp_path), ckpt_every=2, log_every=0,
                   simulate_failure=4, seed=3)
    out_resumed = train_loop("tinyllama-1.1b", steps=6, batch=2, seq=32,
                             ckpt_dir=str(tmp_path), ckpt_every=2,
                             resume=True, log_every=0, seed=3)
    assert out_resumed["start_step"] == 4
    np.testing.assert_allclose(out_full["losses"][4:],
                               out_resumed["losses"], rtol=2e-2)


def test_elastic_restore_across_mesh_shapes(tmp_path):
    """A checkpoint saved on one mesh restores onto another (elastic)."""
    from repro.checkpoint import CheckpointManager
    from jax.sharding import NamedSharding
    model = _toy_model()
    opt = AdamWConfig()
    mesh1 = make_local_mesh(data=1, model=1)
    with mesh_context(mesh1):
        state = init_train_state(model, mesh1, opt)
    mgr = CheckpointManager(tmp_path, async_save=False)
    mgr.save(1, state)
    # "new cluster": same device count here, but restore goes through the
    # topology-agnostic path with explicit new-mesh shardings.
    mesh2 = make_local_mesh(data=1, model=1)
    specs = make_train_state_specs(model, mesh2)
    shardings = jax.tree.map(lambda s: NamedSharding(mesh2, s), specs,
                             is_leaf=lambda x: hasattr(x, "_normalized_spec")
                             or type(x).__name__ == "PartitionSpec")
    restored, step = mgr.restore(state, shardings=shardings)
    assert step == 1
    a = jax.tree.leaves(state.params)[0]
    b = jax.tree.leaves(restored.params)[0]
    assert jnp.allclose(a.astype(jnp.float32), b.astype(jnp.float32))


# ---------------------------------------------------------------- orchestrator
def test_orchestrator_runs_training_dag():
    log = []

    def fetch(i):
        return np.full((2, 2), i, np.float32)

    def train(state, batch):
        new_state = state + batch.sum()
        log.append(float(new_state))
        return new_state, {"loss": float(new_state)}

    saves = []
    cfg = OrchestratorConfig(n_steps=4, ckpt_every=2, pattern="dataflow")
    rep = run_training(cfg, init_state=np.float32(0.0), fetch=fetch,
                       train=train, save=lambda i, s: saves.append((i, s)))
    # final state = sum of batch sums 0+4+8+12
    assert rep.outputs["final_state"] == pytest.approx(24.0)
    assert [i for i, _ in saves] == [1, 3]
    assert len(rep.per_function) == 4 + 4 + 2 + 1  # fetch + step + ckpt + emit


def test_orchestrator_dataflow_overlaps_fetch():
    """With a slow transport, dataflow (prefetch overlap) beats controlflow."""
    def fetch(i):
        time.sleep(0.05)
        return np.ones((64, 64), np.float32)   # 16 KB payload

    def train(state, batch):
        time.sleep(0.05)
        return state + float(batch.mean()), {}

    times = {}
    for pattern in ("dataflow", "controlflow"):
        cfg = OrchestratorConfig(n_steps=5, pattern=pattern,
                                 transport_bandwidth=2e6)
        t0 = time.monotonic()
        rep = run_training(cfg, init_state=np.float32(0.0), fetch=fetch,
                           train=train)
        times[pattern] = time.monotonic() - t0
        assert rep.outputs["final_state"] == pytest.approx(5.0)
    # dataflow must not be slower; usually clearly faster.
    assert times["dataflow"] <= times["controlflow"] * 1.1
