"""DScale tests: lease-token accounting (the headline bugfix), prewarm
budgets + slack allocation, timer lifecycle, arrival generators, the pool
autoscaler control loop, and DServe admission control."""

import math
import random
import threading
import time

import pytest

from repro.core.dag import FunctionSpec, Workflow
from repro.core.dscheduler import DFlowEngine
from repro.core.obs import MetricsRegistry, Tracer
from repro.core.plan import build_plan
from repro.core.scale import (AutoscalerConfig, PoolAutoscaler, PoolSpec,
                              PrewarmBudget, RateEstimator,
                              allocate_prewarms, bursty_arrivals,
                              diurnal_arrivals)
from repro.core.serve import (ContainerPool, ContainerService, DServe,
                              percentile, trace_arrivals)
from repro.core.sim import Env
from repro.core.simcluster import Cluster, SimConfig
from repro.core.sim_systems import make_system
from repro.core.workloads import make_workflow


# ----------------------------------------------------------------------
# Lease-token accounting — the headline bugfix
# ----------------------------------------------------------------------

def test_release_flips_the_leased_container_not_first_busy():
    """The bug: release() un-busied the FIRST busy container in the pool,
    not the one the caller leased.  With one warm lease outstanding and a
    cold boot released mid-boot, first-busy release marked the *warm
    leased* container idle (wrong container, wrong idle_since) — the next
    warm acquire stole it out from under its holder.  The lease token
    pins the identity: these asserts fail under the pre-fix semantics."""
    p = ContainerPool("img", cold_start=1.0, keepalive=2.0)
    a = p.acquire(now=0.0)                 # c0: cold boot, ready at 1.0
    p.release(a, now=1.0)                  # c0 idle since 1.0
    w = p.try_acquire_warm(1.5)            # leases ready c0 (warm hit)
    assert w is not None and w.delay == 0.0 and not w.cold
    b = p.acquire(now=1.5)                 # c1: cold boot, ready at 2.5
    assert b.cold and b.container is not w.container
    p.release(b, now=2.0)                  # released before boot completes
    # c1 (still booting) must be the idle one; c0 stays leased to w.
    # Pre-fix: c0 (first busy) was flipped -> idle_count == 1, and the
    # warm acquire below would have returned c0 with delay 0.0.
    assert p.idle_count(2.0) == 0
    assert p.available(2.0) == 1
    x = p.try_acquire_warm(2.0)
    assert x is not None
    assert x.delay == pytest.approx(0.5)   # joins c1's residual boot
    assert x.container is b.container
    # w's lease is still intact and releasable.
    p.release(w, now=2.2)
    p.release(x, now=3.0)


def test_release_of_retired_container_is_tolerated():
    p = ContainerPool("img", cold_start=0.1, keepalive=10.0)
    lease = p.acquire(now=0.0)
    p.shutdown(now=1.0)
    p.release(lease, now=2.0)              # no raise: retired under lease
    assert lease.released
    with pytest.raises(RuntimeError):      # double release still caught
        p.release(lease, now=3.0)


def test_service_release_after_node_failure_is_noop():
    svc = ContainerService(["node0"], keepalive=10.0, cold_start=0.0)
    lease = svc.acquire("node0", "img", cold_start=0.0)
    svc.fail_node("node0")
    svc.release("node0", "img", lease)     # tolerated, not an error
    assert lease.released


# ----------------------------------------------------------------------
# Input validation satellites
# ----------------------------------------------------------------------

def test_trace_arrivals_rejects_nonfinite():
    for bad in (float("nan"), float("inf"), float("-inf")):
        with pytest.raises(ValueError):
            trace_arrivals([0.1, bad, 0.2])
    assert trace_arrivals([0.3, 0.0, 0.2]) == [0.0, 0.2, 0.3]


def test_percentile_validates_q():
    vals = [1.0, 2.0, 3.0, 4.0]
    assert percentile(vals, 0.0) == 1.0        # edges are legal
    assert percentile(vals, 100.0) == 4.0
    for q in (-1.0, 100.1, 1000.0, float("nan")):
        with pytest.raises(ValueError):
            percentile(vals, q)
    assert math.isnan(percentile([], 50.0))


# ----------------------------------------------------------------------
# Arrival generators
# ----------------------------------------------------------------------

def test_diurnal_arrivals_deterministic_and_shaped():
    a = diurnal_arrivals(400, base_rate=2.0, peak_rate=20.0, period=10.0,
                         seed=7)
    assert a == diurnal_arrivals(400, base_rate=2.0, peak_rate=20.0,
                                 period=10.0, seed=7)
    assert a == sorted(a) and len(a) == 400
    # Density near the peak (mid-period) beats density near the trough.
    def count(lo, hi):
        return sum(1 for t in a if lo <= (t % 10.0) < hi)
    assert count(4.0, 6.0) > count(0.0, 1.0) + count(9.0, 10.0)
    with pytest.raises(ValueError):
        diurnal_arrivals(10, base_rate=0.0, peak_rate=5.0)
    with pytest.raises(ValueError):
        diurnal_arrivals(10, base_rate=5.0, peak_rate=1.0)


def test_bursty_arrivals_deterministic_and_shaped():
    a = bursty_arrivals(400, base_rate=1.0, burst_rate=30.0,
                        burst_every=10.0, burst_len=2.0, seed=3)
    assert a == bursty_arrivals(400, base_rate=1.0, burst_rate=30.0,
                                burst_every=10.0, burst_len=2.0, seed=3)
    assert a == sorted(a)
    in_burst = sum(1 for t in a if (t % 10.0) < 2.0)
    # Bursts occupy 20% of the time but carry the vast majority of load.
    assert in_burst > 0.7 * len(a)
    with pytest.raises(ValueError):
        bursty_arrivals(10, base_rate=1.0, burst_rate=5.0,
                        burst_every=1.0, burst_len=2.0)


# ----------------------------------------------------------------------
# PrewarmBudget
# ----------------------------------------------------------------------

def test_budget_grant_deny_settle_refund():
    b = PrewarmBudget(1.0)
    g1 = b.request("f1", 0.6, now=0.0)
    assert g1 is not None and b.available(0.0) == pytest.approx(0.4)
    assert b.request("f2", 0.6, now=0.0) is None      # over budget
    assert b.denied == 1
    assert b.settle(g1) is True and g1.fired
    b.refund(g1)                                      # boot was a no-op
    assert b.available(0.0) == pytest.approx(1.0)
    with pytest.raises(ValueError):
        PrewarmBudget(-1.0)


def test_budget_cancel_closes_the_timer_race():
    """cancel() both refunds AND revokes, so a timer racing the
    cancellation sees settle() fail and never boots on refunded tokens."""
    b = PrewarmBudget(1.0)
    g = b.request("f", 0.5, now=0.0)
    b.cancel(g)
    assert b.available(0.0) == pytest.approx(1.0)
    assert b.settle(g) is False                       # the race is closed
    b.cancel(g)                                       # idempotent
    assert b.available(0.0) == pytest.approx(1.0)


def test_budget_refill_is_lazy_and_capped():
    b = PrewarmBudget(2.0, refill_per_s=1.0)
    assert b.request("f", 2.0, now=0.0) is not None
    assert b.available(0.5) == pytest.approx(0.5)
    assert b.available(100.0) == pytest.approx(2.0)   # capped at capacity


def test_budget_reclaim_revokes_highest_slack_first():
    b = PrewarmBudget(3.0)
    critical = b.request("crit", 1.0, slack=0.0, now=0.0)
    mid = b.request("mid", 1.0, slack=2.0, now=0.0)
    loose = b.request("loose", 1.0, slack=5.0, now=0.0)
    revoked = b.reclaim(1.5, now=0.0)
    assert [g.function for g in revoked] == ["loose", "mid"]
    assert loose.revoked and mid.revoked and not critical.revoked
    assert b.settle(critical) is True
    assert b.settle(mid) is False


# ----------------------------------------------------------------------
# allocate_prewarms — budget spent along DPlan slack
# ----------------------------------------------------------------------

def _diamond():
    """a -> {b (slow, critical), c (fast, slacky)} -> d."""
    return Workflow("dia", [
        FunctionSpec("a", ("x",), ("ka",), exec_time=1.0, cold_start=0.5),
        FunctionSpec("b", ("ka",), ("kb",), exec_time=5.0, cold_start=0.5),
        FunctionSpec("c", ("ka",), ("kc",), exec_time=1.0, cold_start=0.5),
        FunctionSpec("d", ("kb", "kc"), ("kd",), exec_time=1.0,
                     cold_start=0.5),
    ])


def test_allocate_prewarms_drops_highest_slack_first():
    plan = build_plan(_diamond(), nodes=["node0"])
    assert plan.functions["c"].slack > 0          # the droppable boot
    assert plan.functions["b"].slack == 0
    # Budget covers b and d's boot_cost (0.5 each) but not also c's.
    budget = PrewarmBudget(1.0)
    rows = allocate_prewarms(plan, budget, now=0.0)
    granted = {f for f, _, _, g in rows if g is not None}
    assert "b" in granted and "d" in granted      # critical path survives
    assert "c" not in granted                     # highest slack dropped
    assert budget.denied >= 1
    # Rows come back in boot order for the timer-arming loop.
    boots = [b for _, b, _, _ in rows]
    assert boots == sorted(boots)
    # No budget: every scheduled boot passes through with grant=None.
    free = allocate_prewarms(build_plan(_diamond(), nodes=["node0"]), None)
    assert len(free) == len(plan.prewarm_schedule)
    assert all(g is None for *_, g in free)


# ----------------------------------------------------------------------
# Prewarm timer lifecycle (dscheduler satellites)
# ----------------------------------------------------------------------

def test_prewarm_and_set_target_noop_after_shutdown_and_node_failure():
    svc = ContainerService(["node0", "node1"], keepalive=10.0)
    assert svc.prewarm("node0", "img", cold_start=0.1) is True
    assert svc.prewarm("node0", "img", cold_start=0.1) is False  # joinable
    svc.fail_node("node0")
    assert svc.prewarm("node0", "img", cold_start=0.1) is False
    assert svc.set_target("node0", "img", 3) == (0, 0)
    assert svc.prewarm("node1", "img", cold_start=0.1) is True
    svc.shutdown()
    assert svc.prewarm("node1", "img", cold_start=0.1) is False
    assert svc.set_target("node1", "img", 3) == (0, 0)
    assert svc.container_seconds() >= 0.0


def _slow_chain():
    def mk(out):
        def fn(**kw):
            time.sleep(0.05)
            return {out: b"v"}
        return fn
    return Workflow("tk", [
        FunctionSpec("a", ("x",), ("ka",), fn=mk("ka"), exec_time=0.05,
                     cold_start=0.0),
        FunctionSpec("b", ("ka",), ("kb",), fn=mk("kb"), exec_time=0.05,
                     cold_start=0.04),
    ])


def test_evict_cancels_pending_prewarm_timers_and_refunds_grants():
    """Killing an instance with armed prewarm timers: the timers must not
    fire containers.prewarm afterwards, and their budget grants must be
    refunded (satellite: timer lifecycle)."""
    wf = _slow_chain()
    svc = ContainerService([f"node{i}" for i in range(2)], keepalive=10.0)
    eng = DFlowEngine(n_nodes=2, containers=svc, prewarm=True,
                      get_timeout=5.0)
    placement = eng.gs.assign(wf)
    plan = build_plan(wf, placement)
    # b's slack-timed boot is armed on a threading.Timer (boot_at > 0).
    assert dict((f, fp.boot_at) for f, fp in plan.functions.items())["b"] > 0
    budget = PrewarmBudget(10.0)
    run = eng.start(wf, {"x": b"v"}, placement=placement, plan=plan,
                    budget=budget)
    run.evict()                       # kill before b's timer fires
    time.sleep(0.15)                  # well past boot_at
    b_pools = [p for (n, img), p in svc._pools.items() if img == "tk/b"]
    assert sum(p.prewarm_boots for p in b_pools) == 0
    assert all(g.fired or g.revoked for g in run._grants)
    # Every unfired grant's container-seconds went back to the bucket.
    spent = sum(g.cost for g in run._grants if g.fired and not g.refunded)
    assert budget.available(0.0) == pytest.approx(10.0 - spent)


def test_zero_budget_drops_priced_boots_but_not_free_ones():
    """b's slack-timed boot costs 0.04 container-seconds (it idles ahead
    of est); a's boots exactly at its est (cost 0) and stays granted even
    at zero budget — the p99-per-container-second pricing in action."""
    wf = _slow_chain()
    for cap, expect_b_boot in ((10.0, True), (0.0, False)):
        svc = ContainerService([f"node{i}" for i in range(2)],
                               keepalive=10.0)
        eng = DFlowEngine(n_nodes=2, containers=svc, prewarm=True,
                          get_timeout=5.0)
        placement = eng.gs.assign(wf)
        plan = build_plan(wf, placement)
        assert plan.functions["b"].boot_cost > 0
        assert plan.functions["a"].boot_cost == 0
        run = eng.start(wf, {"x": b"v"}, placement=placement, plan=plan,
                        budget=PrewarmBudget(cap))
        rep = run.wait()
        assert rep.outputs["kb"] == b"v"
        b_boots = sum(p.prewarm_boots
                      for (n, img), p in svc._pools.items()
                      if img == "tk/b")
        assert (b_boots > 0) is expect_b_boot, (cap, b_boots)


# ----------------------------------------------------------------------
# Pool conservation (property-style)
# ----------------------------------------------------------------------

def test_pool_conservation_random_interleaving_virtual_clock():
    """Under any interleaving of acquire/release/prewarm/sweep/set_target,
    every booted container is either live or evicted (never lost, never
    double-counted) and container-seconds stay consistent and monotone."""
    rng = random.Random(1234)
    p = ContainerPool("img", cold_start=0.3, keepalive=2.0)
    leases = []
    now, prev_secs = 0.0, 0.0
    for _ in range(500):
        now += rng.random() * 0.5
        op = rng.randrange(6)
        if op == 0:
            leases.append(p.acquire(now))
        elif op == 1 and leases:
            p.release(leases.pop(rng.randrange(len(leases))), now)
        elif op == 2:
            p.sweep(now)
        elif op == 3:
            p.prewarm(now)
        elif op == 4:
            p.set_target(rng.randrange(4), now)
        else:
            p.set_target(None, now)
        assert p.live() + p.evictions == p.boots
        assert p.boots == p.cold_starts + p.prewarm_boots
        secs = p.container_seconds(now)
        assert secs >= prev_secs - 1e-9
        prev_secs = secs
        assert len([c for c in p._containers if c.busy]) == len(leases)
    total = p.shutdown(now)
    for lease in leases:
        p.release(lease, now)          # tolerated: retired under lease
    assert p.live() == 0
    assert p.container_seconds(now + 100.0) == pytest.approx(total)


def test_pool_conservation_threaded_interleaving():
    svc = ContainerService(["node0"], keepalive=0.2, max_per_node=8,
                           cold_start=0.01)
    stop = threading.Event()
    errors: list[BaseException] = []

    def worker(seed: int) -> None:
        rng = random.Random(seed)
        try:
            while not stop.is_set():
                lease = svc.acquire("node0", "img", cold_start=0.01)
                time.sleep(rng.random() * 0.01)
                svc.release("node0", "img", lease)
        except BaseException as exc:   # noqa: BLE001 - surfaced below
            errors.append(exc)

    def scaler() -> None:
        rng = random.Random(99)
        try:
            while not stop.is_set():
                svc.set_target("node0", "img", rng.randrange(5),
                               cold_start=0.01)
                time.sleep(0.004)
        except BaseException as exc:   # noqa: BLE001 - surfaced below
            errors.append(exc)

    threads = [threading.Thread(target=worker, args=(i,)) for i in range(6)]
    threads.append(threading.Thread(target=scaler))
    for t in threads:
        t.start()
    time.sleep(0.4)
    stop.set()
    for t in threads:
        t.join(5.0)
    assert not errors, errors
    p = svc.pool("node0", "img", 0.01)
    assert p.live() + p.evictions == p.boots       # quiescent conservation
    total = svc.shutdown()
    assert math.isfinite(total) and total >= 0.0
    assert p.live() == 0


# ----------------------------------------------------------------------
# RateEstimator + PoolAutoscaler
# ----------------------------------------------------------------------

def test_rate_estimator_windows_and_damps_short_history():
    r = RateEstimator(window=1.0)
    assert r.rate() == 0.0
    r.observe(0.0, 0.0)
    r.observe(0.05, 5.0)
    # Two samples 50 ms apart are not evidence of a 100/s sustained rate:
    # a short span still divides by the full window.
    assert r.rate() == pytest.approx(5.0)
    r.observe(1.0, 20.0)
    assert r.rate() == pytest.approx(20.0)
    r.observe(2.0, 20.0)
    assert r.rate() == pytest.approx(0.0, abs=1e-9)
    with pytest.raises(ValueError):
        RateEstimator(window=0.0)


def _scaler(reg, tr, **cfg_kw):
    calls = []
    cfg = AutoscalerConfig(**{"window": 1.0, "headroom": 1.0,
                              "max_pool": 8, "scale_down_delay": 1.0,
                              **cfg_kw})
    spec = PoolSpec(node="node0", image="wf/f", service_time=0.5)
    sc = PoolAutoscaler(
        reg, [spec], cfg=cfg, spans=tr,
        apply=lambda n, i, t, c: calls.append((n, i, t)))
    return sc, calls


def test_autoscaler_scales_up_on_rate_spike():
    reg, tr = MetricsRegistry(), Tracer()
    sc, calls = _scaler(reg, tr)
    arrivals = reg.counter("serve_arrivals_total")
    sc.step(0.0)
    arrivals.inc(10)                       # 10 arrivals over 1 s
    decisions = sc.step(1.0)
    assert len(decisions) == 1
    d = decisions[0]
    assert d.target == 5 and d.previous is None and d.reason == "rate"
    assert d.rate == pytest.approx(10.0)
    assert sc.target("node0", "wf/f") == 5
    assert calls == [("node0", "wf/f", 5)]
    # Published twice: registry events AND tracer span instants.
    assert reg.counter("autoscale_decisions_total", node="node0",
                       image="wf/f", direction="up").value == 1
    assert reg.gauge("pool_target", node="node0", image="wf/f").value == 5
    evs = [s for s in tr.finished() if s.kind == "scale"]
    assert len(evs) == 1 and evs[0].trace == "autoscaler"
    assert evs[0].attrs["target"] == 5 and evs[0].attrs["direction"] == "up"
    assert reg.total("autoscale_steps_total") == 2


def test_autoscaler_scales_down_after_hysteresis():
    reg, tr = MetricsRegistry(), Tracer()
    sc, calls = _scaler(reg, tr, scale_down_delay=1.0)
    arrivals = reg.counter("serve_arrivals_total")
    sc.step(0.0)
    arrivals.inc(10)
    sc.step(1.0)                            # up to 5
    sc.step(2.0)                            # rate 0, but within the delay
    assert sc.target("node0", "wf/f") == 5  # hysteresis holds
    sc.step(3.5)                            # sustained idle -> shrink
    assert sc.target("node0", "wf/f") == 0
    assert calls[-1] == ("node0", "wf/f", 0)
    assert sc.decisions[-1].reason == "idle"
    assert reg.counter("autoscale_decisions_total", node="node0",
                       image="wf/f", direction="down").value == 1
    downs = [s for s in tr.finished()
             if s.kind == "scale" and s.attrs["direction"] == "down"]
    assert len(downs) == 1 and downs[0].attrs["previous"] == 5


def test_autoscaler_mem_pressure_blocks_scale_up():
    reg, tr = MetricsRegistry(), Tracer()
    sc, calls = _scaler(reg, tr)
    arrivals = reg.counter("serve_arrivals_total")
    sc.step(0.0)
    arrivals.inc(2)
    sc.step(1.0)
    assert sc.target("node0", "wf/f") == 1
    # DShard gauges report the node memory-bound: scale-up must hold.
    reg.gauge("capacity_bytes", node="node0").set(100.0)
    reg.gauge("dstore_resident_bytes", node="node0").set(95.0)
    arrivals.inc(40)
    sc.step(2.0)
    assert sc.target("node0", "wf/f") == 1            # held
    assert reg.counter("autoscale_mem_holds_total", node="node0",
                       image="wf/f").value == 1
    # Pressure clears -> the pending scale-up goes through.
    reg.gauge("dstore_resident_bytes", node="node0").set(10.0)
    arrivals.inc(40)
    sc.step(3.0)
    assert sc.target("node0", "wf/f") > 1


def test_autoscaler_slo_bump():
    reg, tr = MetricsRegistry(), Tracer()
    sc, _ = _scaler(reg, tr, slo_p99=0.2)
    reg.histogram("serve_latency_seconds").observe(1.0)   # p99 over SLO
    arrivals = reg.counter("serve_arrivals_total")
    sc.step(0.0)
    arrivals.inc(10)
    sc.step(1.0)
    assert sc.target("node0", "wf/f") == 6    # 5 from rate + 1 SLO bump


def test_set_target_boots_up_and_reclaims_idle_early():
    p = ContainerPool("img", cold_start=0.5, keepalive=100.0)
    booted, evicted = p.set_target(3, now=0.0)
    assert (booted, evicted) == (3, 0)
    assert p.live() == 3 and p.prewarm_boots == 3
    assert p.idle_count(1.0) == 3               # boots completed
    # Scale down: idle containers beyond target are reclaimed ahead of
    # their (100 s) TTL — the container-seconds win.
    booted, evicted = p.set_target(1, now=2.0)
    assert (booted, evicted) == (0, 2)
    assert p.live() == 1 and p.evictions == 2
    assert p.container_seconds(2.0) == pytest.approx(3 * 2.0)


def test_target_floor_outranks_keepalive_ttl():
    # The autoscaler's target pins the pool from both sides: a lull
    # longer than the TTL must not drain a pool the control loop
    # believes is provisioned (apply only fires on target *changes*).
    p = ContainerPool("img", cold_start=0.5, keepalive=1.0)
    p.set_target(2, now=0.0)
    assert p.sweep(10.0) == 0                   # far past TTL: pinned
    assert p.live() == 2 and p.evictions == 0
    lease = p.try_acquire_warm(10.0)            # pinned-warm is reusable
    assert lease is not None and lease.delay == 0.0
    p.release(lease, now=10.1)
    # Dropping the target releases the pin: TTL reclaim resumes.
    p.set_target(1, now=10.2)
    assert p.live() == 1
    p.target = None
    assert p.sweep(20.0) == 1
    assert p.live() == 0


# ----------------------------------------------------------------------
# Simulator wiring (virtual clock)
# ----------------------------------------------------------------------

def test_sim_pool_set_target_respects_capacity_accounting():
    env = Env()
    cluster = Cluster(env, SimConfig(cold_start=0.5, keepalive=100.0))
    node = cluster.nodes["node1"]
    pool = node.pool("img")
    pool.set_target(3)
    assert pool.model.live() == 3
    assert node.container_cap.in_use == 3
    env.run(until=1.0)
    assert pool.warm == 3
    pool.set_target(1)
    assert pool.model.live() == 1
    assert node.container_cap.in_use == 1       # capacity handed back


def test_sim_lease_release_pins_container():
    env = Env()
    cluster = Cluster(env, SimConfig(cold_start=0.5, keepalive=100.0))
    pool = cluster.nodes["node1"].pool("img")
    got = []
    pool.acquire().add_waiter(got.append)
    env.run(until=1.0)
    (lease,) = got
    assert lease.cold and lease.delay == pytest.approx(0.5)
    lease.release()
    assert pool.warm == 1


def test_sim_zero_budget_blocks_speculative_prewarm():
    """faasflow's decentralized prewarm (the free heuristic) must pay the
    DScale budget in the simulator too: a zero bucket means no
    speculative boots, and the run still completes (cold boots on
    demand)."""
    wf = make_workflow("WC")
    boots = {}
    for cap in (None, 0.0):
        env = Env()
        cluster = Cluster(env, SimConfig())
        budget = None if cap is None else PrewarmBudget(cap)
        sys_ = make_system("faasflow", env, cluster, wf, budget=budget)
        sys_.invoke()
        env.run(until=120.0)
        assert len(sys_.results) == 1, cap
        boots[cap] = sum(p.model.prewarm_boots
                         for n in cluster.nodes.values()
                         for p in n._pools.values())
    assert boots[None] > 0
    assert boots[0.0] == 0


# ----------------------------------------------------------------------
# DServe admission control (bounded concurrency + shedding)
# ----------------------------------------------------------------------

def _echo_chain(work: float = 0.03):
    def s0(request):
        time.sleep(work)
        return {"mid": b"mid:" + request}

    def s1(mid):
        time.sleep(work)
        return {"response": b"resp:" + mid}
    return Workflow("echo", [
        FunctionSpec("s0", ("request",), ("mid",), fn=s0, exec_time=work,
                     cold_start=0.0),
        FunctionSpec("s1", ("mid",), ("response",), fn=s1, exec_time=work,
                     cold_start=0.0),
    ])


def test_admission_bounds_inflight_and_queues_overflow():
    srv = DServe(_echo_chain(), n_nodes=2, max_inflight=2,
                 keepalive=10.0, get_timeout=10.0)
    rep = srv.run([0.0] * 6, inputs=lambda i: {"request": b"r%d" % i})
    assert rep.failures == 0 and rep.shed == 0
    assert rep.max_concurrency <= 2
    assert rep.queued == 4                     # 2 ran, 4 waited
    assert rep.queue_wait_p95 > 0.0
    # Queued instances still produce correct, per-instance responses.
    for i, stat in enumerate(rep.stats):
        assert stat.outputs["response"] == b"resp:mid:r%d" % i
    # Registry carries the same counters the report was derived from.
    assert srv.metrics.total("serve_queued_total") == 4


def test_admission_sheds_when_queue_full():
    srv = DServe(_echo_chain(), n_nodes=2, max_inflight=1, queue_depth=1,
                 keepalive=10.0, get_timeout=10.0)
    rep = srv.run([0.0] * 4, inputs=lambda i: {"request": b"r%d" % i})
    assert rep.shed >= 1 and rep.queued >= 1
    assert rep.shed == sum(1 for s in rep.stats if s.shed)
    # Shed requests are backpressure, not failures.
    assert rep.failures == 0
    assert sum(1 for s in rep.stats if s.ok) == 4 - rep.shed
    for s in rep.stats:
        if s.shed:
            assert "shed" in s.error
    assert srv.metrics.total("serve_shed_total") == rep.shed


def test_admission_validation():
    with pytest.raises(ValueError):
        DServe(_echo_chain(), max_inflight=0)
    with pytest.raises(ValueError):
        DServe(_echo_chain(), queue_depth=-1)


def test_dserve_autoscale_end_to_end_publishes_decisions():
    """DServe(autoscale=...) closes the loop for real: registry arrival
    rates drive set_target on the live ContainerService, and every
    decision shows up as registry events and tracer span instants."""
    tr = Tracer()
    cfg = AutoscalerConfig(interval=0.02, window=0.4, headroom=1.5,
                           max_pool=8, scale_down_delay=30.0)
    srv = DServe(_echo_chain(), n_nodes=2, autoscale=cfg, spans=tr,
                 keepalive=10.0, get_timeout=10.0)
    assert srv.autoscaler is not None
    arrivals = [i * 0.025 for i in range(16)]
    rep = srv.run(arrivals, inputs=lambda i: {"request": b"r%d" % i})
    assert rep.failures == 0
    assert srv.autoscaler.decisions, "no scaling decisions taken"
    assert srv.metrics.total("autoscale_decisions_total") >= 1
    assert srv.metrics.total("autoscale_steps_total") >= 1
    scale_events = [s for s in tr.finished() if s.kind == "scale"]
    assert scale_events and all(s.trace == "autoscaler"
                                for s in scale_events)
    # The autoscaler's targets actually reached the pools.
    assert any(p.target is not None
               for p in srv.containers._pools.values())
