"""DServe serving-layer tests: container lifecycle, concurrent instances,
per-instance namespacing/eviction, prewarm, bounded concurrency, and
failure injection with per-instance incremental recovery."""

import threading
import time

import pytest

from repro.core.dag import FunctionSpec, Workflow
from repro.core.dscheduler import DFlowEngine
from repro.core.dstore import DStore
from repro.core.serve import (ContainerPool, ContainerService, DServe,
                              poisson_arrivals, trace_arrivals)
from repro.core.workloads import serving_chain, serving_fanout


# ----------------------------------------------------------------------
# ContainerPool — pure lifecycle model (shared with the simulator)
# ----------------------------------------------------------------------

def test_pool_cold_then_warm():
    p = ContainerPool("img", cold_start=0.5, keepalive=10.0)
    lease = p.acquire(now=0.0)
    assert (lease.delay, lease.cold) == (0.5, True) and p.cold_starts == 1
    p.release(lease, now=1.0)
    lease = p.acquire(now=2.0)
    assert (lease.delay, lease.cold) == (0.0, False)
    assert p.warm_hits == 1 and p.cold_starts == 1


def test_pool_prewarm_join():
    """An acquire during a prewarm boot joins it: pays only the residual
    boot time (the §3.2 overlap), counted as a prewarm hit."""
    p = ContainerPool("img", cold_start=1.0, keepalive=10.0)
    assert p.prewarm(now=0.0) == 1.0
    assert p.prewarm(now=0.1) == pytest.approx(0.9)   # no second boot
    assert p.prewarm_boots == 1
    lease = p.acquire(now=0.4)
    assert not lease.cold and lease.delay == pytest.approx(0.6)
    assert p.prewarm_hits == 1 and p.cold_starts == 0


def test_pool_keepalive_eviction_and_container_seconds():
    p = ContainerPool("img", cold_start=0.5, keepalive=2.0)
    lease = p.acquire(now=0.0)
    p.release(lease, now=1.0)
    assert p.idle_count(1.0) == 1
    assert p.sweep(now=2.9) == 0            # TTL not yet expired
    assert p.sweep(now=3.1) == 1            # idle since 1.0 + 2.0 < 3.1
    assert p.evictions == 1 and p.live() == 0
    # lifetime accounted 0.0 -> 3.0 (eviction instant = idle + keepalive)
    assert p.container_seconds(10.0) == pytest.approx(3.0)
    # next acquire is cold again
    assert p.acquire(now=5.0).cold


def test_pool_double_release_raises():
    p = ContainerPool("img")
    lease = p.acquire(now=0.0)
    p.release(lease, now=1.0)
    with pytest.raises(RuntimeError):
        p.release(lease, now=2.0)


def test_pool_shutdown_finalizes_seconds():
    p = ContainerPool("img", cold_start=0.1, keepalive=100.0)
    p.acquire(now=0.0)
    p.prewarm(now=0.0)
    assert p.shutdown(now=4.0) == pytest.approx(8.0)
    assert p.live() == 0


# ----------------------------------------------------------------------
# Arrival processes
# ----------------------------------------------------------------------

def test_poisson_arrivals_deterministic_and_calibrated():
    a = poisson_arrivals(10.0, 500, seed=42)
    b = poisson_arrivals(10.0, 500, seed=42)
    assert a == b and len(a) == 500
    assert a == sorted(a) and a[0] > 0
    mean_gap = a[-1] / len(a)
    assert 0.05 < mean_gap < 0.2              # mean 1/rate = 0.1 +/- slack
    assert poisson_arrivals(10.0, 50, seed=1) != poisson_arrivals(
        10.0, 50, seed=2)
    with pytest.raises(ValueError):
        poisson_arrivals(0.0, 5)


def test_trace_arrivals():
    assert trace_arrivals([0.3, 0.1, 0.2]) == [0.1, 0.2, 0.3]
    with pytest.raises(ValueError):
        trace_arrivals([-1.0])


# ----------------------------------------------------------------------
# Concurrent multi-instance serving
# ----------------------------------------------------------------------

def _echo_chain():
    """2-stage chain whose response encodes the request — distinct per
    instance, so cross-instance key collisions are detectable."""
    def s0(request):
        return {"mid": b"mid:" + request}

    def s1(mid):
        return {"response": b"resp:" + mid}
    return Workflow("echo", [
        FunctionSpec("s0", ("request",), ("mid",), fn=s0, exec_time=0.02,
                     cold_start=0.02),
        FunctionSpec("s1", ("mid",), ("response",), fn=s1, exec_time=0.02,
                     cold_start=0.02),
    ])


@pytest.mark.parametrize("pattern", ["dataflow", "controlflow"])
def test_concurrent_instances_no_collision(pattern):
    """The satellite bug: global DStore keys made concurrent instances of
    one workflow collide.  With per-instance namespacing every instance
    gets the response for *its own* request."""
    srv = DServe(_echo_chain(), n_nodes=2, pattern=pattern,
                 keepalive=10.0, max_per_node=8, get_timeout=10.0)
    n = 8
    rep = srv.run([0.0] * n, inputs=lambda i: {"request": b"r%d" % i})
    assert rep.failures == 0
    assert rep.max_concurrency >= 4
    for i, stat in enumerate(rep.stats):
        assert stat.outputs["response"] == b"resp:mid:r%d" % i, stat


def test_instance_eviction_bounds_store():
    srv = DServe(_echo_chain(), n_nodes=2, keepalive=10.0,
                 get_timeout=10.0)
    rep = srv.run(poisson_arrivals(50.0, 6, seed=5),
                  inputs=lambda i: {"request": b"r%d" % i})
    assert rep.failures == 0
    assert srv.store.directory.keys() == []       # all namespaces evicted
    for store in srv.store.stores.values():
        assert not store._data


def test_prewarm_cuts_request_path_cold_starts():
    """fig12 serving acceptance: request-path cold-start counts drop with
    the §3.2 prewarm trigger enabled."""
    counts = {}
    for prewarm in (True, False):
        wf = serving_chain(stages=4, exec_time=0.02, cold_start=0.08,
                           payload=4 * 1024)
        srv = DServe(wf, n_nodes=2, pattern="dataflow", prewarm=prewarm,
                     keepalive=10.0, get_timeout=10.0)
        rep = srv.run(poisson_arrivals(6.0, 6, seed=1),
                      inputs={"request": b"x"})
        assert rep.failures == 0
        counts[prewarm] = (rep.cold_starts, rep.prewarm_hits)
    assert counts[True][0] < counts[False][0]
    assert counts[True][1] > 0 and counts[False][1] == 0


def test_dataflow_beats_controlflow_p99_under_load():
    """serve_load acceptance in test form: at >=4 concurrent instances the
    dataflow pattern's p99 beats controlflow's."""
    p99 = {}
    for pattern in ("dataflow", "controlflow"):
        wf = serving_chain(stages=4, exec_time=0.03, cold_start=0.15,
                           payload=8 * 1024)
        srv = DServe(wf, n_nodes=2, pattern=pattern, keepalive=10.0,
                     max_per_node=16, get_timeout=10.0)
        rep = srv.run(poisson_arrivals(8.0, 10, seed=7),
                      inputs={"request": b"req"})
        assert rep.failures == 0
        assert rep.max_concurrency >= 4, rep.max_concurrency
        p99[pattern] = rep.p99
    assert p99["dataflow"] < p99["controlflow"], p99


def test_bounded_per_node_concurrency():
    """max_per_node caps how many functions *execute* simultaneously on a
    node (launched-but-blocked fetches don't hold slots, so no deadlock)."""
    running = {"now": 0, "peak": 0}
    lock = threading.Lock()

    def work(**kw):
        with lock:
            running["now"] += 1
            running["peak"] = max(running["peak"], running["now"])
        time.sleep(0.03)
        with lock:
            running["now"] -= 1
        return {next(iter(kw)).replace("in", "out"): b"v"}

    fns = [FunctionSpec(f"w{i}", (f"in{i}",), (f"out{i}",), fn=work,
                        exec_time=0.03, cold_start=0.0)
           for i in range(6)]
    wf = Workflow("fan", fns)
    srv = DServe(wf, n_nodes=1, pattern="dataflow", max_per_node=2,
                 keepalive=10.0, get_timeout=10.0)
    rep = srv.run([0.0], inputs={f"in{i}": b"x" for i in range(6)})
    assert rep.failures == 0
    assert running["peak"] <= 2


def test_fanout_workload_serves():
    srv = DServe(serving_fanout(workers=3, exec_time=0.01, cold_start=0.02),
                 n_nodes=2, keepalive=10.0, get_timeout=10.0)
    rep = srv.run([0.0, 0.05, 0.1], inputs={"request": b"q"})
    assert rep.failures == 0
    assert all(s.outputs["response"] for s in rep.stats)


# ----------------------------------------------------------------------
# Failure injection across concurrent instances
# ----------------------------------------------------------------------

def test_node_failure_recovers_only_lost_functions_per_instance():
    """Kill a node while 2 instances are mid-flight: every instance
    completes, and only the functions whose outputs actually died re-run
    (incremental, per instance) — survivors run exactly once."""
    calls: dict[str, int] = {}
    lock = threading.Lock()

    def mk(name, out_key, slow=False):
        def fn(**kw):
            with lock:
                calls[name] = calls.get(name, 0) + 1
            if slow:
                time.sleep(0.15)
            src = b"".join(bytes(v) for _, v in sorted(kw.items()))
            return {out_key: name.encode() + b"|" + src}
        return fn

    # a -> b -> c; placement puts the chain on one node, so failing the
    # OTHER node must lose nothing.
    wf = Workflow("ft", [
        FunctionSpec("a", ("x",), ("ka",), fn=mk("a", "ka"),
                     exec_time=0.01, cold_start=0.0),
        FunctionSpec("b", ("ka",), ("kb",), fn=mk("b", "kb", slow=True),
                     exec_time=0.15, cold_start=0.0),
        FunctionSpec("c", ("kb",), ("kc",), fn=mk("c", "kc"),
                     exec_time=0.01, cold_start=0.0),
    ])
    srv = DServe(wf, n_nodes=2, pattern="dataflow", keepalive=10.0,
                 get_timeout=10.0)
    used = set(srv.placement.values())
    dead = next(iter(used))
    expected = {"kc": b"c|b|a|x0"}, {"kc": b"c|b|a|x1"}
    # fail while b (slow) is mid-flight: a's output ka is lost, only a
    # re-runs; b's blocked/done state recovers through the re-publish.
    rep = srv.run([0.0, 0.02], inputs=lambda i: {"x": b"x%d" % i},
                  fail_node_at=(0.08, dead))
    assert rep.failures == 0, [s.error for s in rep.stats]
    for i, stat in enumerate(rep.stats):
        assert stat.outputs == expected[i]
    # c never started before the failure -> executed exactly once per inst.
    assert calls["c"] == 2
    # something was actually lost and re-run on at least one instance
    assert sum(s.reexecuted for s in rep.stats) >= 1 or calls["a"] > 2


def test_failure_on_unused_node_is_noop():
    srv = DServe(_echo_chain(), n_nodes=3, keepalive=10.0, get_timeout=10.0)
    unused = [n for n in srv.engine.nodes
              if n not in set(srv.placement.values())]
    if not unused:
        pytest.skip("partitioner used every node")
    rep = srv.run([0.0, 0.01], inputs=lambda i: {"request": b"r%d" % i},
                  fail_node_at=(0.03, unused[0]))
    assert rep.failures == 0
    assert all(s.reexecuted == 0 for s in rep.stats)


def test_manual_fail_node_between_instances():
    """fail_node() between arrivals: finished instances are unaffected
    (already evicted), in-flight ones recover."""
    srv = DServe(_echo_chain(), n_nodes=2, keepalive=10.0, get_timeout=10.0)
    r1 = srv.run([0.0], inputs={"request": b"one"})
    assert r1.failures == 0
    lost = srv.fail_node(srv.placement["s0"])
    assert lost == []                  # everything was already evicted
    r2 = srv.run([0.0], inputs={"request": b"two"})
    assert r2.failures == 0
    assert r2.stats[0].outputs["response"] == b"resp:mid:two"


# ----------------------------------------------------------------------
# Engine-level instance API (what DServe builds on)
# ----------------------------------------------------------------------

def test_instance_runs_share_store_without_collision():
    eng = DFlowEngine(n_nodes=2, get_timeout=10.0)
    store = DStore(eng.nodes, eng.transport)
    wf = _echo_chain()
    runs = [eng.start(wf, {"request": b"r%d" % i}, store=store,
                      instance=f"echo#{i}") for i in range(4)]
    for i, run in enumerate(runs):
        rep = run.wait()
        assert rep.outputs["response"] == b"resp:mid:r%d" % i
    # namespaced keys really are distinct records
    keys = store.directory.keys()
    assert len([k for k in keys if k.endswith(":response")]) == 4
    runs[0].evict()
    assert not any(k.startswith("echo#0:") for k in store.directory.keys())


def test_container_service_metrics_aggregate():
    svc = ContainerService(["node0"], keepalive=10.0, max_per_node=4)
    lease = svc.acquire("node0", "img", cold_start=0.0)
    assert lease.cold is True
    svc.release("node0", "img", lease)
    lease = svc.acquire("node0", "img", cold_start=0.0)
    assert lease.cold is False
    svc.release("node0", "img", lease)
    svc.prewarm("node0", "img2", cold_start=0.0)
    assert svc.cold_starts == 1
    assert svc.warm_hits == 1
    assert svc.prewarm_boots == 1
    assert svc.container_seconds() >= 0.0
