"""Discrete-event kernel tests: clock, processes, resources, max-min net."""

import pytest

from repro.core.sim import Env, Network, Resource, all_of


def test_timeout_ordering():
    env = Env()
    seen = []

    def p(name, delay):
        yield env.timeout(delay)
        seen.append((name, env.now))
    env.process(p("b", 2.0))
    env.process(p("a", 1.0))
    env.run()
    assert seen == [("a", 1.0), ("b", 2.0)]


def test_process_return_value_and_all_of():
    env = Env()

    def inner(v):
        yield env.timeout(v)
        return v * 10

    def outer():
        vals = yield all_of(env, [env.process(inner(1)),
                                  env.process(inner(2))])
        return vals
    p = env.process(outer())
    env.run()
    assert p.value == [10, 20]
    assert env.now == 2.0


def test_resource_fifo_and_capacity():
    env = Env()
    order = []

    def worker(i):
        yield res.acquire()
        order.append(("start", i, env.now))
        yield env.timeout(1.0)
        res.release()
    res = Resource(env, capacity=2)
    for i in range(4):
        env.process(worker(i))
    env.run()
    starts = [t for (_, _, t) in order]
    assert starts == [0.0, 0.0, 1.0, 1.0]


def test_network_single_flow_rate():
    env = Env()
    net = Network(env, uplink={"a": 100.0, "b": 100.0},
                  downlink={"a": 100.0, "b": 100.0}, latency=0.0)
    done = net.transfer("a", "b", 200.0)
    env.run()
    assert done.triggered
    assert env.now == pytest.approx(2.0)   # 200 B at 100 B/s


def test_network_maxmin_sharing():
    """Two flows into one receiver share its downlink fairly."""
    env = Env()
    net = Network(env, uplink={"a": 100.0, "b": 100.0, "c": 100.0},
                  downlink={"a": 100.0, "b": 100.0, "c": 100.0}, latency=0.0)
    t = {}

    def run_flow(src, size, key):
        yield net.transfer(src, "c", size)
        t[key] = env.now
    env.process(run_flow("a", 100.0, "a"))
    env.process(run_flow("b", 100.0, "b"))
    env.run()
    # Both at 50 B/s until 2.0 — both finish at 2.0 (fair share).
    assert t["a"] == pytest.approx(2.0)
    assert t["b"] == pytest.approx(2.0)


def test_network_residual_speedup():
    """When one flow finishes, the survivor picks up the freed bandwidth."""
    env = Env()
    net = Network(env, uplink={"a": 100.0, "b": 100.0, "c": 100.0},
                  downlink={"a": 100.0, "b": 100.0, "c": 100.0}, latency=0.0)
    t = {}

    def run_flow(src, size, key):
        yield net.transfer(src, "c", size)
        t[key] = env.now
    env.process(run_flow("a", 50.0, "a"))    # finishes at 1.0 (50 @ 50 B/s)
    env.process(run_flow("b", 150.0, "b"))   # 50 @ 50 then 100 @ 100 -> 2.0
    env.run()
    assert t["a"] == pytest.approx(1.0)
    assert t["b"] == pytest.approx(2.0)


def test_network_distinct_receivers_full_rate():
    env = Env()
    net = Network(env, uplink={"a": 100.0, "b": 100.0, "c": 100.0, "d": 100.0},
                  downlink={"a": 100.0, "b": 100.0, "c": 100.0, "d": 100.0},
                  latency=0.0)
    t = {}

    def run_flow(src, dst, key):
        yield net.transfer(src, dst, 100.0)
        t[key] = env.now
    env.process(run_flow("a", "c", "ac"))
    env.process(run_flow("b", "d", "bd"))
    env.run()
    assert t["ac"] == pytest.approx(1.0)     # no shared link => full rate
    assert t["bd"] == pytest.approx(1.0)


def test_network_busy_time_union():
    env = Env()
    net = Network(env, uplink={"a": 100.0, "b": 100.0},
                  downlink={"a": 100.0, "b": 100.0}, latency=0.0)

    def seq():
        yield net.transfer("a", "b", 100.0)      # busy [0,1]
        yield env.timeout(5.0)                    # idle  (1,6)
        yield net.transfer("a", "b", 200.0)      # busy [6,8]
    env.process(seq())
    env.run()
    assert net.busy_time == pytest.approx(3.0)
