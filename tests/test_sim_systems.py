"""Simulator system tests validating the paper's headline claims (§5)."""

import pytest

from repro.core import (SYSTEMS, SimConfig, cold_start_latency, make_system,
                        make_workflow, run_closed_loop, run_open_loop)
from repro.core.sim import Env
from repro.core.simcluster import Cluster


def test_all_systems_complete_simple_benchmark():
    wf = make_workflow("WC")
    for name in SYSTEMS:
        r = run_open_loop(name, wf, rate_per_min=6, n_invocations=3)
        assert len(r.latencies) == 3, name
        assert r.timeouts == 0, name
        assert r.p99 > 0


def test_dflow_beats_every_baseline_p99():
    """Paper Fig. 9: DFlow has the lowest 99%-ile latency everywhere.

    ``dflow-stream`` and ``dflow-shard`` are our beyond-paper extensions,
    not paper baselines — they are allowed (expected, even) to beat plain
    dflow."""
    for bench in ["WC", "Gen", "Soy"]:
        wf = make_workflow(bench)
        p99 = {s: run_open_loop(s, wf, rate_per_min=6, n_invocations=5).p99
               for s in SYSTEMS}
        for s in SYSTEMS:
            if s not in ("dflow", "dflow-stream", "dflow-shard"):
                assert p99["dflow"] <= p99[s] + 1e-6, (bench, s, p99)
                assert p99["dflow-stream"] <= p99[s] + 1e-6, (bench, s, p99)
                assert p99["dflow-shard"] <= p99[s] + 1e-6, (bench, s, p99)


def test_dflow_shard_p99_no_worse_than_dflow():
    """DShard's routed 1-hop + tiered transports must never cost latency
    vs the central-directory DStore (the ISSUE 8 acceptance criterion)."""
    for bench in ["WC", "Gen", "Soy"]:
        wf = make_workflow(bench)
        shard = run_open_loop("dflow-shard", wf, rate_per_min=6,
                              n_invocations=5).p99
        plain = run_open_loop("dflow", wf, rate_per_min=6,
                              n_invocations=5).p99
        assert shard <= plain + 1e-6, (bench, shard, plain)


def test_only_cflow_cyc_times_out_fig9():
    """Paper Fig. 9 at 50 MB/s, 6/min: the only timeout bar is CFlow-Cyc."""
    wf = make_workflow("Cyc")
    assert run_open_loop("cflow", wf, rate_per_min=6,
                         n_invocations=5).timeouts > 0
    for s in ("faasflow", "faasflowredis", "knix", "dflow"):
        assert run_open_loop(s, wf, rate_per_min=6,
                             n_invocations=5).timeouts == 0, s


def test_dataflow_pattern_ablation_low_rate():
    """§5.5: at low rate FaaSFlow+DStore is within ~15% of DFlow (the gap is
    the invocation pattern only; both share the DStore data plane)."""
    wf = make_workflow("Gen")
    df = run_open_loop("dflow", wf, rate_per_min=5, n_invocations=5).p99
    fd = run_open_loop("faasflow+dstore", wf, rate_per_min=5,
                       n_invocations=5).p99
    assert fd >= df - 1e-9
    assert fd / df < 1.25


def test_dataflow_pattern_ablation_high_rate():
    """§5.5: at high request rates the dataflow pattern sustains load the
    controlflow pattern cannot (FaaSFlow times out, DFlow keeps going)."""
    wf = make_workflow("Gen")
    df = run_open_loop("dflow", wf, rate_per_min=40, n_invocations=10)
    ff = run_open_loop("faasflow", wf, rate_per_min=40, n_invocations=10)
    assert df.p99 < ff.p99
    assert df.timeouts <= ff.timeouts


def test_cold_start_ratios():
    """Paper §5.4: DFlow ≈5.6x better than CFlow, ≈1.1x vs FaaSFlow."""
    ratios_cf, ratios_ff = [], []
    for bench in ["Cyc", "Epi", "Gen", "Soy"]:
        wf = make_workflow(bench)
        d = cold_start_latency("dflow", wf)
        c = cold_start_latency("cflow", wf)
        f = cold_start_latency("faasflow", wf)
        assert d > 0
        ratios_cf.append(c / d)
        ratios_ff.append(f / d)
    avg_cf = sum(ratios_cf) / len(ratios_cf)
    avg_ff = sum(ratios_ff) / len(ratios_ff)
    assert 3.0 < avg_cf < 12.0      # paper: 5.6x
    assert 0.9 < avg_ff < 2.0       # paper: 1.1x


def test_colocation_interference_ranking():
    """§5.3: co-run degradation is large for CFlow, small for DFlow."""
    benches = [make_workflow(b) for b in ("WC", "FP")]

    def degradation(sysname):
        solo = [run_closed_loop(sysname, [wf], n_per_client=3)[0].mean
                for wf in benches]
        co = [r.mean for r in run_closed_loop(sysname, benches,
                                              n_per_client=3)]
        return sum(c / s for c, s in zip(co, solo)) / len(solo)
    d_dflow = degradation("dflow")
    d_cflow = degradation("cflow")
    assert d_dflow <= d_cflow + 0.05


def test_deterministic_repeatability():
    wf = make_workflow("FP")
    a = run_open_loop("dflow", wf, rate_per_min=6, n_invocations=4)
    b = run_open_loop("dflow", wf, rate_per_min=6, n_invocations=4)
    assert a.latencies == b.latencies
    assert a.internode_bytes == b.internode_bytes


def test_dflow_bandwidth_spreads_sources():
    """Receiver-driven replica selection should pull from >1 source node."""
    env = Env()
    cluster = Cluster(env, SimConfig())
    wf = make_workflow("Gen")
    sys_ = make_system("dflow", env, cluster, wf)
    sys_.invoke()
    env.run(until=120.0)
    sources = {e[0] for e in cluster.network.log}
    assert len(sources) >= 2
