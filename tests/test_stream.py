"""DStream tests: chunked Put/Get overlap, engine streaming equivalence,
mid-stream failure, simulator plane, and the dflow-stream system."""

import threading
import time

import pytest

from repro.core import SYSTEMS, SimConfig, make_system, run_open_loop
from repro.core.dag import FunctionSpec, Workflow
from repro.core.dscheduler import DFlowEngine
from repro.core.dstore import DStore, GetTimeout, Transport
from repro.core.sim import Env
from repro.core.simcluster import Cluster
from repro.core.stream import StreamBroken
from repro.core.workloads import make_workflow


# ----------------------------------------------------------------------
# Real (threaded) DStore streaming
# ----------------------------------------------------------------------

def test_put_get_stream_roundtrip():
    ds = DStore(["n0", "n1"])
    payload = bytes(range(256)) * 100           # 25600 B
    w = ds.put_stream("n0", "k", chunk_size=4096)
    w.write(payload)
    w.close()
    got = ds.get_stream("n1", "k", timeout=5).read_all()
    assert got == payload
    # chunk-granular receiver-driven pulls: one transfer per chunk
    assert ds.transport.transfers == 7          # ceil(25600 / 4096)
    # the monolithic twin serves plain Gets too
    assert ds.get("n1", "k", timeout=1) == payload


def test_get_stream_overlaps_in_progress_put_stream():
    """A consumer pulls chunk 0 while the producer is still emitting."""
    ds = DStore(["n0", "n1"])
    arrivals = []

    def consume():
        for chunk in ds.get_stream("n1", "s", timeout=10):
            arrivals.append((time.monotonic(), chunk))
    th = threading.Thread(target=consume)
    th.start()
    w = ds.put_stream("n0", "s", chunk_size=1024)
    for i in range(6):
        w.write(bytes([i]) * 1024)
        time.sleep(0.03)
    t_close = time.monotonic()
    w.close()
    th.join(10)
    chunks = [c for _, c in arrivals]
    assert chunks == [bytes([i]) * 1024 for i in range(6)]   # in order
    # first chunk observed well before the stream closed
    assert arrivals[0][0] < t_close


def test_stream_duplicate_writers_coalesce():
    """Duplicate producers (straggler re-issue; deterministic functions)
    co-write one stream: per-chunk publication is idempotent, the first
    closer seals it, and readers see exactly one copy of the payload."""
    ds = DStore(["n0", "n1"])
    w1 = ds.put_stream("n0", "k", chunk_size=8)
    w2 = ds.put_stream("n1", "k", chunk_size=8)
    payload = b"deadbeef" * 3
    w1.write(payload[:16])                   # original stalls after chunk 1
    w2.write(payload)                        # duplicate emits everything
    w2.close()
    assert ds.get_stream("n0", "k", timeout=2).read_all() == payload
    w1.write(payload[16:])                   # original wakes; no-ops
    w1.close()
    assert ds.get_stream("n1", "k", timeout=2).read_all() == payload


def test_engine_straggler_duplicate_completes_stalled_stream():
    """A streaming producer that stalls mid-emission gets a duplicate
    issued; the duplicate finishes the stream and the consumer completes
    instead of hanging until timeout."""
    calls = []

    def producer():
        calls.append(threading.get_ident())
        first = len(calls) == 1

        def gen():
            for i in range(4):
                if first and i == 1:
                    time.sleep(3.0)          # straggler stalls mid-stream
                yield bytes([i]) * 256
        return {"blob": gen()}

    wf = Workflow("strag", [
        FunctionSpec("prod", (), ("blob",), fn=producer, exec_time=0.02,
                     stream_outputs=("blob",), chunk_size=256),
        FunctionSpec("cons", ("blob",), ("digest",),
                     fn=lambda blob: {"digest": b"".join(blob)},
                     exec_time=0.01, stream_inputs=("blob",)),
    ])
    eng = DFlowEngine(n_nodes=2, straggler_factor=3.0, get_timeout=8.0)
    t0 = time.monotonic()
    rep = eng.run(wf)
    assert rep.outputs["digest"] == b"".join(bytes([i]) * 256
                                             for i in range(4))
    assert len(calls) >= 2                   # duplicate actually issued
    assert time.monotonic() - t0 < 3.0       # did not wait out the straggler


def test_get_stream_plain_fallback():
    """get_stream on a monolithically-Put key chunks the value locally."""
    ds = DStore(["n0"])
    ds.put("n0", "k", b"x" * 1000)
    assert ds.get_stream("n0", "k", timeout=2).read_all() == b"x" * 1000
    # non-bytes values arrive as a single-item stream
    ds.put("n0", "obj", {"a": 1})
    assert list(ds.get_stream("n0", "obj", timeout=2)) == [{"a": 1}]


def test_stream_node_failure_mid_stream_raises_clean_error():
    ds = DStore(["n0", "n1"])
    errors = []
    done = threading.Event()

    def consume():
        try:
            for _ in ds.get_stream("n1", "k", timeout=10):
                pass
        except StreamBroken as exc:
            errors.append(exc)
        done.set()
    th = threading.Thread(target=consume)
    th.start()
    w = ds.put_stream("n0", "k", chunk_size=16)
    w.write(b"a" * 48)                           # 3 chunks out, not closed
    time.sleep(0.05)
    ds.fail_node("n0")
    assert done.wait(5)
    assert errors and "before close" in str(errors[0])
    th.join(5)


def test_get_stream_timeout():
    ds = DStore(["n0"])
    with pytest.raises(GetTimeout):
        next(iter(ds.get_stream("n0", "never", timeout=0.05)))


def test_closed_stream_reclaimable_after_node_failure():
    """Losing a node after its stream closed must let a recovery rerun
    re-claim and re-publish the stream (regression: the stale claim used to
    silently discard the rerun's writes)."""
    ds = DStore(["n0", "n1"])
    w = ds.put_stream("n0", "k", chunk_size=8)
    w.write(b"payload!" * 4)
    w.close()
    lost = ds.fail_node("n0")
    assert "k" in lost                          # sole replica was on n0
    w2 = ds.put_stream("n1", "k", chunk_size=8)  # re-claim after eviction
    w2.write(b"payload!" * 4)
    w2.close()
    assert ds.get_stream("n1", "k", timeout=2).read_all() == b"payload!" * 4


def test_engine_recovers_stream_outputs_after_node_failure():
    """Incremental recovery re-runs a streaming producer whose node died
    after completion; the workflow still finishes with correct bytes."""
    runs = {"n": 0}

    def producer():
        runs["n"] += 1
        return {"blob": (bytes([i]) * 256 for i in range(4))}

    def consumer(blob):
        return {"digest": b"".join(blob)}

    wf = Workflow("rec", [
        FunctionSpec("prod", (), ("blob",), fn=producer, exec_time=0.01,
                     stream_outputs=("blob",), chunk_size=256),
        FunctionSpec("cons", ("blob",), ("digest",), fn=consumer,
                     exec_time=0.01, stream_inputs=("blob",)),
    ])
    eng = DFlowEngine(n_nodes=2, get_timeout=10.0)
    placement = eng.gs.assign(wf)
    rep = eng.run(wf, inject_failure=placement["prod"])
    assert rep.outputs["digest"] == b"".join(bytes([i]) * 256
                                             for i in range(4))


def test_concurrent_instances_mid_stream_failure_recovers_per_instance():
    """Serve-path fault handling: two namespaced instances stream through
    one shared DStore; the producer node dies *mid-stream*.  Incremental
    recovery re-runs only the lost producers (per instance), re-claims the
    aborted streams, and consumers retry instead of wedging — both
    instances finish with the exact bytes."""
    calls: dict[str, int] = {}
    lock = threading.Lock()

    def mk_producer(inst):
        def producer(seed):
            with lock:
                calls[inst] = calls.get(inst, 0) + 1

            def gen():
                for i in range(6):
                    time.sleep(0.02)          # still emitting when node dies
                    yield bytes(seed) * 128
            return {"blob": gen()}
        return producer

    def consumer(blob):
        return {"digest": b"".join(blob)}

    eng = DFlowEngine(n_nodes=2, get_timeout=10.0)
    store = DStore(eng.nodes, eng.transport)
    runs = []
    for i in range(2):
        wf = Workflow("mid", [
            FunctionSpec("prod", ("seed",), ("blob",),
                         fn=mk_producer(f"prod#{i}"), exec_time=0.12,
                         stream_outputs=("blob",), chunk_size=128),
            FunctionSpec("cons", ("blob",), ("digest",), fn=consumer,
                         exec_time=0.01, stream_inputs=("blob",)),
        ])
        runs.append(eng.start(wf, {"seed": b"%d" % i}, store=store,
                              instance=f"mid#{i}"))
    time.sleep(0.06)                          # both producers mid-emission
    prod_node = runs[0].placement["prod"]
    lost = store.fail_node(prod_node)
    for run in runs:
        run.recover(lost)
    for i, run in enumerate(runs):
        rep = run.wait()
        assert rep.outputs["digest"] == (b"%d" % i) * 6 * 128, i
    # each lost producer re-ran at least once; nothing ran wild
    assert all(1 <= calls[f"prod#{i}"] <= 3 for i in range(2)), calls


# ----------------------------------------------------------------------
# Threaded engine with streaming FunctionSpecs
# ----------------------------------------------------------------------

def _streaming_workflow(n_chunks=6, chunk=4096):
    def producer():
        def gen():
            for i in range(n_chunks):
                time.sleep(0.01)
                yield bytes([i]) * chunk
        return {"blob": gen()}

    def consumer(blob):
        return {"digest": b"".join(blob)}

    return Workflow("stream-wf", [
        FunctionSpec("prod", (), ("blob",), fn=producer, exec_time=0.06,
                     stream_outputs=("blob",), chunk_size=chunk,
                     output_sizes={"blob": n_chunks * chunk}),
        FunctionSpec("cons", ("blob",), ("digest",), fn=consumer,
                     exec_time=0.01, stream_inputs=("blob",)),
    ])


@pytest.mark.parametrize("pattern", ["dataflow", "controlflow"])
def test_engine_streaming_patterns_byte_identical(pattern):
    rep = DFlowEngine(n_nodes=2, pattern=pattern).run(_streaming_workflow())
    expected = b"".join(bytes([i]) * 4096 for i in range(6))
    assert rep.outputs["digest"] == expected


def test_engine_streaming_generator_error_propagates():
    def bad_producer():
        def gen():
            yield b"ok" * 100
            raise ValueError("mid-stream kaput")
        return {"blob": gen()}

    wf = Workflow("bad", [
        FunctionSpec("prod", (), ("blob",), fn=bad_producer,
                     stream_outputs=("blob",), chunk_size=64),
        FunctionSpec("cons", ("blob",), ("d",),
                     fn=lambda blob: {"d": b"".join(blob)},
                     stream_inputs=("blob",)),
    ])
    with pytest.raises(RuntimeError):
        DFlowEngine(n_nodes=2, get_timeout=5.0).run(wf)


def test_functionspec_stream_validation():
    with pytest.raises(ValueError, match="stream_inputs"):
        FunctionSpec("f", inputs=("a",), stream_inputs=("b",))
    with pytest.raises(ValueError, match="stream_outputs"):
        FunctionSpec("f", outputs=("x",), stream_outputs=("y",))


def test_parser_accepts_stream_fields():
    from repro.core.dag import parse_workflow
    wf = parse_workflow({
        "name": "p",
        "functions": {
            "a": {"inputs": ["src"], "outputs": ["mid"],
                  "stream_outputs": ["mid"], "chunk_size": "64KB"},
            "b": {"inputs": ["mid"], "outputs": ["out"],
                  "stream_inputs": ["mid"]},
        },
    })
    assert wf.functions["a"].stream_outputs == ("mid",)
    assert wf.functions["a"].chunk_size == 64 * 1024
    assert wf.functions["b"].stream_inputs == ("mid",)


# ----------------------------------------------------------------------
# Simulator: StreamingDStorePlane / dflow-stream
# ----------------------------------------------------------------------

def test_dflow_stream_registered():
    assert "dflow-stream" in SYSTEMS
    env = Env()
    cluster = Cluster(env, SimConfig())
    sys_ = make_system("dflow-stream", env, cluster, make_workflow("WC"))
    assert sys_.streaming and sys_.plane.name == "dstore-stream"


def test_sim_streaming_plane_chunks_overlap_production():
    """A consumer's chunk pulls start before the producer finishes."""
    env = Env()
    cluster = Cluster(env, SimConfig(bandwidth=25e6, stream_chunk=1e6))
    plane = make_system("dflow-stream", env, cluster,
                        make_workflow("WC")).plane
    plane.put_stream("node1", "k", 8e6, produce_time=1.0)
    got = plane.get_stream("node2", "k")
    env.run(until=30.0)
    assert got.triggered and got.value == pytest.approx(8e6)
    # first chunk transfer began while the producer was still emitting
    first_start = min(t0 for (_, _, _, t0, _, tag) in cluster.network.log
                      if tag.startswith("dstream:k"))
    assert first_start < 1.0


def test_dflow_stream_beats_dflow_on_large_outputs():
    """Acceptance: on a large-output workload under constrained bandwidth,
    dflow-stream beats monolithic dflow on simulated p99."""
    cfg = SimConfig(bandwidth=25e6)
    wf = make_workflow("WC-L")
    p99 = {}
    for system in ("dflow", "dflow-stream"):
        r = run_open_loop(system, wf, rate_per_min=6.0, n_invocations=4,
                          cfg=cfg)
        assert r.timeouts == 0
        p99[system] = r.p99
    assert p99["dflow-stream"] < p99["dflow"]


def test_real_engine_streaming_beats_monolithic_wall_time():
    """Acceptance: real-engine wall time improves with streaming when the
    producer emits incrementally and the consumer processes per chunk."""
    chunk, n = 64 * 1024, 10
    gap = 0.02

    def producer_stream():
        def gen():
            for i in range(n):
                time.sleep(gap)
                yield bytes([i]) * chunk
        return {"blob": gen()}

    def producer_mono():
        parts = []
        for i in range(n):
            time.sleep(gap)
            parts.append(bytes([i]) * chunk)
        return {"blob": b"".join(parts)}

    def consumer_stream(blob):
        total = 0
        for c in blob:
            time.sleep(gap / 2)
            total += len(c)
        return {"out": total}

    def consumer_mono(blob):
        time.sleep(gap / 2 * n)
        return {"out": len(blob)}

    def wall(prod, cons, streaming):
        extra = (dict(stream_outputs=("blob",), chunk_size=chunk)
                 if streaming else {})
        wf = Workflow("w", [
            FunctionSpec("p", (), ("blob",), fn=prod, exec_time=gap * n,
                         output_sizes={"blob": chunk * n}, **extra),
            FunctionSpec("c", ("blob",), ("out",), fn=cons,
                         exec_time=gap / 2 * n,
                         stream_inputs=("blob",) if streaming else ()),
        ])
        eng = DFlowEngine(n_nodes=2, transport=Transport(bandwidth=50e6))
        rep = eng.run(wf)
        assert rep.outputs["out"] == chunk * n
        return rep.wall_time

    wall(producer_stream, consumer_stream, True)        # warm-up (imports)
    t_stream = wall(producer_stream, consumer_stream, True)
    t_mono = wall(producer_mono, consumer_mono, False)
    assert t_stream < t_mono


# ----------------------------------------------------------------------
# DShard: mid-stream node loss heals from the producing shard only
# ----------------------------------------------------------------------

def test_sharded_mid_stream_failure_heals_from_producing_shard():
    """Sharded replay of the PR 2 recovery harness: two namespaced
    instances stream through one shared ShardedDStore, the producer node
    dies mid-stream (StreamBroken), and per-instance recovery re-runs only
    the lost producers.  Healing touches the producing shard only — a
    bystander shard's records are byte-for-byte untouched (no
    directory-wide scan) — and every post-failure Get still resolves in
    at most one hop (a failure re-home is not a misroute)."""
    from repro.core.check import TraceChecker, TraceRecorder
    from repro.core.router import ShardedDStore

    calls: dict[str, int] = {}
    lock = threading.Lock()

    def mk_producer(inst):
        def producer(seed):
            with lock:
                calls[inst] = calls.get(inst, 0) + 1

            def gen():
                for i in range(6):
                    time.sleep(0.02)          # still emitting when node dies
                    yield bytes(seed) * 128
            return {"blob": gen()}
        return producer

    def consumer(blob):
        return {"digest": b"".join(blob)}

    eng = DFlowEngine(n_nodes=3, get_timeout=10.0, sharded=True)
    store = ShardedDStore(eng.nodes, eng.transport)
    rec = TraceRecorder()
    store.attach_tracer(rec)
    runs = []
    for i in range(2):
        wf = Workflow("mid", [
            FunctionSpec("prod", ("seed",), ("blob",),
                         fn=mk_producer(f"prod#{i}"), exec_time=0.12,
                         stream_outputs=("blob",), chunk_size=128),
            FunctionSpec("cons", ("blob",), ("digest",), fn=consumer,
                         exec_time=0.01, stream_inputs=("blob",)),
        ])
        runs.append(eng.start(wf, {"seed": b"%d" % i}, store=store,
                              instance=f"mid#{i}"))
    prod_node = runs[0].placement["prod"]
    used = set(runs[0].placement.values()) | set(runs[1].placement.values())
    bystander = next(n for n in eng.nodes if n not in used)
    store.put(bystander, "sentinel", b"innocent")     # homed on bystander

    time.sleep(0.06)                          # both producers mid-emission
    lost = store.fail_node(prod_node)
    bys_keys = sorted(store.shards[bystander].keys())
    for run in runs:
        run.recover(lost)
    for i, run in enumerate(runs):
        rep = run.wait()
        assert rep.outputs["digest"] == (b"%d" % i) * 6 * 128, i
    assert all(1 <= calls[f"prod#{i}"] <= 3 for i in range(2)), calls

    # Healing never scanned/mutated the bystander shard: same records,
    # same replica locations, bytes still served from it.
    assert sorted(store.shards[bystander].keys()) == bys_keys
    meta = store.shards[bystander].peek("sentinel")
    assert meta is not None and set(meta.locations) == {bystander}
    assert store.get(prod_node, "sentinel", timeout=5.0) == b"innocent"

    # 1-hop invariant held across the failure: no directory bounce, and
    # the full trace (incl. routing events) is checker-clean.
    assert store.hop_hist.get(2, 0) == 0, dict(store.hop_hist)
    TraceChecker().check_or_raise(rec.events())
