"""Substrate tests: optimizer, data pipeline, checkpointing, compression."""

import pathlib

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import CheckpointManager, latest_step, save_state
from repro.checkpoint.ckpt import restore_state
from repro.data import DataConfig, Prefetcher, SyntheticLM
from repro.optim.adamw import (AdamWConfig, adamw_init, adamw_update,
                               clip_by_global_norm, warmup_cosine)
from repro.optim.compress import (CompressionConfig, compress_gradients,
                                  decompress_gradients)


# ---------------------------------------------------------------- optimizer
def test_warmup_cosine_shape():
    cfg = AdamWConfig(lr=1.0, warmup_steps=10, total_steps=100)
    lrs = [float(warmup_cosine(cfg, jnp.asarray(s))) for s in range(100)]
    assert lrs[0] == pytest.approx(0.0)
    assert lrs[10] == pytest.approx(1.0, abs=0.1)
    assert lrs[99] < 0.2
    assert max(lrs) <= 1.0 + 1e-6


def test_clip_by_global_norm():
    g = {"a": jnp.ones((10,)) * 10.0}
    clipped, gn = clip_by_global_norm(g, 1.0)
    assert float(gn) == pytest.approx(10.0 * np.sqrt(10), rel=1e-5)
    norm_after = float(jnp.linalg.norm(clipped["a"]))
    assert norm_after == pytest.approx(1.0, rel=1e-5)


def test_adamw_reduces_quadratic_loss():
    cfg = AdamWConfig(lr=0.1, warmup_steps=1, total_steps=100,
                      weight_decay=0.0)
    params = {"w": jnp.asarray([5.0, -3.0])}
    state = adamw_init(params, cfg)

    def loss(p):
        return jnp.sum(p["w"] ** 2)
    l0 = float(loss(params))
    for _ in range(50):
        g = jax.grad(loss)(params)
        params, state, _ = adamw_update(params, g, state, cfg)
    assert float(loss(params)) < 0.1 * l0
    assert int(state.step) == 50


def test_adamw_no_decay_on_norms():
    cfg = AdamWConfig(lr=0.0, weight_decay=1.0, warmup_steps=0,
                      total_steps=10)
    params = {"w": jnp.ones(4), "ln1": jnp.ones(4)}
    g = jax.tree.map(jnp.zeros_like, params)
    state = adamw_init(params, cfg)
    new_params, *_ = adamw_update(params, g, state, cfg)
    # lr=0 => no update at all; decay applies inside the lr-scaled update,
    # so both stay identical here — check the path selector directly.
    from repro.optim.adamw import _no_decay
    assert _no_decay(("layers", "ln1"))
    assert _no_decay(("layers", "attn", "q_norm"))
    assert not _no_decay(("layers", "attn", "wq"))


# ---------------------------------------------------------------- data
def test_synthetic_batch_deterministic_by_step():
    cfg = DataConfig(vocab=128, seq_len=16, global_batch=4, seed=7)
    src = SyntheticLM(cfg)
    a = src.batch_at(3)["tokens"]
    b = src.batch_at(3)["tokens"]
    c = src.batch_at(4)["tokens"]
    assert np.array_equal(a, b)
    assert not np.array_equal(a, c)
    assert a.shape == (4, 17)
    assert a.min() >= 0 and a.max() < 128


def test_prefetcher_order_and_restart():
    cfg = DataConfig(vocab=64, seq_len=8, global_batch=2, seed=1)
    src = SyntheticLM(cfg)
    pf = Prefetcher(src, start_step=5, depth=2)
    steps = [pf.next()[0] for _ in range(3)]
    pf.close()
    assert steps == [5, 6, 7]
    pf2 = Prefetcher(src, start_step=6, depth=2)
    s, batch = pf2.next()
    pf2.close()
    assert s == 6
    assert np.array_equal(batch["tokens"], src.batch_at(6)["tokens"])


# ---------------------------------------------------------------- checkpoint
def test_checkpoint_roundtrip(tmp_path):
    state = {"params": {"w": jnp.arange(6, dtype=jnp.float32).reshape(2, 3)},
             "step": jnp.asarray(7)}
    save_state(tmp_path, 7, state)
    assert latest_step(tmp_path) == 7
    like = jax.tree.map(lambda x: jnp.zeros_like(x), state)
    restored = restore_state(tmp_path, 7, like)
    assert np.array_equal(np.asarray(restored["params"]["w"]),
                          np.arange(6, dtype=np.float32).reshape(2, 3))


def test_checkpoint_manager_gc_and_async(tmp_path):
    mgr = CheckpointManager(tmp_path, keep=2, async_save=True)
    state = {"w": jnp.ones(3)}
    for s in (1, 2, 3):
        mgr.save(s, state)
    mgr.wait()
    steps = sorted(int(p.name.split("_")[1]) for p in tmp_path.iterdir()
                   if p.name.startswith("step_"))
    assert steps == [2, 3]
    restored, step = mgr.restore({"w": jnp.zeros(3)})
    assert step == 3


def test_checkpoint_restore_respects_dtype(tmp_path):
    state = {"w": jnp.ones(4, jnp.float32)}
    save_state(tmp_path, 1, state)
    like = {"w": jnp.zeros(4, jnp.bfloat16)}
    restored = restore_state(tmp_path, 1, like)
    assert restored["w"].dtype == jnp.bfloat16


# ---------------------------------------------------------------- compression
@pytest.mark.parametrize("scheme", ["int8", "topk"])
def test_compression_roundtrip_error_feedback(scheme):
    cfg = CompressionConfig(scheme=scheme, topk_ratio=0.25)
    g = {"w": jnp.asarray(np.random.default_rng(0)
                          .normal(size=(32, 8)).astype(np.float32))}
    payload, residual = compress_gradients(g, None, cfg)
    approx = decompress_gradients(payload, cfg)
    err1 = float(jnp.abs(approx["w"] - g["w"]).mean())
    # feeding the residual back must reduce accumulated error over rounds
    payload2, residual2 = compress_gradients(g, residual, cfg)
    approx2 = decompress_gradients(payload2, cfg)
    total2 = approx["w"] + approx2["w"]
    err2 = float(jnp.abs(total2 - 2 * g["w"]).mean())
    assert err2 < 2 * err1 + 1e-6          # error does not accumulate


def test_int8_payload_is_int8():
    cfg = CompressionConfig(scheme="int8")
    g = {"w": jnp.ones((16,), jnp.float32)}
    payload, _ = compress_gradients(g, None, cfg)
    q, scale = payload["w"]
    assert q.dtype == jnp.int8
